"""Prefill/decode consistency: serving a sequence incrementally must agree
with the train-path full forward pass.

For each smoke arch (local mode, single device):

  * prefill over ``tokens[:, :S-1]`` must predict the same next token as
    the full-forward argmax at position S-2, and
  * one decode step consuming ``tokens[:, S-1]`` against the prefilled
    cache must predict the same next token as the full-forward argmax at
    position S-1.

The reference logits come from ``transformer.apply_stack`` — the *training*
forward — so any cache-slot or RoPE off-by-one in the serving path breaks
this end to end. The encoder-decoder arch is exercised separately (its
decoder consistency is covered by the spmd `serve_encdec` dist check; the
prefill here is encoder-only).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.startrail import StarTrailConfig
from repro.models import blocks, transformer
from repro.models.factory import build_model
from repro.models.runtime import Runtime
from repro.serve import step as serve_step

S = 17   # prefill length 16 divides the SSM chunk (8); S itself is odd

ARCHS = [a for a in registry.ASSIGNED_ARCHS
         if not registry.get_smoke(a).encdec]


def _consistency_cfg(arch):
    """Smoke config with MoE capacity lifted so no token is ever dropped:
    expert capacity couples tokens across the sequence, so full-forward vs
    incremental decode legitimately differ at drop boundaries. The cache
    and RoPE bookkeeping under test are unaffected."""
    import dataclasses

    cfg = registry.get_smoke(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    return cfg


def _rt(cfg, seq_len):
    return Runtime(mode="local", st_cfg=StarTrailConfig(
        seq_len=seq_len, seq_scheme="contiguous", causal=True,
        window=cfg.window))


def _full_logits(model, params, tokens):
    """Train-path forward -> (B, S, V) float32 logits (reference)."""
    cfg = model.cfg
    rt = _rt(cfg, tokens.shape[1])
    x = blocks.embed(rt, params["embed"], tokens, cfg)
    x, _ = transformer.apply_stack(rt, params["stack"], x, cfg, causal=True,
                                   remat="none")
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    table = head["table"].astype(jnp.float32)
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), table)
    if table.shape[0] > cfg.vocab_size:
        logits = jnp.where(jnp.arange(table.shape[0]) < cfg.vocab_size,
                           logits, -1e30)
    return logits


def _pad_attn_cache(cache, capacity):
    """Grow the attention K/V slots (period-stacked (n_per, B, S, H, hd))
    to `capacity`; recurrent states pass through unchanged."""
    def pad(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = pad(v)
            elif k in ("k", "v") and v.ndim == 5:
                arr = np.zeros(v.shape[:2] + (capacity,) + v.shape[3:],
                               np.asarray(v).dtype)
                arr[:, :, :v.shape[2]] = np.asarray(v)
                out[k] = jnp.asarray(arr)
            else:
                out[k] = v
        return out
    return pad(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    cfg = _consistency_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # the reference runs right-padded to an SSM-chunk multiple; causality
    # (attention masks and recurrences alike) makes padding invisible to
    # every position before it
    s_ref = ((S + 7) // 8) * 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, s_ref), 0,
                                cfg.vocab_size, jnp.int32)

    ref = np.asarray(jax.jit(
        lambda p, t: _full_logits(model, p, t))(params, tokens))
    ref_argmax = ref.argmax(-1)[0]                       # (s_ref,)

    rt = _rt(cfg, S - 1)
    tok_p, cache = jax.jit(lambda p, b: serve_step.lm_prefill(
        rt, p, b, cfg))(params, {"tokens": tokens[:, :S - 1]})
    assert int(np.asarray(tok_p)[0, 0]) == int(ref_argmax[S - 2]), (
        f"{arch}: prefill next-token != full-forward argmax at {S - 2}")

    cache = _pad_attn_cache(cache, S)        # capacity for the new slot
    tok_d, _ = jax.jit(lambda p, c, t: serve_step.lm_decode_step(
        rt, p, c, t, cfg, S - 1))(params, cache, tokens[:, S - 1:S])
    assert int(np.asarray(tok_d)[0, 0]) == int(ref_argmax[S - 1]), (
        f"{arch}: decode next-token != full-forward argmax at {S - 1}")


# ---------------------------------------------------------------------------
# chunked prefill: every paged-engine (attention-mixer) arch
# ---------------------------------------------------------------------------

def _paged_archs():
    from repro.engine import paged_cache

    out = []
    for a in ARCHS:
        cfg = registry.get_smoke(a)
        if paged_cache.supported(cfg)[0] and cfg.moe is None:
            out.append(a)
    return out


@pytest.mark.parametrize("arch", _paged_archs())
def test_chunked_prefill_consistency(arch):
    """Chunked == monolithic == train-path argmax, per attention-mixer
    arch: a greedy request whose prompt splits into several chunks must
    emit the same tokens as the unchunked engine, and every emitted token
    must equal the full-forward greedy continuation."""
    from repro.engine import EngineConfig, Request, build_engine

    eng = build_engine(arch, smoke=True, c=1, data=1,
                       eng=EngineConfig(max_slots=1, page_size=4,
                                        pages_per_shard=32, max_len=64,
                                        prefill_chunk=8))
    cfg = eng.cfg
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (S - 1,), 0, cfg.vocab_size, jnp.int32))
    req = dict(uid="c", tokens=prompt.tolist(), max_new_tokens=3)

    eng.add_request(Request(**req))
    out_chunked = eng.run()["c"]
    assert eng.metrics.prefill_chunks > eng.metrics.prefills, (
        f"{arch}: the {S - 1}-token prompt did not split into chunks")

    eng.reset()
    eng._chunk = 0                           # same engine, monolithic
    eng.add_request(Request(**req))
    out_mono = eng.run()["c"]
    assert out_chunked == out_mono, (
        f"{arch}: chunked prefill diverged from monolithic: "
        f"{out_chunked} != {out_mono}")

    # train-path reference: greedy continuation via the full forward
    seq = prompt.tolist()
    for i, tok in enumerate(out_chunked):
        s_ref = ((len(seq) + 7) // 8) * 8    # causal right-padding
        padded = np.zeros((1, s_ref), np.int32)
        padded[0, :len(seq)] = seq
        ref = np.asarray(jax.jit(
            lambda p, t: _full_logits(eng.model, p, t))(
                eng.params, jnp.asarray(padded)))
        want = int(ref.argmax(-1)[0, len(seq) - 1])
        assert tok == want, (
            f"{arch}: chunked token {i} = {tok} != train-path argmax {want}")
        seq.append(tok)


def test_chunked_prefill_rejected_for_moe():
    """Expert capacity couples a chunk's tokens to the rest of the prompt —
    the engine must refuse the knob rather than silently diverge."""
    from repro.engine import EngineConfig, build_engine

    moe = [a for a in ARCHS if registry.get_smoke(a).moe is not None
           and _paged_supported(a)]
    if not moe:
        pytest.skip("no paged MoE arch assigned")
    with pytest.raises(NotImplementedError, match="chunked prefill"):
        build_engine(moe[0], smoke=True, c=1, data=1,
                     eng=EngineConfig(max_slots=1, page_size=4,
                                      pages_per_shard=32, max_len=64,
                                      prefill_chunk=8))


def _paged_supported(arch):
    from repro.engine import paged_cache

    return paged_cache.supported(registry.get_smoke(arch))[0]
