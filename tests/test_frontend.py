"""Tests for the process-separated serving front end (``repro.frontend``).

Three tiers, cheapest first:

  * pure-host unit tests — the engine-API wire protocol, typed
    ``Rejection`` reasons (engine- and frontend-side), priority-class
    parsing, SLO-priced admission, the cross-process Prometheus merge,
    and orchestrator policy (budgets, liveness, failover) driven through
    a scripted fake replica: no jax, no devices;
  * one shared single-device engine behind ``LocalReplica`` —
    orchestrator-vs-engine token parity, preemption bit-identity
    (greedy AND sampled), drain/shutdown semantics, and the HTTP/SSE
    server end to end on an ephemeral port;
  * one spawned two-worker session — engine-API over real pipes:
    bit-identity vs the in-process baseline, merged ``/metrics``, then
    one worker hard-killed mid-decode and every stream (the dead
    worker's re-admitted on the survivor included) still bit-identical.
"""

import dataclasses

import pytest

from repro.engine import Rejection, Request
from repro.frontend import protocol
from repro.frontend.orchestrator import Orchestrator
from repro.frontend.protocol import ReplicaDead, StepResult, pack_step
from repro.frontend.slo import (PriorityClass, SLOAdmission,
                                default_classes, parse_classes)

ARCH = "h2o-danube-1.8b"


# ---------------------------------------------------------------------------
# protocol: wire round-trips and the packed step result
# ---------------------------------------------------------------------------

def test_request_wire_roundtrip():
    req = Request(uid=protocol.uid_for(7), tokens=[1, 2, 3],
                  max_new_tokens=4, temperature=0.8, top_k=16, top_p=0.9,
                  seed=3, priority="batch")
    assert protocol.request_from_wire(protocol.request_to_wire(req)) == req
    assert protocol.rid_for(req.uid) == 7


def test_rejection_wire_roundtrip():
    rej = Rejection("slo_ttft_unattainable", "priced out",
                    retry_after_steps=12)
    back = protocol.rejection_from_wire(protocol.rejection_to_wire(rej))
    assert back == rej and back.retryable
    perm = Rejection("empty_prompt", "no tokens")
    assert not perm.retryable


def test_pack_step_is_one_host_array():
    import numpy as np

    res = pack_step([(3, 101), (9, 102)], [9], free_slots=1, queued=2,
                    active=1, outstanding_tokens=40)
    assert isinstance(res.tokens, np.ndarray)
    assert res.tokens.dtype == np.int32 and res.tokens.shape == (2, 2)
    assert res.emitted == [(3, 101), (9, 102)]
    assert res.finished == [9]
    empty = pack_step([], [], free_slots=0, queued=0, active=0,
                      outstanding_tokens=0)
    assert empty.tokens.shape == (0, 2) and empty.emitted == []


# ---------------------------------------------------------------------------
# engine-side typed rejections (scheduler.validate, one reason each)
# ---------------------------------------------------------------------------

def _sched(**kw):
    from repro.engine import Scheduler

    base = dict(max_slots=2, page_size=4, sp=1, pages_per_shard=4,
                max_len=32)
    base.update(kw)
    return Scheduler(**base)


@pytest.mark.parametrize("req,reason", [
    (Request("a", [], 4), "empty_prompt"),
    (Request("b", [1, 2], 0), "bad_budget"),
    (Request("c", [1] * 30, 10), "too_long"),
    (Request("d", [1] * 20, 11), "pool_too_small"),
])
def test_engine_rejection_reasons(req, reason):
    rej = _sched().validate(req)
    assert rej is not None and rej.reason == reason
    assert rej.retry_after_steps is None    # all permanent
    # enqueue keeps raising on the same condition
    with pytest.raises(ValueError):
        _sched().enqueue(req)


def test_valid_request_passes_validate():
    assert _sched().validate(Request("ok", [1, 2, 3], 4)) is None


# ---------------------------------------------------------------------------
# priority classes + SLO admission (analytic, no devices)
# ---------------------------------------------------------------------------

def test_parse_classes():
    classes = parse_classes("interactive,batch,scavenger",
                            slo_ttft_ms=250.0, budget_tokens=1000)
    assert [c.rank for c in classes.values()] == [0, 1, 2]
    assert classes["interactive"].slo_ttft_ms == 250.0
    assert classes["interactive"].budget_tokens == 1000
    assert not classes["interactive"].preemptible
    assert classes["batch"].preemptible
    assert classes["scavenger"].preemptible
    assert classes["batch"].slo_ttft_ms == 0.0
    with pytest.raises(ValueError):
        parse_classes("  ,  ")
    assert set(default_classes()) == {"interactive", "batch"}


def test_slo_admission_prices_queue_depth():
    from repro.configs import registry

    cfg = registry.get_smoke(ARCH)
    slo = SLOAdmission(cfg, sp=1, page_size=4, decode_batch=4)
    d = slo.price(prompt_len=16, queued_tokens=0)
    assert d["ttft_s"] == pytest.approx(d["prefill_s"])
    d2 = slo.price(prompt_len=16, queued_tokens=4000)
    assert d2["ttft_s"] > d["ttft_s"]       # queued work prices into TTFT
    # no SLO -> never rejects; tight SLO + deep queue -> typed 429
    assert slo.check(prompt_len=16, slo_ttft_ms=0.0,
                     queued_tokens=10**9) is None
    rej = slo.check(prompt_len=16, slo_ttft_ms=1e-6,
                    queued_tokens=10**6)
    assert rej is not None and rej.reason == "slo_ttft_unattainable"
    assert rej.retryable and rej.retry_after_steps >= 1
    # a generous SLO with an empty queue admits
    assert slo.check(prompt_len=16, slo_ttft_ms=1e9,
                     queued_tokens=0) is None


# ---------------------------------------------------------------------------
# cross-process Prometheus merge
# ---------------------------------------------------------------------------

def test_prometheus_merge_roundtrip():
    from repro import obs

    w = obs.Registry()
    w.counter("engine_steps_total", "steps").inc(5)
    h = w.histogram("engine_ttft_seconds", "ttft")
    for v in (0.002, 0.03, 0.4, 2.0):
        h.observe(v)
    text = w.render_prometheus()

    merged = obs.Registry()
    obs.merge_prometheus_text(merged, text, worker="0")
    obs.merge_prometheus_text(merged, text, worker="1")
    c = merged.get("engine_steps_total")
    assert c.sum() == 10
    assert c.value(worker="0") == 5 and c.value(worker="1") == 5
    hm = merged.get("engine_ttft_seconds")
    assert hm.count() == 8
    # per-worker filtering and quantiles survive the text round-trip
    assert hm.count(worker="0") == 4
    assert hm.quantile(0.5) == h.quantile(0.5)


# ---------------------------------------------------------------------------
# orchestrator policy on a scripted fake replica (no engine, no jax)
# ---------------------------------------------------------------------------

class FakeReplica:
    """Engine-API double: admits everything, emits one token per active
    rid per step, finishes each request after its budget."""

    def __init__(self, index):
        self.index = index
        self.alive = True
        self.last = None
        self.active = {}                    # rid -> remaining budget
        self._pending = False
        self.free_slots_override = None

    def add(self, rid, wire):
        self.active[rid] = int(wire["max_new_tokens"])
        return None

    def step_send(self):
        if not self.alive:
            raise ReplicaDead(self.index)
        self._pending = True

    def step_recv(self):
        assert self._pending
        self._pending = False
        if not self.alive:
            raise ReplicaDead(self.index)
        emitted, finished = [], []
        for rid in list(self.active):
            emitted.append((rid, 1000 + rid))
            self.active[rid] -= 1
            if self.active[rid] <= 0:
                finished.append(rid)
                del self.active[rid]
        free = 4 - len(self.active)
        if self.free_slots_override is not None:
            free = self.free_slots_override
        self.last = pack_step(
            emitted, finished, free_slots=free, queued=0,
            active=len(self.active),
            outstanding_tokens=sum(self.active.values()))
        return self.last

    def preempt(self, rid):
        return None

    def idle(self):
        return not self.active

    def flush(self):
        pass

    def metrics_text(self):
        return ""

    def trace_events(self):
        return []

    def shutdown(self):
        self.alive = False

    def kill(self):
        self.alive = False


def test_frontend_rejection_reasons():
    orch = Orchestrator([FakeReplica(0)], classes={
        "interactive": PriorityClass("interactive", 0, budget_tokens=10)})
    rej = orch.submit([1, 2], 4, cls="nope")
    assert isinstance(rej, Rejection) and rej.reason == "unknown_class"

    ok = orch.submit([1, 2], 8)
    assert isinstance(ok, int)
    rej = orch.submit([1, 2], 8)            # 8 + 8 > 10-token class budget
    assert isinstance(rej, Rejection)
    assert rej.reason == "class_budget_exhausted" and rej.retryable

    orch.draining = True
    rej = orch.submit([1, 2], 2)
    assert isinstance(rej, Rejection) and rej.reason == "draining"
    orch.draining = False

    orch.run()                              # finish the admitted stream
    orch.replicas[0].kill()
    orch.step()                             # notices the dead replica
    rej = orch.submit([1, 2], 2)
    assert isinstance(rej, Rejection) and rej.reason == "no_live_replica"
    # every rejection was counted by reason on the frontend registry
    c = orch.registry.get("frontend_rejections_total")
    for reason in ("unknown_class", "class_budget_exhausted", "draining",
                   "no_live_replica"):
        assert c.value(reason=reason) == 1, reason


def test_failover_readmits_on_survivor():
    orch = Orchestrator([FakeReplica(0), FakeReplica(1)])
    rids = [orch.submit([1, 2, 3], 5) for _ in range(4)]
    for _ in range(2):
        orch.step()
    dead = orch.streams[rids[0]].replica
    survivor = 1 - dead
    orch.replicas[dead].kill()
    out = orch.run()
    for rid in rids:
        s = orch.streams[rid]
        assert s.done and len(out[rid]) == 5, (rid, out[rid])
        assert s.replica in (dead, survivor)
    moved = [r for r in rids if orch.streams[r].replica == survivor
             and orch.streams[r].resumed > 0]
    assert orch.registry.get("frontend_failovers_total").value() >= 1
    assert moved, "no stream was re-admitted on the survivor"


def test_shutdown_drains_and_joins():
    orch = Orchestrator([FakeReplica(0)])
    rid = orch.submit([1, 2], 3)
    streams = orch.shutdown(drain=True)
    assert orch.draining
    assert streams[rid] == [1000 + rid] * 3
    assert not orch.replicas[0].alive       # shut down, not abandoned
    rej = orch.submit([1, 2], 3)
    assert isinstance(rej, Rejection) and rej.reason == "draining"


# ---------------------------------------------------------------------------
# engine-backed: one shared LocalReplica spec (single smoke device)
# ---------------------------------------------------------------------------

_CTX = {}


def _spec():
    if not _CTX:
        from repro.configs import registry
        from repro.engine import EngineConfig
        from repro.plan import make_serve_plan

        cfg = registry.get_smoke(ARCH)
        plan = make_serve_plan(cfg, arch=ARCH, n_devices=1, decode_batch=2,
                               page_size=4, max_len=64, mesh_kind="local",
                               prefix_cache=True)
        eng = EngineConfig(max_slots=2, page_size=4, pages_per_shard=64,
                           max_len=64)
        _CTX["spec"] = protocol.make_worker_spec(plan=plan, eng=eng)
        _CTX["cfg"] = cfg
        _CTX["plan"] = plan
        _CTX["eng"] = eng
    return _CTX["spec"]


def _mixed_requests(n=4, gen=6):
    reqs = []
    for i in range(n):
        prompt = [(3 * i + j) % 97 + 1 for j in range(10 + i)]
        reqs.append(dict(prompt=prompt, max_new_tokens=gen,
                         temperature=0.0 if i % 2 == 0 else 0.8,
                         top_k=0 if i % 2 == 0 else 16, seed=5 + i))
    return reqs


def _submit_all(orch, reqs, **kw):
    rids = []
    for r in reqs:
        r = dict(r, **kw)
        rid = orch.submit(r.pop("prompt"), r.pop("max_new_tokens"), **r)
        assert isinstance(rid, int), rid
        rids.append(rid)
    return rids


def test_orchestrator_matches_engine_tokens():
    import jax

    from repro.engine import Engine
    from repro.frontend.worker import LocalReplica
    from repro.models.factory import build_model

    spec = _spec()
    reqs = _mixed_requests()
    orch = Orchestrator([LocalReplica(0, spec)])
    rids = _submit_all(orch, reqs)
    out = orch.run()

    model = build_model(_CTX["cfg"])
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, _CTX["plan"], _CTX["eng"], params)
    for i, r in enumerate(reqs):
        assert engine.add_request(Request(
            uid=f"q{i}", tokens=r["prompt"],
            max_new_tokens=r["max_new_tokens"],
            temperature=r["temperature"], top_k=r["top_k"],
            seed=r["seed"])) is None
    ref = engine.run()
    for i, rid in enumerate(rids):
        assert out[rid] == ref[f"q{i}"], i
    # engine-side rejection surfaces through the orchestrator, typed
    rej = orch.submit([], 4)
    assert isinstance(rej, Rejection) and rej.reason == "empty_prompt"


def test_preemption_is_bit_identical():
    """Interactive arrivals preempt a slot-pinning batch stream; every
    stream — the spilled-and-resumed one included, greedy and sampled —
    matches the preemption-off run bit for bit."""
    from repro.frontend.worker import LocalReplica

    classes = {"interactive": PriorityClass("interactive", 0),
               "batch": PriorityClass("batch", 1, preemptible=True)}

    def run(preempt):
        orch = Orchestrator([LocalReplica(0, _spec())], classes=classes,
                            preempt=preempt)
        b1 = orch.submit(list(range(1, 11)), 12, cls="batch", seed=2)
        b2 = orch.submit(list(range(2, 12)), 12, cls="batch",
                         temperature=0.7, top_k=8, seed=3)
        for _ in range(6):                  # both batch streams decoding
            orch.step()
        i1 = orch.submit(list(range(5, 13)), 4, cls="interactive", seed=9)
        out = orch.run()
        pre = sum(orch.streams[r].preemptions for r in (b1, b2))
        return [out[r] for r in (b1, b2, i1)], pre

    on, n_on = run(True)
    off, n_off = run(False)
    assert n_on > 0 and n_off == 0
    assert on == off, "preempted/resumed streams diverged"


def test_http_server_streams_and_rejects():
    """The asyncio HTTP/SSE server end to end on an ephemeral port:
    streamed tokens equal the orchestrator's, typed rejections map to
    400, /metrics and /healthz serve."""
    import asyncio
    import threading
    import time

    from repro.frontend import client
    from repro.frontend.server import FrontendServer, status_for
    from repro.frontend.worker import LocalReplica

    assert status_for(Rejection("empty_prompt", "")) == 400
    assert status_for(Rejection("slo_ttft_unattainable", "",
                                retry_after_steps=3)) == 429
    assert status_for(Rejection("draining", "")) == 503
    assert status_for(Rejection("no_live_replica", "",
                                retry_after_steps=1)) == 503

    orch = Orchestrator([LocalReplica(0, _spec())])
    srv = FrontendServer(orch, port=0, worker_spec=_spec(), workers=0)
    loop = asyncio.new_event_loop()

    def serve():
        asyncio.set_event_loop(loop)
        loop.run_until_complete(srv.start())
        loop.run_forever()

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    for _ in range(200):
        if srv.port:
            break
        time.sleep(0.05)
    assert srv.port, "server did not come up"

    res = client.generate("127.0.0.1", srv.port, [5, 3, 8, 1, 9, 2], 5,
                          seed=11)
    assert len(res["tokens"]) == 5
    assert res["n_streamed"] == 5           # one SSE event per token
    assert res["tokens"] == orch.streams[res["rid"]].tokens

    with pytest.raises(client.HTTPError) as ei:
        client.generate("127.0.0.1", srv.port, [], 4)
    assert ei.value.status == 400
    assert ei.value.body["error"] == "empty_prompt"

    health = client.get_json("127.0.0.1", srv.port, "/healthz")
    assert health["ok"] and health["live_replicas"] == 1
    metrics = client.get_text("127.0.0.1", srv.port, "/metrics")
    assert "frontend_ttft_seconds" in metrics
    assert 'worker="0"' in metrics

    srv._stop.set()                         # stop the stepper thread
    loop.call_soon_threadsafe(loop.stop)


# ---------------------------------------------------------------------------
# spawned workers: engine-API over real pipes + death mid-decode
# ---------------------------------------------------------------------------

def test_worker_processes_and_death_failover():
    """One spawn session, three claims: (1) tokens through two worker
    processes are bit-identical to the in-process baseline; (2) the
    merged /metrics scrape carries per-worker series; (3) after one
    worker is hard-killed mid-decode its streams finish on the survivor
    — every stream, unaffected ones included, still bit-identical."""
    from repro.frontend.worker import LocalReplica, ProcReplica

    spec = _spec()
    reqs = _mixed_requests(n=6, gen=5)

    base = Orchestrator([LocalReplica(0, spec)])
    want = [base.run()[r] for r in _submit_all(base, reqs)]

    orch = Orchestrator([ProcReplica(0, spec), ProcReplica(1, spec)])
    try:
        rids = _submit_all(orch, reqs)
        # both replicas took work (router spreads by load)
        assert {orch.streams[r].replica for r in rids} == {0, 1}
        out = orch.run()
        assert [out[r] for r in rids] == want
        merged = orch.metrics_text()
        assert 'worker="0"' in merged and 'worker="1"' in merged
        assert "engine_steps_total" in merged

        # round 2: kill one worker mid-decode
        rids2 = _submit_all(orch, reqs)
        for _ in range(2):
            orch.step()
        victim = next(i for i in (0, 1)
                      if any(orch.streams[r].replica == i
                             and not orch.streams[r].done for r in rids2))
        orch.replicas[victim].kill()
        out2 = orch.run()
        assert [out2[r] for r in rids2] == want
        assert orch.registry.get("frontend_failovers_total").value() >= 1
        assert len(orch.live()) == 1
    finally:
        orch.shutdown(drain=False)
    assert all(not r.proc.is_alive() for r in orch.replicas)
