"""Unit tests for repro.obs: registry label semantics, fixed-bucket
histogram quantiles, Prometheus render/parse round-trip, and span
nesting/ordering — including under two engines' interleaved steps on the
single-device mesh (the commlog measured-vs-analytical check needs 8
devices and runs as the ``commlog_c2`` batch in test_system.py)."""

import json

import pytest

from repro import obs


# ---------------------------------------------------------------------------
# counters / gauges: label semantics
# ---------------------------------------------------------------------------

def test_counter_label_series_are_independent():
    reg = obs.Registry()
    c = reg.counter("reqs_total", "requests")
    c.inc(replica="0")
    c.inc(2, replica="1")
    c.inc(replica="0", kind="long")
    assert c.value(replica="0") == 1            # exact-match read
    assert c.value(replica="1") == 2
    assert c.value(replica="0", kind="long") == 1
    assert c.value(replica="2") == 0            # never-touched series
    assert c.sum(replica="0") == 2              # superset match
    assert c.sum() == 4
    assert set(c.series(replica="0")) == {
        (("replica", "0"),), (("kind", "long"), ("replica", "0"))}
    with pytest.raises(ValueError):
        c.inc(-1)
    c.reset(replica="0")                        # drops both replica=0 series
    assert c.sum() == 2


def test_gauge_ops_and_registry_lookup():
    reg = obs.Registry()
    g = reg.gauge("depth", "queue depth")
    g.set(3, q="a")
    g.inc(2, q="a")
    g.dec(q="a")
    g.max(10, q="a")
    g.max(4, q="a")                              # lower value: no-op
    assert g.value(q="a") == 10
    assert reg.gauge("depth") is g               # get-or-create
    assert reg.value("depth", q="a") == 10
    with pytest.raises(ValueError):
        reg.counter("depth")                     # kind mismatch


def test_scope_contextvar_nesting():
    assert obs.current_scope() == "global"
    with obs.scope("outer"):
        assert obs.current_scope() == "outer"
        with obs.scope("inner"):
            assert obs.current_scope() == "inner"
        assert obs.current_scope() == "outer"
    assert obs.current_scope() == "global"


# ---------------------------------------------------------------------------
# histograms: fixed-bucket quantiles
# ---------------------------------------------------------------------------

def test_histogram_quantiles_on_fixed_buckets():
    reg = obs.Registry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 0.2, 0.4))
    for _ in range(10):
        h.observe(0.15)
    # all mass in (0.1, 0.2]: linear interpolation inside that bucket
    assert h.quantile(0.5) == pytest.approx(0.15)
    assert h.quantile(0.99) == pytest.approx(0.199)
    h.reset()
    for _ in range(5):
        h.observe(0.05)                          # (0, 0.1]
    for _ in range(5):
        h.observe(0.3)                           # (0.2, 0.4]
    assert h.count() == 10
    assert h.bucket_counts() == [5, 0, 5, 0]
    assert h.quantile(0.5) == pytest.approx(0.1)   # exactly at bucket edge
    assert h.quantile(0.95) == pytest.approx(0.38)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_overflow_clamps_to_last_finite_bound():
    reg = obs.Registry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 0.2, 0.4))
    h.observe(100.0)
    assert h.bucket_counts() == [0, 0, 0, 1]
    assert h.quantile(0.5) == pytest.approx(0.4)   # +Inf bucket lower bound


def test_histogram_labels_aggregate_like_counters():
    reg = obs.Registry()
    h = reg.histogram("ttft", "", buckets=obs.TTFT_BUCKETS)
    h.observe(0.02, replica="0")
    h.observe(0.02, replica="1")
    assert h.count(replica="0") == 1
    assert h.count() == 2                        # no filter: all replicas
    assert h.quantile(0.5) == h.quantile(0.5, replica="0")
    with pytest.raises(ValueError):
        reg.histogram("ttft", buckets=(1.0, 2.0))  # conflicting buckets


# ---------------------------------------------------------------------------
# Prometheus exposition round-trip
# ---------------------------------------------------------------------------

def test_prometheus_render_parse_round_trip():
    reg = obs.Registry()
    reg.counter("a_total", "a help").inc(3, entry='we"ird\nname',
                                         path="a\\b")
    reg.gauge("b").set(2.5, x="1")
    h = reg.histogram("c_seconds", "hist", buckets=(0.5, 1.0))
    h.observe(0.3)
    h.observe(2.0)
    text = reg.render_prometheus()
    assert "# TYPE a_total counter" in text
    assert "# TYPE c_seconds histogram" in text
    parsed = obs.parse_prometheus(text)
    key = (("entry", 'we"ird\nname'), ("path", "a\\b"))
    assert parsed[("a_total", key)] == 3
    assert parsed[("b", (("x", "1"),))] == 2.5
    # histogram samples: cumulative buckets + sum + count
    assert parsed[("c_seconds_bucket", (("le", "0.5"),))] == 1
    assert parsed[("c_seconds_bucket", (("le", "1"),))] == 1
    assert parsed[("c_seconds_bucket", (("le", "+Inf"),))] == 2
    assert parsed[("c_seconds_sum", ())] == pytest.approx(2.3)
    assert parsed[("c_seconds_count", ())] == 2


def test_registry_json_dump(tmp_path):
    reg = obs.Registry()
    reg.counter("a_total").inc(7, k="v")
    p = tmp_path / "m.json"
    reg.dump(str(p), fmt="json")
    d = json.loads(p.read_text())
    assert d["a_total"]["kind"] == "counter"
    assert d["a_total"]["series"] == [{"labels": {"k": "v"}, "value": 7.0}]


# ---------------------------------------------------------------------------
# tracer: spans, nesting, async pairs, disabled no-op
# ---------------------------------------------------------------------------

def test_tracer_span_nesting_and_ordering():
    tr = obs.Tracer(enabled=True)
    with tr.span("outer", cat="t"):
        with tr.span("inner", cat="t", k=1):
            pass
    with tr.span("later", cat="t"):
        pass
    ev = {e["name"]: e for e in tr.events()}
    inner, outer, later = ev["inner"], ev["outer"], ev["later"]
    assert inner["ph"] == outer["ph"] == "X"
    assert inner["args"]["k"] == 1
    # containment: inner lies within outer; later starts after outer ends
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["ts"] + outer["dur"] <= later["ts"]
    body = tr.chrome_trace()
    assert json.loads(json.dumps(body))["traceEvents"] == tr.events()


def test_tracer_async_pairs_and_instant():
    tr = obs.Tracer(enabled=True)
    sid = tr.async_begin("request", uid="r0")
    tr.instant("tick")
    tr.async_end("request", sid, tokens=3)
    phs = [e["ph"] for e in tr.events()]
    assert phs == ["b", "i", "e"]
    b, _, e = tr.events()
    assert b["id"] == e["id"] == sid
    assert b["ts"] <= e["ts"]


def test_disabled_tracer_is_a_noop():
    tr = obs.NULL_TRACER
    with tr.span("x"):
        pass
    assert tr.async_begin("r") is None
    tr.async_end("r", None)
    tr.instant("i")
    assert tr.events() == []


# ---------------------------------------------------------------------------
# spans under interleaved engine steps (single-device mesh)
# ---------------------------------------------------------------------------

def test_span_nesting_under_interleaved_engine_steps():
    import numpy as np

    from repro.engine import EngineConfig, Request, build_engine

    tracer = obs.Tracer(enabled=True)
    ecfg = EngineConfig(max_slots=2, page_size=4, pages_per_shard=32,
                        max_len=64)
    eng_a = build_engine("h2o-danube-1.8b", smoke=True, c=1, data=1,
                         eng=ecfg, tracer=tracer)
    eng_b = build_engine("h2o-danube-1.8b", smoke=True, c=1, data=1,
                         eng=ecfg, params=eng_a.params, tracer=tracer)
    rng = np.random.default_rng(3)
    vocab = eng_a.cfg.vocab_size
    for i, eng in enumerate((eng_a, eng_b)):
        for j in range(2):
            eng.add_request(Request(
                uid=f"e{i}r{j}", tokens=rng.integers(0, vocab, 5).tolist(),
                max_new_tokens=3))
    while not (eng_a.idle() and eng_b.idle()):   # interleave the engines
        for eng in (eng_a, eng_b):
            if not eng.idle():
                eng.step()

    events = tracer.events()
    steps = [e for e in events if e["name"] == "engine/step"]
    inner = [e for e in events
             if e["name"] in ("engine/prefill", "engine/decode",
                              "engine/prefill_chunk")]
    assert {e["args"]["scope"] for e in steps} == \
        {eng_a.obs_scope, eng_b.obs_scope}
    # every inner phase span is contained in exactly one step span
    for e in inner:
        owners = [s for s in steps
                  if s["ts"] <= e["ts"]
                  and e["ts"] + e["dur"] <= s["ts"] + s["dur"]]
        assert len(owners) == 1, (e["name"], len(owners))
    # step spans never overlap (one thread drives both engines), and the
    # interleave shows up as alternating scopes in ts order
    steps.sort(key=lambda s: s["ts"])
    for prev, cur in zip(steps, steps[1:]):
        assert prev["ts"] + prev["dur"] <= cur["ts"]
    scopes = [s["args"]["scope"] for s in steps]
    assert any(a != b for a, b in zip(scopes, scopes[1:]))
    # request lifecycle: one async begin/end pair per request, b before e
    asyncs = [e for e in events if e["ph"] in ("b", "e")]
    by_id = {}
    for e in asyncs:
        by_id.setdefault(e["id"], []).append(e)
    uids = set()
    assert len(by_id) == 4
    for pair in by_id.values():
        assert [e["ph"] for e in pair] == ["b", "e"]
        assert pair[0]["ts"] <= pair[1]["ts"]
        uids.add(pair[0]["args"]["uid"])
    assert uids == {"e0r0", "e0r1", "e1r0", "e1r1"}
