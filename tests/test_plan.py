"""Plan-layer tests: cost-model properties, plan validation, mesh errors.

Multi-device behaviour (plans lowering train steps, microbatch equivalence,
scheme cross-checks) runs in the `plan_and_microbatch` subprocess batch of
tests/test_system.py; everything here is single-device / pure python.
"""

import dataclasses as dc
import json

import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, ShapeConfig
from repro.core.topology import valid_c_values
from repro.dist import meshes
from repro.plan import ExecutionPlan, cost, make_plan, plan_path

ALL_ARCHS = list(registry.ASSIGNED_ARCHS)
PROD_SP = 16   # the production 16x16 mesh's model-axis width


def _shapes_for(cfg):
    return [s for s in SHAPES.values()
            if registry.shape_supported(cfg, s)[0]]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_ring_volume_saving_matches_paper_claims(arch):
    """The analytical P2P volumes reproduce benchmarks/comm_volume.py's
    claims: StarTrail-C saves (C-1)/C of Ring's per-device permute bytes
    (~50% at C=2, ~75% at C=4) for every registered config and shape."""
    cfg = registry.get(arch)
    for shape in _shapes_for(cfg):
        ring = cost.comm_volumes(cfg, shape, PROD_SP,
                                 cost.Arrangement("ring", 1, PROD_SP))
        assert ring["team_allgather"] == 0 and ring["combine_rs"] == 0
        for c in (2, 4):
            arr = cost.Arrangement("startrail", c, PROD_SP // (c * c))
            vols = cost.comm_volumes(cfg, shape, PROD_SP, arr)
            saving = 1 - vols["ring_p2p"] / ring["ring_p2p"]
            assert saving == pytest.approx(1 - 1 / c, rel=1e-9), (
                arch, shape.name, c, saving)
            # the team collectives StarTrail pays for the saving are real
            assert vols["team_allgather"] > 0 and vols["combine_rs"] > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_ranking_emits_constructible_plans(arch):
    """Every arrangement the cost model emits turns into an ExecutionPlan
    that validates: the mesh grid refines, shapes divide, Ulysses only
    appears where head counts allow."""
    cfg = registry.get(arch)
    for shape in _shapes_for(cfg):
        ranking = cost.rank_arrangements(cfg, shape, PROD_SP, batch=1)
        keys = [e["arrangement"].key for e in ranking]
        assert len(keys) == len(set(keys)) and ranking
        assert ("ulysses" in keys) == cost.ulysses_supported(cfg, PROD_SP)
        assert [e["total_s"] for e in ranking] == sorted(
            e["total_s"] for e in ranking)
        for e in ranking:
            arr = e["arrangement"]
            plan = make_plan(
                cfg, shape, arch=arch, n_devices=256, data=PROD_SP,
                scheme=arr.scheme, c=arr.c,
                placement=arr.placement if arr.c > 1 else None,
                mesh_kind="production")
            assert plan.sp_size == PROD_SP
            assert plan.c * plan.c * plan.r == PROD_SP
            assert plan.seq_len % plan.sp_size == 0
            # pure-array mesh refinement (no jax device state)
            grid = meshes.refine_grid(
                np.arange(PROD_SP).reshape(1, PROD_SP), plan.c,
                plan.placement)
            assert grid.shape == (1, plan.c, plan.r, plan.c)
            assert sorted(grid.reshape(-1)) == list(range(PROD_SP))


def test_valid_c_values_cover_factorisations():
    for p in (4, 8, 16, 256):
        for c in valid_c_values(p):
            assert p % (c * c) == 0
        arrs = cost.enumerate_arrangements(registry.get("minitron-8b"), p)
        assert {a.c for a in arrs if a.scheme != "ulysses"} == \
            set(valid_c_values(p))


def test_ulysses_rejected_for_low_kv():
    cfg = registry.get("paligemma-3b")      # kv heads = 1
    shape = SHAPES["train_4k"]
    with pytest.raises(ValueError, match="head counts divisible"):
        make_plan(cfg, shape, n_devices=256, data=16, scheme="ulysses",
                  mesh_kind="production")
    arrs = cost.enumerate_arrangements(cfg, PROD_SP)
    assert all(a.scheme != "ulysses" for a in arrs)


def test_explicit_knobs_and_validation_errors():
    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("smoke", seq_len=64, global_batch=4, kind="train")
    plan = make_plan(cfg, shape, n_devices=8, data=2, c=1)
    assert plan.scheme == "ring" and plan.r == 4
    with pytest.raises(ValueError, match="no legal arrangement"):
        make_plan(cfg, shape, n_devices=8, data=2, c=3)
    with pytest.raises(ValueError, match="C=2"):
        ExecutionPlan(arch="x", shape="s", seq_len=64, global_batch=4,
                      n_devices=8, data=4, c=2)      # P=2, C^2=4
    with pytest.raises(ValueError, match="zigzag"):
        ExecutionPlan(arch="x", shape="s", seq_len=8, global_batch=4,
                      n_devices=8, data=1, c=1)      # 8 % (2*8) != 0
    with pytest.raises(ValueError, match="microbatches"):
        ExecutionPlan(arch="x", shape="s", seq_len=64, global_batch=4,
                      n_devices=8, data=2, c=1, microbatches=3)
    with pytest.raises(ValueError, match="implies C=1"):
        ExecutionPlan(arch="x", shape="s", seq_len=64, global_batch=8,
                      n_devices=8, data=2, c=2, scheme="ulysses")


def test_plan_roundtrip_and_path(tmp_path):
    cfg = registry.get("minitron-8b")
    plan = make_plan(cfg, SHAPES["train_4k"], arch="minitron-8b",
                     n_devices=256, data=16, mesh_kind="production")
    p = plan.save(tmp_path / "PLAN_x.json")
    loaded = ExecutionPlan.load(p)
    assert loaded == plan
    rec = json.loads(p.read_text())
    assert rec["plan"]["sp_size"] == 16      # derived fields recorded
    assert plan_path(tmp_path, "a", "s").name == "PLAN_a_s.json"


def test_microbatch_selection():
    """Auto microbatching divides the per-device batch; the big archs need
    accumulation for train_4k's global_batch=256 (the 'honest' case)."""
    shape = SHAPES["train_4k"]
    for arch in ALL_ARCHS:
        cfg = registry.get(arch)
        m = cost.choose_microbatches(cfg, shape, dp=16, sp=16, c=2)
        assert (shape.global_batch // 16) % m == 0
    big = cost.choose_microbatches(
        registry.get("jamba-1.5-large-398b"), shape, dp=16, sp=16, c=2)
    assert big > 1
    plan = make_plan(registry.get("jamba-1.5-large-398b"), shape,
                     n_devices=256, data=16, mesh_kind="production")
    assert plan.microbatches == big


def test_production_mesh_error_lists_refinable_grids():
    """With too few devices the mesh error enumerates legal (data, model)
    grids instead of a silent jax shape mismatch (satellite acceptance)."""
    import jax

    from repro.launch import mesh as mesh_lib

    assert jax.device_count() < 256   # tier-1 session runs single-device
    with pytest.raises(ValueError) as ei:
        mesh_lib.make_production_mesh()
    msg = str(ei.value)
    assert "256 devices" in msg and "--smoke" in msg
    assert mesh_lib.refinable_grids(8) == [(2, 4), (1, 8)]
    assert all(d * m == 64 and m % 4 == 0
               for d, m in mesh_lib.refinable_grids(64))


def test_run_config_reflects_plan():
    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
    plan = make_plan(cfg, shape, n_devices=8, data=2, c=2,
                     microbatches=2)
    rc = plan.run_config()
    assert rc.c == 2 and rc.microbatches == 2
    assert rc.attention_scheme == plan.scheme
    assert rc.seq_scheme == plan.seq_scheme
    plan_ssm = make_plan(registry.get_smoke("xlstm-1.3b"), shape,
                         n_devices=8, data=2)
    assert plan_ssm.seq_scheme == "contiguous"


# ---------------------------------------------------------------------------
# serving face (kind='decode' plans for repro.engine — see docs/SERVING.md)
# ---------------------------------------------------------------------------

def test_make_serve_plan_and_roundtrip(tmp_path):
    from repro.plan import make_serve_plan

    cfg = registry.get_smoke("h2o-danube-1.8b")
    plan = make_serve_plan(cfg, arch="h2o-danube-1.8b", n_devices=8,
                           data=1, c=2, decode_batch=4, page_size=8,
                           max_len=100)
    assert plan.kind == "decode" and plan.scheme == "startrail"
    assert plan.decode_batch == 4 and plan.page_size == 8
    # capacity padded so both SP and the page size divide it
    assert plan.seq_len >= 100
    assert plan.seq_len % plan.sp_size == 0
    assert plan.seq_len % plan.page_size == 0
    assert plan.seq_scheme == "contiguous"
    p = plan.save(tmp_path / "PLAN_serve.json")
    assert ExecutionPlan.load(p) == plan
    rc = plan.run_config()
    assert rc.kernel_impl == plan.kernel_impl


def test_impls_default_to_backend():
    """make_plan's unset block_impl/kernel_impl follow the backend: 'ref'
    on CPU (this session), 'pallas' on TPU (satellite acceptance — the
    hardcoded "ref" default is gone)."""
    import jax

    from repro.kernels import dispatch
    from repro.plan import make_serve_plan

    assert jax.default_backend() == "cpu"
    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("smoke", seq_len=64, global_batch=4, kind="train")
    plan = make_plan(cfg, shape, n_devices=8, data=2)
    assert plan.block_impl == dispatch.resolve_impl(None) == "ref"
    splan = make_serve_plan(cfg, n_devices=8, decode_batch=2, page_size=4,
                            max_len=64)
    assert splan.kernel_impl == "ref"
    # explicit knobs pass through and are validated
    plan = make_plan(cfg, shape, n_devices=8, data=2, block_impl="pallas",
                     kernel_impl="pallas")
    assert plan.block_impl == "pallas" and plan.kernel_impl == "pallas"
    with pytest.raises(ValueError, match="impl"):
        make_plan(cfg, shape, n_devices=8, data=2, block_impl="cuda")


def test_serve_plan_validation():
    from repro.plan import make_serve_plan

    cfg = registry.get_smoke("h2o-danube-1.8b")
    with pytest.raises(ValueError, match="decode_batch"):
        make_serve_plan(cfg, n_devices=8, decode_batch=0, page_size=4)
    with pytest.raises(ValueError, match="page_size"):
        make_serve_plan(cfg, n_devices=8, decode_batch=2, page_size=0)
    with pytest.raises(ValueError, match="kernel_impl"):
        ExecutionPlan(arch="x", shape="s", seq_len=64, global_batch=4,
                      n_devices=8, data=2, c=1, kind="decode",
                      seq_scheme="contiguous", kernel_impl="cuda")
    with pytest.raises(ValueError, match="page_size"):
        ExecutionPlan(arch="x", shape="s", seq_len=60, global_batch=4,
                      n_devices=4, data=2, c=1, kind="decode",
                      seq_scheme="contiguous", page_size=8,
                      decode_batch=2)   # 60 % 8 != 0


def test_decode_kernel_cost_model():
    """The paged kernel strictly beats the gather path on bytes (it skips
    the dense cache copy); flops are identical."""
    cfg = registry.get("h2o-danube-1.8b")
    kw = dict(batch=8, cache_len=4096, sp=16, page_size=16)
    ref_c = cost.decode_step_cost(cfg, kernel="ref", **kw)
    pal_c = cost.decode_step_cost(cfg, kernel="pallas", **kw)
    assert pal_c["flops"] == ref_c["flops"]
    assert pal_c["bytes"] < ref_c["bytes"]
    ranked = cost.rank_decode_kernels(cfg, **kw)
    assert ranked[0]["kernel"] == "pallas"
    with pytest.raises(ValueError, match="kernel"):
        cost.decode_step_cost(cfg, kernel="cuda", **kw)


# ---------------------------------------------------------------------------
# pipelined ring scan knobs (pipeline_scan / comm_chunks) + overlap model
# ---------------------------------------------------------------------------

def test_pipeline_knobs_roundtrip_and_validation(tmp_path):
    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
    plan = make_plan(cfg, shape, n_devices=8, data=1, c=2,
                     pipeline_scan=False, comm_chunks=2)
    assert plan.pipeline_scan is False and plan.comm_chunks == 2
    rc = plan.run_config()
    assert rc.pipeline_scan is False and rc.comm_chunks == 2
    loaded = ExecutionPlan.load(plan.save(tmp_path / "p.json"))
    assert loaded == plan
    # defaults: pipelined, unchunked
    plan_d = make_plan(cfg, shape, n_devices=8, data=1, c=2)
    assert plan_d.pipeline_scan is True and plan_d.comm_chunks >= 1
    assert plan_d.run_config().pipeline_scan is True
    # comm_chunks must divide the team sequence length C*N/P
    with pytest.raises(ValueError, match="comm_chunks"):
        ExecutionPlan(arch="x", shape="s", seq_len=64, global_batch=8,
                      n_devices=8, data=1, c=2, comm_chunks=3)  # 16 % 3
    with pytest.raises(ValueError, match="comm_chunks"):
        ExecutionPlan(arch="x", shape="s", seq_len=64, global_batch=8,
                      n_devices=8, data=1, c=2, comm_chunks=0)


def test_overlap_model_properties():
    """attention_step_cost's measured-overlap parameterization: perfect
    hiding is never slower than none; chunk latency is monotone; chunking
    helps exactly when the exposed wire dominates the added latency."""
    from repro.core import scheduler as sch

    w = sch.AttnWorkload(batch=1, seq_len=65536, num_heads=16,
                         num_kv_heads=4, head_dim=128)
    cl = sch.ClusterModel(sp_size=16)

    t_perfect = sch.attention_step_cost(w, cl, 2, "team_inner")["total_s"]
    t_none = sch.attention_step_cost(
        w, cl, 2, "team_inner", overlap_frac=0.0)["total_s"]
    assert t_none >= t_perfect
    # monotone in f
    ts = [sch.attention_step_cost(w, cl, 2, "team_inner",
                                  overlap_frac=f)["total_s"]
          for f in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert ts == sorted(ts, reverse=True)

    # f=0 (nothing hides): chunking pipelines the exposed wire -> faster,
    # so the chooser picks the largest grid entry
    n = sch.choose_comm_chunks(w, cl, 2, "team_inner", overlap_frac=0.0,
                               grid=(1, 2, 4))
    assert n == 4
    # f=1 (everything hides): chunks only add latency -> 1 wins
    n = sch.choose_comm_chunks(w, cl, 2, "team_inner", overlap_frac=1.0,
                               grid=(1, 2, 4))
    assert n == 1
    # latency-bound regime: huge per-message latency kills chunking even
    # with nothing hidden
    cl_lat = dc.replace(cl, step_latency=1.0)
    n = sch.choose_comm_chunks(w, cl_lat, 2, "team_inner",
                               overlap_frac=0.0, grid=(1, 2, 4))
    assert n == 1

    with pytest.raises(ValueError, match="overlap_frac"):
        sch.attention_step_cost(w, cl, 2, "team_inner", overlap_frac=1.5)
    with pytest.raises(ValueError, match="comm_chunks"):
        sch.attention_step_cost(w, cl, 2, "team_inner", comm_chunks=0)


def test_cost_choose_comm_chunks():
    """Plan-level resolution: non-ring schemes -> 1; the grid is filtered
    to divisors of the team sequence length; make_plan(comm_chunks=None)
    uses the resolved value."""
    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
    ul = cost.Arrangement("ulysses", 1, 8)
    assert cost.choose_comm_chunks(cfg, shape, 8, ul) == 1
    st2 = cost.Arrangement("startrail", 2, 2)
    # s_team = 2*64/8 = 16: every grid entry legal; perfect overlap -> 1
    assert cost.choose_comm_chunks(cfg, shape, 8, st2) == 1
    # zero measured overlap -> largest legal chunk count wins on this
    # bandwidth-bound shape
    big = ShapeConfig("big", seq_len=65536, global_batch=8, kind="train")
    assert cost.choose_comm_chunks(cfg, big, 8, st2,
                                   overlap_frac=0.0) == 4
    # grid entries that do not divide s_team are dropped (s_team=16 here,
    # grid entry 5 illegal, 2 legal)
    assert cost.choose_comm_chunks(cfg, shape, 8, st2, overlap_frac=0.0,
                                   grid=(5, 2)) == 2
    plan = make_plan(cfg, shape, n_devices=8, data=1, c=2,
                     comm_chunks=None)
    assert plan.comm_chunks == cost.choose_comm_chunks(
        cfg, shape, 8, cost.Arrangement("startrail", 2, 2,
                                        placement=plan.placement))
