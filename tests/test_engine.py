"""Unit tests for the serving engine: scheduler/page accounting (pure
host-side), vocab-parallel sampling in local mode, and a single-device
end-to-end engine run (the SP=1 degenerate mesh — everything still goes
through shard_map, paging and bucketed compilation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.startrail import StarTrailConfig
from repro.engine import Request, Scheduler, bucket_pow2
from repro.engine import sampling as sampling_lib
from repro.models.runtime import Runtime


# ---------------------------------------------------------------------------
# scheduler / paging (host-side, no devices)
# ---------------------------------------------------------------------------

def _sched(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("sp", 4)
    kw.setdefault("pages_per_shard", 8)
    kw.setdefault("max_len", 64)
    return Scheduler(**kw)


def test_bucket_pow2():
    assert bucket_pow2(1) == 1
    assert bucket_pow2(3) == 4
    assert bucket_pow2(4) == 4
    assert bucket_pow2(9, lo=8) == 16


def test_round_robin_allocation():
    s = _sched()
    s.enqueue(Request("a", list(range(10)), 6))  # 16 positions -> 4 blocks
    [st] = s.admit(step=0)
    assert st.slot == 0
    # block b -> shard b % sp, local index b // sp
    shards = [sh for sh, _ in st.pages]
    assert shards == [0, 1, 2, 3]
    assert all(s.table[0, sh, 0] >= 0 for sh in range(4))
    assert s.pages_in_use() == 4
    s.finish(0, step=1)
    assert s.pages_in_use() == 0
    assert (s.table == -1).all()


def test_fifo_admission_and_slot_reuse():
    s = _sched()
    for uid in "abc":
        s.enqueue(Request(uid, [1, 2, 3], 5))   # 8 positions -> 2 blocks
    admitted = s.admit(step=0)
    assert [st.req.uid for st in admitted] == ["a", "b"]  # 2 slots
    assert s.admit(step=0) == []                          # no free slot
    s.finish(admitted[0].slot, step=3)
    [st_c] = s.admit(step=3)
    assert st_c.req.uid == "c" and st_c.slot == admitted[0].slot


def test_head_of_line_blocking_on_pages():
    s = _sched(pages_per_shard=2)                # 8 pages total
    s.enqueue(Request("big", list(range(20)), 12))   # 32 pos -> 8 blocks
    s.enqueue(Request("small", [1], 1))
    [st] = s.admit(step=0)
    assert st.req.uid == "big"
    # FIFO: nothing fits behind the (now empty) pool; small waits
    assert s.admit(step=0) == []
    s.finish(st.slot, step=1)
    assert [x.req.uid for x in s.admit(step=1)] == ["small"]


def test_enqueue_validation():
    s = _sched()
    with pytest.raises(ValueError):
        s.enqueue(Request("x", [], 4))
    with pytest.raises(ValueError):
        s.enqueue(Request("x", [1], 0))
    with pytest.raises(ValueError):
        s.enqueue(Request("x", [1] * 60, 10))    # exceeds max_len=64


def test_decode_width_buckets():
    s = _sched()
    s.enqueue(Request("a", [1] * 10, 30))        # up to 40 positions
    [st] = s.admit(step=0)
    st.cache_len = 10
    assert s.decode_width() == 1                 # 3 blocks over sp=4
    st.cache_len = 17                            # 5 blocks -> ceil(5/4)=2
    assert s.decode_width() == 2
    st.cache_len = 39                            # 10 blocks -> ceil=3 -> pow2
    assert s.decode_width() == 4


# ---------------------------------------------------------------------------
# sampling (local mode: full-vocab slice on one shard)
# ---------------------------------------------------------------------------

def _local_rt():
    return Runtime(mode="local",
                   st_cfg=StarTrailConfig(seq_len=8, seq_scheme="contiguous"))


def _sampling_fixture():
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(name="s", family="dense", num_layers=1, d_model=4,
                      num_heads=1, num_kv_heads=1, d_ff=8, vocab_size=64)
    rng = np.random.default_rng(0)
    table = np.zeros((64, 4), np.float32)
    table[:, 0] = rng.normal(size=64).astype(np.float32)
    x = np.zeros((1, 1, 4), np.float32)
    x[0, 0, 0] = 1.0                             # logits_v == table[v, 0]
    return cfg, jnp.asarray(table), jnp.asarray(x), table[:, 0].astype(float)


def test_greedy_matches_argmax_local():
    cfg, table, x, full = _sampling_fixture()
    tok = sampling_lib.greedy(_local_rt(), {"table": table}, x, cfg)
    assert int(tok[0, 0]) == int(np.argmax(full))


def _draw(cfg, table, x, temp, top_k, top_p, fold):
    keys = jax.random.fold_in(jax.random.PRNGKey(0), fold)[None]
    tok = sampling_lib.sample(
        _local_rt(), {"table": table}, x, cfg,
        temperature=jnp.full((1,), temp, jnp.float32),
        top_k=jnp.full((1,), top_k, jnp.int32),
        top_p=jnp.full((1,), top_p, jnp.float32), keys=keys)
    return int(tok[0, 0])


def test_top_k_membership_and_determinism():
    cfg, table, x, full = _sampling_fixture()
    allowed = set(np.argsort(full)[-8:].tolist())
    seen = {_draw(cfg, table, x, 1.0, 8, 1.0, i) for i in range(24)}
    assert seen <= allowed
    assert len(seen) > 1
    assert _draw(cfg, table, x, 0.9, 8, 0.9, 5) == \
        _draw(cfg, table, x, 0.9, 8, 0.9, 5)


def test_top_p_membership():
    cfg, table, x, full = _sampling_fixture()
    probs = np.exp(full - full.max())
    probs /= probs.sum()
    order = np.argsort(-probs)
    csum = np.cumsum(probs[order])
    nucleus = set(order[:int(np.searchsorted(csum, 0.4) + 1)].tolist())
    seen = {_draw(cfg, table, x, 1.0, 0, 0.4, i) for i in range(24)}
    assert seen <= nucleus


def test_zero_temperature_rows_are_greedy():
    cfg, table, x, full = _sampling_fixture()
    for i in range(4):
        assert _draw(cfg, table, x, 0.0, 0, 1.0, i) == int(np.argmax(full))


# ---------------------------------------------------------------------------
# engine end-to-end on the single-device (SP=1) mesh
# ---------------------------------------------------------------------------

def test_engine_single_device_end_to_end():
    from repro.engine import EngineConfig, build_engine

    eng = build_engine("h2o-danube-1.8b", smoke=True, c=1, data=1,
                       eng=EngineConfig(max_slots=2, page_size=4,
                                        pages_per_shard=32, max_len=64))
    rng = np.random.default_rng(0)
    vocab = eng.cfg.vocab_size
    reqs = [
        Request("g", rng.integers(0, vocab, 5).tolist(), 4),
        Request("s", rng.integers(0, vocab, 11).tolist(), 5,
                temperature=0.8, top_k=8, top_p=0.9, seed=3),
        Request("late", rng.integers(0, vocab, 3).tolist(), 3),
    ]
    eng.add_request(reqs[0])
    eng.add_request(reqs[1])
    eng.step()
    eng.add_request(reqs[2])                     # joins the running batch
    out = eng.run()
    assert sorted(out) == ["g", "late", "s"]
    assert [len(out[r.uid]) for r in reqs] == [4, 5, 3]
    assert all(0 <= t < vocab for toks in out.values() for t in toks)
    # batched == solo (solo short requests may touch smaller width buckets,
    # so compile counts are compared on a replay of the same workload)
    for r in reqs:
        eng.reset()
        eng.add_request(r)
        assert eng.run()[r.uid] == out[r.uid], f"{r.uid} diverged solo"

    pc, dc = eng.metrics.prefill_compiles, eng.metrics.decode_compiles
    eng.reset()
    eng.add_request(reqs[0])
    eng.add_request(reqs[1])
    eng.step()
    eng.add_request(reqs[2])
    assert eng.run() == out, "replay of the same workload diverged"
    assert (eng.metrics.prefill_compiles, eng.metrics.decode_compiles) == \
        (pc, dc), "recompiled on replay"
    # once-per-bucket, XLA-level: each bucket fn holds exactly one trace
    assert eng.xla_compiles() == (len(eng._prefill_fns),
                                  len(eng._decode_fns))


def test_unserveable_request_rejected_at_enqueue():
    from repro.engine import EngineConfig, Rejection, build_engine

    eng = build_engine("h2o-danube-1.8b", smoke=True, c=1, data=1,
                       eng=EngineConfig(max_slots=1, page_size=4,
                                        pages_per_shard=4, max_len=64))
    # 40 positions -> 10 blocks on the 1-shard pool of 4 pages: would
    # head-of-line block forever; must be rejected up front, as a typed
    # Rejection (permanent: no retry hint) rather than an exception
    rej = eng.add_request(Request("big", [1] * 30, 10))
    assert isinstance(rej, Rejection)
    assert rej.reason == "pool_too_small" and "pages" in rej.detail
    assert not rej.retryable
    assert eng.idle()                       # nothing was enqueued
    # the raw scheduler enqueue keeps its raising contract
    with pytest.raises(ValueError, match="pages"):
        eng.scheduler.enqueue(Request("big", [1] * 30, 10))


# ---------------------------------------------------------------------------
# chunked prefill: schedule fuzzing + per-engine fallback accounting
# ---------------------------------------------------------------------------

_CHUNK_CTX = {}


def _chunk_engine():
    """One shared engine + request pool + monolithic baseline, built once:
    every fuzz example reuses the compile caches and only varies the chunk
    size and arrival order."""
    if not _CHUNK_CTX:
        from repro.engine import EngineConfig, build_engine

        eng = build_engine("h2o-danube-1.8b", smoke=True, c=1, data=1,
                           eng=EngineConfig(max_slots=2, page_size=4,
                                            pages_per_shard=32, max_len=64))
        rng = np.random.default_rng(7)
        vocab = eng.cfg.vocab_size
        reqs = [
            Request("long", rng.integers(0, vocab, 23).tolist(), 3),
            Request("short", rng.integers(0, vocab, 5).tolist(), 6),
            Request("sampled", rng.integers(0, vocab, 17).tolist(), 4,
                    temperature=0.8, top_k=8, top_p=0.9, seed=11),
            Request("mid", rng.integers(0, vocab, 9).tolist(), 5),
        ]
        for r in reqs:
            eng.add_request(r)
        base = eng.run()
        _CHUNK_CTX.update(eng=eng, reqs=reqs, base=base)
    return _CHUNK_CTX


def _run_with_invariants(eng, order):
    """Drive the engine over staggered arrivals, asserting after every step
    that no slot ever holds more pages than its admission reserved."""
    reserved = {}
    pending = list(order)
    steps = 0
    while pending or not eng.idle():
        if pending:
            eng.add_request(pending.pop(0))
        eng.step()
        live = eng.scheduler.active()
        for st in live:
            uid = st.req.uid
            if uid not in reserved:
                reserved[uid] = len(st.pages)
            assert len(st.pages) == reserved[uid], (
                f"{uid}: pages grew after admission "
                f"({reserved[uid]} -> {len(st.pages)})")
        assert eng.scheduler.pages_in_use() <= sum(
            len(st.pages) for st in live), "pool holds unaccounted pages"
        steps += 1
        assert steps < 500, "engine did not drain"
    return eng.collect()


def test_chunked_prefill_schedule_property():
    """Property: any chunk size x any arrival order produces tokens
    bit-identical to the monolithic prefill, without ever exceeding the
    page reservation made at admission (chunks never allocate)."""
    import random as _random

    from hypothesis import given, settings
    from hypothesis import strategies as st

    ctx = _chunk_engine()
    eng, reqs, base = ctx["eng"], ctx["reqs"], ctx["base"]

    @settings(max_examples=8)
    @given(st.sampled_from([0, 4, 8, 16]), st.integers(0, 7))
    def prop(chunk, order_seed):
        eng.reset()
        # the knob the EngineConfig would have set (bucket-rounded)
        eng._chunk = 0 if not chunk else bucket_pow2(
            max(chunk, eng._prefill_base), eng._prefill_base)
        order = list(reqs)
        _random.Random(order_seed).shuffle(order)
        out = _run_with_invariants(eng, order)
        assert out == base, (
            f"chunk={chunk} order_seed={order_seed} diverged from "
            "monolithic prefill")
        if eng._chunk and eng._chunk < 32:
            assert eng.metrics.prefill_chunks > eng.metrics.prefills, \
                "long prompts did not actually split into chunks"

    prop()
    eng._chunk = 0                           # restore for other tests


def test_engine_pallas_fallbacks_per_instance():
    """Regression: each engine must report only the fallbacks traced under
    *its* obs scope (every step runs inside ``obs.scope(engine.obs_scope)``)
    — never history from earlier engines, tests, or scope-less traces.
    The scope-labeled registry counters replaced the process-global
    snapshot-delta arithmetic engines used to carry."""
    from repro import obs
    from repro.kernels import dispatch as kd

    ctx = _chunk_engine()
    eng = ctx["eng"]
    base = eng.pallas_fallbacks()
    # the whole engine suite so far: zero batched-positions prefill
    # fallbacks (the ragged kernel serves that case now)
    assert base.get("block_fwd", 0) == 0 and base.get("prefill", 0) == 0
    other_scope = "test-fallback-attribution"
    try:
        # a fallback traced outside every engine's scope: attributed to
        # its own scope, inherited by no engine
        with obs.scope(other_scope):
            kd._note_fallback("block_bwd")
        assert eng.pallas_fallbacks().get("block_bwd", 0) == \
            base.get("block_bwd", 0)
        assert kd.pallas_fallbacks(scope=other_scope) == {"block_bwd": 1}
        # the process-wide view still sums every scope
        assert kd.pallas_fallbacks().get("block_bwd", 0) >= 1
        # a fallback traced under this engine's scope lands on it alone,
        # with provenance labels (entry/reason/shape/scope) on the series
        with obs.scope(eng.obs_scope):
            kd._note_fallback("block_bwd", reason="batched_positions",
                              shape=(2, 1, 4, 8))
        assert eng.pallas_fallbacks().get("block_bwd", 0) == \
            base.get("block_bwd", 0) + 1
        counter = obs.global_registry().get(kd.FALLBACK_METRIC)
        assert counter.sum(entry="block_bwd", reason="batched_positions",
                           shape="2x1x4x8", scope=eng.obs_scope) == 1
        # fresh engines get fresh scopes -> empty fallback reports
        assert eng.obs_scope != other_scope
    finally:
        # undo the synthetic ticks (scope-targeted reset leaves the rest)
        kd.reset_pallas_fallbacks(scope=other_scope)
        kd.reset_pallas_fallbacks(scope=eng.obs_scope)
