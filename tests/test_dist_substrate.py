"""Substrate tests beyond test_substrate.py: sharding rules over real model
trees, and mesh refinement vs the paper topology's rank layout."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core import topology as topo_lib
from repro.dist import elastic, meshes, sharding
from repro.models.factory import build_model

# the axis sizes the CPU test meshes actually use (see dist_checks /
# launch --smoke) and the production 16x16 pod refined at C=2
MESH_SIZES = {
    "smoke_c2": {"data": 2, "sp_grp": 2, "sp_ring": 1, "sp_team": 2},
    "smoke_c1": {"data": 2, "sp_grp": 1, "sp_ring": 4, "sp_team": 1},
    "prod_c2": {"data": 16, "sp_grp": 2, "sp_ring": 4, "sp_team": 2},
}

RULE_ARCHS = ["h2o-danube-1.8b", "phi3.5-moe-42b-a6.6b",
              "jamba-1.5-large-398b", "xlstm-1.3b", "seamless-m4t-large-v2"]


def _spec_entries(spec):
    """Normalise a PartitionSpec into a per-dim tuple of mesh-axis tuples."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(())
        elif isinstance(entry, (tuple, list)):
            out.append(tuple(entry))
        else:
            out.append((entry,))
    return out


# ---- partition_tree round-trip over real models -----------------------------

@pytest.mark.parametrize("rules", sorted(sharding.RULES))
@pytest.mark.parametrize("arch", RULE_ARCHS)
def test_partition_tree_roundtrip(arch, rules):
    """Every leaf's PartitionSpec matches its logical axes mapped through the
    rule table, with no mesh axis used twice within one spec."""
    import jax

    model = build_model(registry.get_smoke(arch))
    axes_tree = model.axes()
    ptree = model.partition(rules)
    is_axes = lambda x: isinstance(x, tuple)
    axes_leaves = jax.tree.leaves(axes_tree, is_leaf=is_axes)
    spec_leaves = jax.tree.leaves(ptree, is_leaf=lambda x: isinstance(x, P))
    assert len(axes_leaves) == len(spec_leaves) > 0
    table = sharding.RULES[rules]
    for axes, spec in zip(axes_leaves, spec_leaves):
        entries = _spec_entries(spec)
        assert len(entries) == len(axes)
        used = []
        for ax, got in zip(axes, entries):
            expect = tuple(table.get(ax) or ()) if ax is not None else ()
            assert got == expect, (arch, rules, axes, ax, got, expect)
            used.extend(got)
        assert len(used) == len(set(used)), (arch, rules, axes, used)


@pytest.mark.parametrize("rules", sorted(sharding.RULES))
@pytest.mark.parametrize("mesh_name", sorted(MESH_SIZES))
@pytest.mark.parametrize("arch", RULE_ARCHS)
def test_partition_layout_divisible(arch, rules, mesh_name):
    """Sharded dims divide evenly on the meshes we actually run (smoke CPU
    meshes with the full arch set; the production pod with full configs)."""
    import jax

    sizes = MESH_SIZES[mesh_name]
    cfg = (registry.get(arch) if mesh_name.startswith("prod")
           else registry.get_smoke(arch))
    model = build_model(cfg)
    abstract = jax.tree.leaves(model.abstract())
    specs = jax.tree.leaves(model.partition(rules),
                            is_leaf=lambda x: isinstance(x, P))
    for aval, spec in zip(abstract, specs):
        for dim, axes in zip(aval.shape, _spec_entries(spec)):
            shards = int(np.prod([sizes[a] for a in axes], initial=1))
            assert dim % shards == 0, (arch, rules, mesh_name, aval.shape,
                                       spec, dim, shards)


def test_fsdp_logical_subset_of_rules():
    """Gather-on-use axes must be mapped by their rule set (otherwise
    Runtime.dense would silently skip the gather)."""
    for name, table in sharding.RULES.items():
        for ax in sharding.fsdp_logical(name):
            assert table.get(ax), (name, ax)


def test_partition_tree_rejects_axis_reuse():
    with pytest.raises(ValueError):
        sharding.spec_for_axes(("embed", "embed_out"),
                               {"embed": ("data",), "embed_out": ("data",)})


# ---- refine_mesh vs core/topology rank layout -------------------------------

@pytest.mark.parametrize("p,c", [(4, 1), (4, 2), (8, 2), (16, 2), (16, 4),
                                 (64, 4), (256, 2), (256, 4)])
def test_refine_grid_matches_topology_ranks(p, c):
    """Device (g, j, t) in the refined grid is the flat-model-axis device at
    rank ``(g*R + j)*C + t`` — i.e. exactly ``StarTrailTopology.rank`` and
    the ``PartitionSpec(SP_AXES)`` linearisation."""
    topo = topo_lib.StarTrailTopology(p, c)
    grid = meshes.refine_grid(np.arange(p), c, "team_inner")
    assert grid.shape == (c, topo.ring_size, c)
    for g in range(c):
        for j in range(topo.ring_size):
            for t in range(c):
                assert grid[g, j, t] == topo.rank(g, j, t)
                tau = topo.team_of(g, j)
                assert tau == g * topo.ring_size + j


@pytest.mark.parametrize("p,c", [(8, 2), (16, 2), (64, 4)])
def test_refine_grid_ring_inner_adjacency(p, c):
    """ring_inner puts consecutive flat-axis devices along the ring: walking
    j at fixed (g, t) visits adjacent devices (the P2P_intra placement)."""
    r = p // (c * c)
    grid = meshes.refine_grid(np.arange(p), c, "ring_inner")
    assert grid.shape == (c, r, c)
    for g in range(c):
        for t in range(c):
            ring = [int(grid[g, j, t]) for j in range(r)]
            assert all(b - a == 1 for a, b in zip(ring, ring[1:])), ring
    # still a bijection of the flat axis
    assert sorted(grid.reshape(-1).tolist()) == list(range(p))


def test_refine_grid_preserves_leading_axes():
    grid = np.arange(2 * 16).reshape(2, 16)
    out = meshes.refine_grid(grid, 2, "team_inner")
    assert out.shape == (2, 2, 4, 2)
    np.testing.assert_array_equal(out[1].reshape(-1), grid[1])


def test_refine_grid_validates_factorisation():
    with pytest.raises(ValueError):
        meshes.refine_grid(np.arange(8), 3, "team_inner")
    with pytest.raises(ValueError):
        meshes.refine_grid(np.arange(16), 2, "diagonal")


# ---- checkpoint: multi-tree consistency -------------------------------------

def test_latest_common_step_skips_torn_checkpoint(tmp_path):
    """A crash between the params save and the opt save leaves the trees one
    step apart; the restart point must be the newest step present in BOTH."""
    import jax.numpy as jnp

    from repro.dist import checkpoint

    params, opt = {"w": jnp.ones(3)}, {"mu": jnp.zeros(3)}
    opt_dir = tmp_path / "opt"
    checkpoint.save(tmp_path, 1, params)
    checkpoint.save(opt_dir, 1, opt)
    checkpoint.save(tmp_path, 2, params)   # "crash" before opt step 2
    assert checkpoint.latest_step(tmp_path) == 2
    assert checkpoint.latest_common_step(tmp_path, opt_dir) == 1
    # both trees restorable at the common step
    checkpoint.restore(tmp_path, 1, params)
    checkpoint.restore(opt_dir, 1, opt)
    # empty opt tree -> no consistent restore point at all
    assert checkpoint.latest_common_step(tmp_path, tmp_path / "nope") is None
    # diverged step SETS (different cadences across restarts): params
    # {1,2,10}, opt {1,6} -> common step is 1, not min(latest) = 6
    checkpoint.save(tmp_path, 10, params)
    checkpoint.save(opt_dir, 6, opt)
    assert checkpoint.latest_common_step(tmp_path, opt_dir) == 1


def test_async_save_failure_surfaces_at_join(tmp_path):
    """A writer-thread failure must re-raise at join(), not die silently
    (training would otherwise continue checkpoint-less and exit 0)."""
    import jax.numpy as jnp

    from repro.dist import checkpoint

    # a regular file squatting on the staging path makes the writer fail
    (tmp_path / "step_00000005.tmp").write_text("not a dir")
    t = checkpoint.save(tmp_path, 5, {"a": jnp.ones(2)}, blocking=False)
    with pytest.raises(NotADirectoryError):
        t.join()
    assert checkpoint.latest_step(tmp_path) is None


# ---- elastic plan feeds a valid refinement ----------------------------------

@pytest.mark.parametrize("world,target", [
    (512, 16), (511, 16), (509, 16), (256, 16), (48, 16), (12, 16), (8, 16),
    (4, 16),
    (8, 12),    # non-power-of-two target on a small pool -> 8
    (100, 12), (64, 24), (9, 5),
    (5, 12), (4, 12),   # pool below target must still yield model=4, not raise
])
def test_plan_mesh_model_axis_refinable(world, target):
    """Whatever plan_mesh returns for the model axis must admit at least the
    C=2 StarTrail refinement (that is the point of min_model=4)."""
    plan = elastic.plan_mesh(world, model_axis_target=target)
    assert plan.devices <= world
    assert plan.model * plan.data == plan.devices
    assert 2 in topo_lib.valid_c_values(plan.model)
    # and the refined grid is constructible
    grid = meshes.refine_grid(np.arange(plan.model), 2, "team_inner")
    assert grid.size == plan.model
