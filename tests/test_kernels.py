"""Pallas flash-attention + paged-decode kernels vs the pure-jnp oracle
(interpret mode).

Sweeps shapes/dtypes per the kernel-testing contract: every kernel is
asserted allclose against ref.py. Also grep-enforces the dispatch-layer
contract: nothing outside kernels/ imports ref/ops/flash_attention/
paged_decode directly — all attention call sites go through
``kernels.dispatch``.

Runnable standalone (the CI ``kernels-interpret`` step):
    PYTHONPATH=src python -m pytest -x -q tests/test_kernels.py
"""

import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels import ops
from repro.kernels import ref


def _data(key, B, Sq, Sk, Hq, Hkv, D, dtype):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32).astype(dtype)
    do = jax.random.normal(ks[3], (B, Sq, Hq, D), jnp.float32).astype(dtype)
    return q, k, v, do


CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, block
    (1, 128, 128, 2, 2, 64, True, None, jnp.float32, 64),
    (2, 128, 256, 4, 2, 64, True, None, jnp.float32, 128),
    (1, 128, 128, 4, 1, 128, False, None, jnp.float32, 64),
    (1, 256, 128, 2, 2, 64, True, 64, jnp.float32, 64),
    (1, 128, 128, 2, 2, 64, False, 32, jnp.float32, 64),
    (1, 128, 128, 2, 2, 64, True, None, jnp.bfloat16, 64),
    (1, 64, 64, 3, 1, 32, True, None, jnp.float32, 32),  # odd head count
]


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window,dtype,blk", CASES)
def test_fwd_matches_ref(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, blk):
    q, k, v, _ = _data(jax.random.PRNGKey(0), B, Sq, Sk, Hq, Hkv, D, dtype)
    pos_q = jnp.arange(Sq, dtype=jnp.int32)
    # offset k positions so causal masks are non-trivial across blocks
    pos_k = jnp.arange(Sk, dtype=jnp.int32) + (Sq - Sk) // 2
    o_ker, lse_ker = ops.flash_attention_fwd(
        q, k, v, pos_q, pos_k, causal=causal, window=window,
        block_q=blk, block_k=blk)
    o_ref, lse_ref = ref.block_attention(
        q, k, v, pos_q, pos_k, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_ker, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)
    # compare lse only on live rows
    live = np.asarray(lse_ref) > -1e29
    np.testing.assert_allclose(np.asarray(lse_ker)[live],
                               np.asarray(lse_ref)[live], atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window,dtype,blk", CASES[:5])
def test_bwd_matches_ref(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, blk):
    q, k, v, do = _data(jax.random.PRNGKey(1), B, Sq, Sk, Hq, Hkv, D, dtype)
    pos_q = jnp.arange(Sq, dtype=jnp.int32)
    pos_k = jnp.arange(Sk, dtype=jnp.int32) + (Sq - Sk) // 2
    o_ref, lse = ref.block_attention(q, k, v, pos_q, pos_k, causal=causal,
                                     window=window)
    delta = jnp.einsum("bshd,bshd->bhs", do.astype(jnp.float32),
                       o_ref.astype(jnp.float32))
    got = ops.flash_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k,
                                  causal=causal, window=window,
                                  block_q=blk, block_k=blk)
    want = ref.block_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k,
                                   causal=causal, window=window)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=3e-4, rtol=3e-4,
                                   err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# paged-decode kernel: page-table-indexed online softmax vs the dense oracle
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # B, Hq, Hkv, D, page_size, W, sp, rank, window, raggedness
    (2, 4, 2, 16, 4, 3, 2, 1, None, "ragged"),     # GQA, mid-shard
    (3, 4, 1, 8, 4, 4, 4, 3, None, "ragged"),      # MQA, last shard
    (2, 2, 2, 32, 8, 2, 1, 0, None, "partial"),    # MHA, partially-filled page
    (2, 4, 2, 16, 4, 4, 2, 0, 6, "ragged"),        # sliding window
    (1, 4, 2, 16, 4, 3, 2, 1, 5, "partial"),       # window + partial page
    (2, 4, 2, 16, 4, 3, 2, 0, None, "empty"),      # a row with nothing valid
]


def _paged_fixture(B, Hkv, D, ps, W, sp, raggedness, seed=0):
    """Random pools + a table with some -1 holes + per-row cache lengths."""
    rng = np.random.default_rng(seed)
    pages_loc = 8
    pool_k = jnp.asarray(rng.normal(size=(pages_loc, ps, Hkv, D))
                         .astype(np.float32))
    pool_v = jnp.asarray(rng.normal(size=(pages_loc, ps, Hkv, D))
                         .astype(np.float32))
    tbl = rng.integers(0, pages_loc, size=(B, W)).astype(np.int32)
    tbl[0, -1] = -1                               # unallocated tail page
    max_pos = W * sp * ps
    if raggedness == "partial":
        # last valid position lands mid-page on every row
        cl = (rng.integers(0, W * sp, size=(B,)) * ps
              + rng.integers(1, ps - 1, size=(B,))).astype(np.int32)
    else:
        cl = rng.integers(0, max_pos, size=(B,)).astype(np.int32)
    if raggedness == "empty":
        tbl[-1] = -1                              # no pages at all
        cl[-1] = 0
    return pool_k, pool_v, jnp.asarray(tbl), jnp.asarray(cl)


@pytest.mark.parametrize("B,Hq,Hkv,D,ps,W,sp,rank,window,ragged", PAGED_CASES)
def test_paged_decode_matches_ref(B, Hq, Hkv, D, ps, W, sp, rank, window,
                                  ragged):
    """Interpret-mode parity: the Pallas paged kernel's partial (o, lse)
    equals ref.block_attention over the dense gather of the same pages
    (GQA, sliding window, ragged cache_len, partially-filled pages)."""
    pool_k, pool_v, tbl, cl = _paged_fixture(B, Hkv, D, ps, W, sp, ragged)
    q = jnp.asarray(np.random.default_rng(1)
                    .normal(size=(B, 1, Hq, D)).astype(np.float32))
    rank_a = jnp.int32(rank)

    o_p, lse_p = dispatch.paged_decode(
        q, pool_k, pool_v, tbl, cl, rank_a, sp=sp, page_size=ps,
        window=window, impl="pallas")

    # dense oracle: gather this shard's pages by hand, positions encode
    # validity (invalid slots pushed past the query position)
    pages_loc = pool_k.shape[0]
    safe = jnp.clip(tbl, 0, pages_loc - 1)
    k_r = pool_k[safe].reshape(B, W * ps, Hkv, D)
    v_r = pool_v[safe].reshape(B, W * ps, Hkv, D)
    pos = ((np.arange(W) * sp + rank) * ps)[:, None] + np.arange(ps)[None]
    pos = jnp.asarray(pos.reshape(-1).astype(np.int32))
    valid = jnp.repeat(tbl >= 0, ps, axis=1) & (pos[None] <= cl[:, None])
    pos_k = jnp.where(valid, pos[None], (cl + 1)[:, None])
    o_r, lse_r = ref.block_attention(q, k_r, v_r, cl[:, None], pos_k,
                                     causal=True, window=window)

    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    live = np.asarray(lse_r) > -1e29
    np.testing.assert_allclose(np.asarray(lse_p)[live],
                               np.asarray(lse_r)[live], atol=1e-4, rtol=1e-4)
    # dead rows (no visible key on this shard) must report lse = -inf so
    # the cross-shard combine drops them
    assert (np.asarray(lse_p)[~live] < -1e29).all()


def test_paged_decode_ref_impl_matches_oracle():
    """dispatch.paged_decode(impl='ref') — the gather fallback — agrees
    with the pallas kernel bit-for-tolerance on the same fixture."""
    B, Hq, Hkv, D, ps, W, sp, rank = 2, 4, 2, 16, 4, 3, 2, 1
    pool_k, pool_v, tbl, cl = _paged_fixture(B, Hkv, D, ps, W, sp, "ragged")
    q = jnp.asarray(np.random.default_rng(2)
                    .normal(size=(B, 1, Hq, D)).astype(np.float32))
    o_r, lse_r = dispatch.paged_decode(q, pool_k, pool_v, tbl, cl,
                                       jnp.int32(rank), sp=sp, page_size=ps,
                                       impl="ref")
    o_p, lse_p = dispatch.paged_decode(q, pool_k, pool_v, tbl, cl,
                                       jnp.int32(rank), sp=sp, page_size=ps,
                                       impl="pallas")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    live = np.asarray(lse_r) > -1e29
    np.testing.assert_allclose(np.asarray(lse_p)[live],
                               np.asarray(lse_r)[live], atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# dispatch layer contract
# ---------------------------------------------------------------------------

def test_resolve_impl():
    assert dispatch.resolve_impl("ref") == "ref"
    assert dispatch.resolve_impl("pallas") == "pallas"
    assert dispatch.resolve_impl(None) == (
        "pallas" if jax.default_backend() == "tpu" else "ref")
    with pytest.raises(ValueError):
        dispatch.resolve_impl("cuda")


def test_pallas_batched_positions_no_fallback():
    """impl='pallas' with batched (B, S) positions (per-sequence cache
    lengths) runs the scalar-prefetch ragged kernels — forward AND
    backward. The fallback counter stays empty and both directions match
    the reference."""
    key = jax.random.PRNGKey(3)
    q, k, v, do = _data(key, 2, 8, 8, 2, 2, 16, jnp.float32)
    pos_shared = jnp.arange(8, dtype=jnp.int32)
    pos_batched = jnp.stack([pos_shared, pos_shared + 1])     # (B, S)

    dispatch.reset_pallas_fallbacks()
    o_pl, lse_pl = dispatch.block_fwd(q, k, v, pos_batched, pos_batched,
                                      causal=True, impl="pallas")
    assert dispatch.pallas_fallbacks() == {}, \
        "batched forward positions must run the ragged kernel, not fall back"
    o_ref, lse_ref = ref.block_attention(q, k, v, pos_batched, pos_batched,
                                         causal=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(lse_pl), np.asarray(lse_ref),
                               atol=2e-5, rtol=2e-5)

    # shared (S,) positions keep running the training flash kernel...
    dispatch.block_fwd(q, k, v, pos_shared, pos_shared, causal=True,
                       impl="pallas")
    assert dispatch.pallas_fallbacks() == {}
    # ...and impl='ref' is not a fallback, it is the requested path
    dispatch.block_fwd(q, k, v, pos_batched, pos_batched, causal=True,
                       impl="ref")
    assert dispatch.pallas_fallbacks() == {}
    # the backward pass now has ragged kernels too: no fallback, and the
    # grads match the reference
    lse = lse_pl
    delta = jnp.sum(o_pl * do, axis=-1).swapaxes(1, 2).astype(jnp.float32)
    got = dispatch.block_bwd(q, k, v, do, lse, delta, pos_batched,
                             pos_batched, causal=True, impl="pallas")
    assert dispatch.pallas_fallbacks() == {}, \
        "batched backward positions must run the ragged kernels, not fall back"
    want = ref.block_attention_bwd(q, k, v, do, lse, delta, pos_batched,
                                   pos_batched, causal=True)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=3e-4, rtol=3e-4, err_msg=f"d{name}")
    dispatch.reset_pallas_fallbacks()


RAGGED_BWD_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window
    (2, 64, 64, 4, 2, 32, True, None),     # GQA
    (2, 64, 128, 4, 1, 32, True, None),    # MQA, rectangular
    (1, 96, 96, 2, 2, 32, True, 24),       # window + non-pow2 seq
    (2, 64, 64, 2, 2, 32, False, None),    # full attention
]


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window", RAGGED_BWD_CASES)
def test_bwd_ragged_matches_ref(B, Sq, Sk, Hq, Hkv, D, causal, window):
    """The scalar-prefetch ragged backward kernels (per-batch positions
    sliced from SMEM) vs the reference backward, including per-row offsets
    that differ across the batch."""
    q, k, v, do = _data(jax.random.PRNGKey(7), B, Sq, Sk, Hq, Hkv, D,
                        jnp.float32)
    base_q = jnp.arange(Sq, dtype=jnp.int32)
    base_k = jnp.arange(Sk, dtype=jnp.int32) + (Sq - Sk) // 2
    pos_q = jnp.stack([base_q + 3 * b for b in range(B)])
    pos_k = jnp.stack([base_k + 3 * b for b in range(B)])
    o_ref, lse = ref.block_attention(q, k, v, pos_q, pos_k, causal=causal,
                                     window=window)
    delta = jnp.einsum("bshd,bshd->bhs", do.astype(jnp.float32),
                       o_ref.astype(jnp.float32))
    got = ops.flash_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k,
                                  causal=causal, window=window)
    want = ref.block_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k,
                                   causal=causal, window=window)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=3e-4, rtol=3e-4, err_msg=f"d{name}")


MERGE_CASES = [
    # B, S, Hq, Hkv, D, causal, window, seed_dead
    (2, 128, 4, 2, 64, True, None, False),   # GQA
    (1, 128, 4, 1, 64, True, None, False),   # MQA
    (1, 128, 2, 2, 64, True, 32, False),     # window: dead rows in block
    (2, 128, 2, 2, 64, True, None, True),    # dead rows in the RUNNING acc
    (1, 128, 2, 2, 64, False, None, False),  # full attention
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,seed_dead", MERGE_CASES)
def test_fwd_merge_fused_matches_two_step(B, S, Hq, Hkv, D, causal, window,
                                          seed_dead):
    """The fused merge epilogue (flash kernel consuming a running
    (o_acc, lse_acc)) matches the two-step form it replaces — block_fwd
    followed by combine_pair — to within 2 ulp on o (XLA may fuse the
    merge's multiply-adds differently across the two compilations) and
    bit-exactly on lse. Covers GQA, windowed masks that kill whole rows
    inside the block, and dead rows (lse=-inf) arriving in the running
    accumulator."""
    from repro.core.combine import NEG_INF, combine_pair

    q, k, v, _ = _data(jax.random.PRNGKey(11), B, S, S, Hq, Hkv, D,
                       jnp.float32)
    pos_q = jnp.arange(S, dtype=jnp.int32)
    pos_k = jnp.arange(S, dtype=jnp.int32) + 16
    # a running accumulator from an earlier ring step over different keys
    k2, v2, _, _ = _data(jax.random.PRNGKey(12), B, S, S, Hkv, Hkv, D,
                         jnp.float32)
    o_acc, lse_acc = ref.block_attention(q, k2, v2, pos_q,
                                         jnp.arange(S, dtype=jnp.int32),
                                         causal=causal, window=window)
    if seed_dead:
        # first half of the rows have seen nothing yet (lse = -inf)
        dead = (jnp.arange(S) < S // 2)[None, None, :]
        lse_acc = jnp.where(dead, NEG_INF, lse_acc)
        o_acc = jnp.where(dead.swapaxes(1, 2)[..., None], 0.0, o_acc)

    fused = dispatch.block_fwd_merge(q, k, v, o_acc, lse_acc, pos_q, pos_k,
                                     causal=causal, window=window,
                                     impl="pallas")
    o_blk, lse_blk = dispatch.block_fwd(q, k, v, pos_q, pos_k,
                                        causal=causal, window=window,
                                        impl="pallas")
    two_step = combine_pair(o_acc, lse_acc, o_blk, lse_blk)
    np.testing.assert_allclose(np.asarray(fused[0]), np.asarray(two_step[0]),
                               atol=1e-7, rtol=5e-7,
                               err_msg="fused merge o vs combine_pair")
    assert np.array_equal(np.asarray(fused[1]), np.asarray(two_step[1])), (
        "fused merge lse not bit-identical to combine_pair (max diff "
        f"{np.abs(np.asarray(fused[1]) - np.asarray(two_step[1])).max()})")
    # the ref-impl fallback of block_fwd_merge is the same two-step form
    ref_merge = dispatch.block_fwd_merge(q, k, v, o_acc, lse_acc, pos_q,
                                         pos_k, causal=causal, window=window,
                                         impl="ref")
    np.testing.assert_allclose(np.asarray(ref_merge[0]),
                               np.asarray(two_step[0]), atol=2e-5, rtol=2e-5)


def test_no_direct_kernel_imports():
    """Grep-enforced: no module outside kernels/ imports kernels.ref /
    kernels.ops / kernels.flash_attention / kernels.paged_decode directly —
    every attention call site in core/, serve/, engine/, models/ goes
    through kernels.dispatch. (testing/dist_checks.py is exempt: it uses
    ref as the *oracle* the distributed paths are checked against.)"""
    src = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    pat = re.compile(
        r"repro\.kernels\s+import\s+"
        r"(ref|ops|flash_attention|paged_decode|ragged_prefill|paged_prefill)"
        r"|repro\.kernels\."
        r"(ref|ops|flash_attention|paged_decode|ragged_prefill|paged_prefill)")
    offenders = []
    for path in sorted(src.rglob("*.py")):
        rel = path.relative_to(src)
        if rel.parts[0] in ("kernels", "testing"):
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, (
        "direct kernel imports outside kernels/ (use kernels.dispatch):\n"
        + "\n".join(offenders))


def test_flash_attention_grad_end_to_end():
    """custom_vjp wrapper: jax.grad through the kernel == grad through ref."""
    B, S, Hq, Hkv, D = 1, 128, 2, 1, 64
    q, k, v, do = _data(jax.random.PRNGKey(2), B, S, S, Hq, Hkv, D, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)

    def loss_ker(q, k, v):
        o = ops.flash_attention(q, k, v, pos, pos, True, None, None)
        return (o * do).sum()

    def loss_ref(q, k, v):
        o, _ = ref.block_attention(q, k, v, pos, pos, causal=True)
        return (o.astype(q.dtype) * do).sum()

    g_ker = jax.grad(loss_ker, (0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b, name in zip(g_ker, g_ref, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-4, err_msg=f"d{name}")
