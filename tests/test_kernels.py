"""Pallas flash-attention kernels vs the pure-jnp oracle (interpret mode).

Sweeps shapes/dtypes per the kernel-testing contract: every kernel is
asserted allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref


def _data(key, B, Sq, Sk, Hq, Hkv, D, dtype):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32).astype(dtype)
    do = jax.random.normal(ks[3], (B, Sq, Hq, D), jnp.float32).astype(dtype)
    return q, k, v, do


CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, block
    (1, 128, 128, 2, 2, 64, True, None, jnp.float32, 64),
    (2, 128, 256, 4, 2, 64, True, None, jnp.float32, 128),
    (1, 128, 128, 4, 1, 128, False, None, jnp.float32, 64),
    (1, 256, 128, 2, 2, 64, True, 64, jnp.float32, 64),
    (1, 128, 128, 2, 2, 64, False, 32, jnp.float32, 64),
    (1, 128, 128, 2, 2, 64, True, None, jnp.bfloat16, 64),
    (1, 64, 64, 3, 1, 32, True, None, jnp.float32, 32),  # odd head count
]


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window,dtype,blk", CASES)
def test_fwd_matches_ref(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, blk):
    q, k, v, _ = _data(jax.random.PRNGKey(0), B, Sq, Sk, Hq, Hkv, D, dtype)
    pos_q = jnp.arange(Sq, dtype=jnp.int32)
    # offset k positions so causal masks are non-trivial across blocks
    pos_k = jnp.arange(Sk, dtype=jnp.int32) + (Sq - Sk) // 2
    o_ker, lse_ker = ops.flash_attention_fwd(
        q, k, v, pos_q, pos_k, causal=causal, window=window,
        block_q=blk, block_k=blk)
    o_ref, lse_ref = ref.block_attention(
        q, k, v, pos_q, pos_k, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_ker, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)
    # compare lse only on live rows
    live = np.asarray(lse_ref) > -1e29
    np.testing.assert_allclose(np.asarray(lse_ker)[live],
                               np.asarray(lse_ref)[live], atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window,dtype,blk", CASES[:5])
def test_bwd_matches_ref(B, Sq, Sk, Hq, Hkv, D, causal, window, dtype, blk):
    q, k, v, do = _data(jax.random.PRNGKey(1), B, Sq, Sk, Hq, Hkv, D, dtype)
    pos_q = jnp.arange(Sq, dtype=jnp.int32)
    pos_k = jnp.arange(Sk, dtype=jnp.int32) + (Sq - Sk) // 2
    o_ref, lse = ref.block_attention(q, k, v, pos_q, pos_k, causal=causal,
                                     window=window)
    delta = jnp.einsum("bshd,bshd->bhs", do.astype(jnp.float32),
                       o_ref.astype(jnp.float32))
    got = ops.flash_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k,
                                  causal=causal, window=window,
                                  block_q=blk, block_k=blk)
    want = ref.block_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k,
                                   causal=causal, window=window)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=3e-4, rtol=3e-4,
                                   err_msg=f"d{name}")


def test_flash_attention_grad_end_to_end():
    """custom_vjp wrapper: jax.grad through the kernel == grad through ref."""
    B, S, Hq, Hkv, D = 1, 128, 2, 1, 64
    q, k, v, do = _data(jax.random.PRNGKey(2), B, S, S, Hq, Hkv, D, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)

    def loss_ker(q, k, v):
        o = ops.flash_attention(q, k, v, pos, pos, True, None, None)
        return (o * do).sum()

    def loss_ref(q, k, v):
        o, _ = ref.block_attention(q, k, v, pos, pos, causal=True)
        return (o.astype(q.dtype) * do).sum()

    g_ker = jax.grad(loss_ker, (0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b, name in zip(g_ker, g_ref, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-4, err_msg=f"d{name}")
