"""Unit tests for the serving gateway: ref-counted page pool (double-free
regression), block-hash trie + COW sharing + eviction (pure host-side),
routing policy, and a single-device end-to-end prefix-cached gateway run
(the SP=1 degenerate mesh — still through shard_map and the suffix-prefill
jit path)."""

import numpy as np
import pytest

from repro.engine import EngineConfig, Request, Scheduler
from repro.engine.paged_cache import PagePool
from repro.gateway import PrefixCache, Router, block_hashes


# ---------------------------------------------------------------------------
# PagePool: ref-counted free lists (no devices)
# ---------------------------------------------------------------------------

def test_pagepool_alloc_share_release():
    pool = PagePool(sp=2, pages_per_shard=2)
    p0 = pool.alloc(0)
    pool.incref(0, p0)                      # a second sequence shares it
    assert pool.pages_in_use() == 1
    assert not pool.decref(0, p0)           # first release: still held
    assert pool.decref(0, p0)               # second release frees
    assert pool.pages_in_use() == 0


def test_pagepool_double_free_raises():
    pool = PagePool(sp=1, pages_per_shard=2)
    page = pool.alloc(0)
    pool.decref(0, page)
    with pytest.raises(ValueError, match="double free"):
        pool.decref(0, page)
    with pytest.raises(ValueError, match="free page"):
        pool.incref(0, page)                # resurrection is also an error


def test_pagepool_exhaustion():
    pool = PagePool(sp=1, pages_per_shard=1)
    pool.alloc(0)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(0)


# ---------------------------------------------------------------------------
# block hashes
# ---------------------------------------------------------------------------

def test_block_hashes_chain():
    a = block_hashes([1, 2, 3, 4, 5, 6, 7], page_size=4)
    assert len(a) == 1                      # only full blocks
    b = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], page_size=4)
    assert a[0] == b[0]                     # shared first block
    c = block_hashes([9, 1, 2, 3, 4], page_size=4)
    assert c[0] != a[0]                     # position-qualified: shifted
    #                                         content is a different prefix
    d = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], page_size=4)
    assert b == d                           # deterministic


# ---------------------------------------------------------------------------
# Scheduler + PrefixCache (host-side: admission shares pages, COW holds)
# ---------------------------------------------------------------------------

def _cached_sched(pages_per_shard=8, sp=2, max_slots=2):
    s = Scheduler(max_slots=max_slots, page_size=4, sp=sp,
                  pages_per_shard=pages_per_shard, max_len=64)
    s.prefix_cache = PrefixCache(s.pool, page_size=4, sp=sp)
    return s


def test_admission_shares_prefix_pages():
    s = _cached_sched()
    prompt = list(range(12))                # 3 full blocks
    s.enqueue(Request("a", prompt + [90], 3))
    [st_a] = s.admit(step=0)
    assert st_a.cached_len == 0
    s.register_prefix(st_a)                 # prefill landed: blocks cached
    s.enqueue(Request("b", prompt + [91, 92], 3))
    [st_b] = s.admit(step=1)
    assert st_b.cached_len == 12            # 3 shared blocks
    assert st_b.pages[:3] == st_a.pages[:3], "COW: same physical pages"
    for shard, page in st_b.pages[:3]:
        assert s.pool.refs[shard, page] == 3   # a + b + cache hold
    # decode writes target blocks past the shared prefix only
    shared = set(st_b.pages[:3])
    assert not shared & set(st_b.pages[3:])
    # finishing a does NOT free the shared pages (b + cache still hold)
    s.finish(st_a.slot, step=2)
    for shard, page in st_b.pages[:3]:
        assert s.pool.refs[shard, page] == 2
    s.finish(st_b.slot, step=3)
    for shard, page in st_b.pages[:3]:
        assert s.pool.refs[shard, page] == 1   # cache keeps them resident


def test_scheduler_finish_double_free_regression():
    """Regression: finish used to append pages to the free list
    unconditionally — with sharing that double-frees. Now every release
    goes through the ref-counted pool and over-release raises."""
    s = _cached_sched()
    s.enqueue(Request("a", list(range(9)), 2))
    [st] = s.admit(step=0)
    pages = list(st.pages)
    s.register_prefix(st)
    s.finish(st.slot, step=1)
    for shard, page in pages[:2]:           # cached full blocks: held
        assert s.pool.refs[shard, page] == 1
    with pytest.raises(ValueError, match="double free"):
        s.pool.decref(*pages[-1])           # already freed at finish


def test_fully_cached_prompt_keeps_one_suffix_token():
    s = _cached_sched()
    prompt = list(range(8))                 # exactly 2 full blocks
    s.enqueue(Request("a", prompt, 3))
    [st_a] = s.admit(step=0)
    s.register_prefix(st_a)
    s.finish(st_a.slot, step=1)
    s.enqueue(Request("b", prompt, 3))      # identical prompt
    [st_b] = s.admit(step=2)
    # only (prompt_len - 1) // ps = 1 block may hit: the last token must
    # be forwarded to produce the first sampled token's hidden state
    assert st_b.cached_len == 4


def test_blocked_admission_is_side_effect_free():
    """Regression: a head-of-line-blocked request must not evict cached
    pages, refresh LRU stamps, or inflate hit/lookup stats — the probe is
    read-only until admission is certain."""
    s = _cached_sched(pages_per_shard=2, sp=2, max_slots=2)
    cache = s.prefix_cache
    # seed the cache with one retained block (a finishes, block 0 stays)
    s.enqueue(Request("a", [1, 2, 3, 4, 5], 2))
    [st_a] = s.admit(step=0)
    s.register_prefix(st_a)
    s.finish(st_a.slot, step=0)
    # b occupies (and keeps live) one page per shard
    s.enqueue(Request("b", [9] * 4, 3))         # 7 pos -> 2 blocks live
    [st_b] = s.admit(step=1)
    assert st_b.cached_len == 0
    # c cannot fit: needs 2 shard-0 pages; 0 free + only 1 evictable
    s.enqueue(Request("c", [8] * 9, 4))         # 13 pos -> 4 blocks
    stats0 = cache.stats()
    for step in range(2, 6):                    # engine retries every step
        assert s.admit(step=step) == []
    stats1 = cache.stats()
    assert stats1 == stats0, "blocked retries skewed cache stats/trie"
    assert cache.evicted_pages == 0, "blocked admission evicted pages"
    assert cache.match_len(cache.hashes([1, 2, 3, 4])) == 1, \
        "blocked admission dropped a cached block"
    # once b finishes, c admits (evicting under real feasibility)
    s.finish(st_b.slot, step=6)
    [st_c] = s.admit(step=7)
    assert st_c.req.uid == "c"
    assert cache.evicted_pages == 1             # a's block, now reclaimed


def test_eviction_lru_and_live_protection():
    s = _cached_sched(pages_per_shard=2, sp=2, max_slots=2)
    cache = s.prefix_cache
    # a: 8 pos -> 2 blocks; 1 full block cached after finish
    s.enqueue(Request("a", [1, 2, 3, 4, 5], 3))
    [st_a] = s.admit(step=0)
    s.register_prefix(st_a)
    s.finish(st_a.slot, step=1)
    # b shares a's block and stays LIVE
    s.enqueue(Request("b", [1, 2, 3, 4, 9], 3))
    [st_b] = s.admit(step=2)
    assert st_b.cached_len == 4
    shared = st_b.pages[0]
    # c fills the pool -> must evict, but only cache-only pages; the
    # shared block (live ref from b) survives in the pool
    s.enqueue(Request("c", [7, 7, 7, 7, 8], 3))
    [st_c] = s.admit(step=3)
    assert st_c.cached_len == 0
    assert s.pool.refs[shared] >= 1, "live shared page was freed"
    assert st_b.pages[0] == shared
    # dropping the cache while b is live never frees b's pages
    cache.drop_all()
    assert s.pool.refs[shared] == 1         # b's ref only
    s.finish(st_b.slot, step=4)
    assert s.pool.refs[shared] == 0         # now truly free


# ---------------------------------------------------------------------------
# Router (stub engines)
# ---------------------------------------------------------------------------

class _StubSched:
    def __init__(self):
        self.queue = []

    def active(self):
        return []


class _StubEngine:
    def __init__(self, cached):
        self._cached = cached
        self.scheduler = _StubSched()
        self.prefix_cache = self

    # PrefixCache protocol used by the router
    page_size = 4

    def hashes(self, tokens):
        return tokens

    def match_len(self, hashes):
        return self._cached


def test_router_prefers_prefix_then_load_then_index():
    a, b = _StubEngine(cached=0), _StubEngine(cached=2)
    r = Router([a, b])
    req = Request("x", [1, 2, 3, 4, 5, 6, 7, 8], 2)
    assert r.route(req) == 1                # 8 cached tokens beat empty
    b._cached = 0
    assert r.route(req) == 0                # tie -> lower index
    a.scheduler.queue = [req]               # load on a
    assert r.route(req) == 1


def test_router_session_affinity_sticks():
    a, b = _StubEngine(0), _StubEngine(0)
    r = Router([a, b])
    req = Request("x", [1] * 8, 2)
    first = r.route(req, session="s")
    b._cached = 99                          # would win without affinity
    assert r.route(req, session="s") == first
    assert r.affinity_hits == 1


# ---------------------------------------------------------------------------
# end-to-end on the single-device (SP=1) mesh
# ---------------------------------------------------------------------------

def test_gateway_single_device_prefix_cache_end_to_end():
    from repro.gateway import build_gateway

    eng = EngineConfig(max_slots=2, page_size=4, pages_per_shard=32,
                       max_len=64)
    gw = build_gateway("h2o-danube-1.8b", smoke=True, c=1, data=1,
                       replicas=1, prefix_cache=True, eng=eng)
    rng = np.random.default_rng(0)
    vocab = gw.cfg.vocab_size
    shared = rng.integers(0, vocab, 16).tolist()
    reqs = [Request(f"r{i}", shared + rng.integers(0, vocab, 3 + i).tolist(),
                    4, seed=i) for i in range(3)]
    for r in reqs:
        gw.add_request(r)
    out = gw.run()
    m = gw.metrics_dict()
    assert m["prefill_tokens_cached"] == 32      # r1 + r2 hit 16 each
    assert m["prefix_hit_rate"] > 0.5
    # streaming: every request's stream drains to its full output
    assert all(gw.take(r.uid) == out[r.uid] for r in reqs)
    assert gw.take(reqs[0].uid) == []            # drained
    # bit-identical to cold-cache solo serving
    cold = build_gateway("h2o-danube-1.8b", smoke=True, c=1, data=1,
                         replicas=1, prefix_cache=False, eng=eng)
    for r in reqs:
        cold.reset()
        cold.add_request(r)
        assert cold.run()[r.uid] == out[r.uid], f"{r.uid} diverged"
    # replay on warm buckets: zero new compiles, incl. the suffix path
    compiles = gw.compiles()
    gw.reset()
    for r in reqs:
        gw.add_request(r)
    assert gw.run() == out, "replay diverged"
    assert gw.compiles() == compiles, "recompiled on replay"
    e = gw.engines[0]
    assert e.xla_compiles() == (
        len(e._prefill_fns) + len(e._suffix_fns), len(e._decode_fns)), \
        "a bucket fn holds more than one XLA trace"


def test_prefix_cache_rejected_for_moe():
    from repro.gateway import build_gateway

    with pytest.raises(NotImplementedError, match="MoE"):
        build_gateway("phi3.5-moe-42b-a6.6b", smoke=True, c=1, data=1,
                      replicas=1, prefix_cache=True,
                      eng=EngineConfig(max_slots=1, page_size=4,
                                       pages_per_shard=8, max_len=32))


def test_serve_plan_gateway_face_round_trip(tmp_path):
    from repro.configs import registry
    from repro.plan import ExecutionPlan, make_serve_plan

    cfg = registry.get_smoke("h2o-danube-1.8b")
    plan = make_serve_plan(cfg, arch="h2o-danube-1.8b", n_devices=1,
                           decode_batch=2, page_size=4, max_len=64,
                           replicas=2, prefix_cache=True)
    assert plan.replicas == 2 and plan.prefix_cache
    path = plan.save(tmp_path / "plan.json")
    assert ExecutionPlan.load(path) == plan
    with pytest.raises(ValueError, match="serving-face"):
        import dataclasses
        dataclasses.replace(plan, page_size=0, decode_batch=0)


def test_prefix_cache_cost_model():
    from repro.configs import registry
    from repro.plan import cost

    cfg = registry.get_smoke("h2o-danube-1.8b")
    cold = cost.prefill_step_cost(cfg, prompt_len=128, sp=4)
    warm = cost.prefill_step_cost(cfg, prompt_len=128, cached_len=96, sp=4)
    assert warm["flops"] < cold["flops"]
    assert warm["saved_frac"] > 0.5         # 3/4 of the prompt cached
    assert cold["saved_frac"] == 0.0
    roi = cost.prefix_cache_value(cfg, prompt_len=128, shared_len=96,
                                  requests=8, sp=4, page_size=8,
                                  pages_per_shard=64, max_len=32)
    assert roi["fits"] and roi["hit_rate"] > 0.5 and roi["saved_flops"] > 0
    # a pool too small for prefix + one live request prices to zero
    none = cost.prefix_cache_value(cfg, prompt_len=128, shared_len=96,
                                   requests=8, sp=1, page_size=8,
                                   pages_per_shard=16, max_len=32)
    assert not none["fits"] and none["hit_rate"] == 0.0
