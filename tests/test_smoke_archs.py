"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core.startrail import StarTrailConfig
from repro.models.factory import build_model
from repro.models.runtime import Runtime

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _runtime(cfg, seq_len):
    scheme = ("contiguous"
              if cfg.family in ("ssm", "hybrid") else "zigzag")
    st = StarTrailConfig(seq_len=seq_len, seq_scheme=scheme, causal=True)
    return Runtime(mode="local", st_cfg=st)


@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_smoke(arch)
    model = build_model(cfg)
    rt = _runtime(cfg, SMOKE_SHAPE.seq_len)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(jax.random.PRNGKey(1), SMOKE_SHAPE)

    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: model.loss(rt, p, batch))
    )(params)

    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{arch}: no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), (
            f"{arch}: non-finite grad")
    # loss should be near log(vocab) at init (sanity, generous range)
    assert 0.1 < float(loss) < 3 * np.log(cfg.vocab_size), (
        f"{arch}: implausible init loss {loss}")


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "xlstm-1.3b"])
def test_smoke_two_steps_decrease(arch):
    """One SGD step on the same batch must reduce the loss."""
    cfg = registry.get_smoke(arch)
    model = build_model(cfg)
    rt = _runtime(cfg, SMOKE_SHAPE.seq_len)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(jax.random.PRNGKey(1), SMOKE_SHAPE)

    vg = jax.jit(jax.value_and_grad(lambda p: model.loss(rt, p, batch)))
    l0, g = vg(params)
    params = jax.tree.map(lambda p, gr: p - 0.5 * gr.astype(p.dtype), params, g)
    l1, _ = vg(params)
    assert float(l1) < float(l0), f"{arch}: loss did not decrease {l0}->{l1}"
