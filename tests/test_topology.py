"""Topology unit + property tests (pure python, no devices)."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import topology as topo
from repro.core import zigzag as zz


def factorizations():
    out = []
    for p in (4, 8, 16, 36, 64, 144, 256):
        for c in topo.valid_c_values(p):
            out.append((p, c))
    return out


@pytest.mark.parametrize("p,c", factorizations())
def test_invariants(p, c):
    tp = topo.StarTrailTopology(p, c)
    tp.check_invariants()


@pytest.mark.parametrize("p,c", factorizations())
def test_matches_paper_algorithms(p, c):
    tp = topo.StarTrailTopology(p, c)
    d_t, d_a = tp.num_teams, c
    perm = dict(tp.init_placement_permutation())
    for r_t in range(d_t):
        for r_a in range(d_a):
            src = r_t * c + r_a
            assert perm[src] == topo.paper_get_init_send(r_t, r_a, d_t, d_a)
    ring = dict(tp.ring_permutation())
    for r_t in range(d_t):
        for r_a in range(d_a):
            src = r_t * c + r_a
            nxt, last = topo.paper_get_p2p_config(r_t, r_a, d_t, d_a)
            assert ring[src] in (nxt, last)


@pytest.mark.parametrize("p,c", factorizations())
def test_ring_is_single_cycle_per_ring(p, c):
    tp = topo.StarTrailTopology(p, c)
    ring = dict(tp.ring_permutation())
    for g in range(c):
        for t in range(c):
            start = tp.rank(g, 0, t)
            seen = {start}
            cur = ring[start]
            while cur != start:
                assert cur not in seen
                seen.add(cur)
                cur = ring[cur]
            assert len(seen) == tp.ring_size


@given(st.integers(1, 6).map(lambda c: c * c).flatmap(
    lambda c2: st.tuples(st.just(c2), st.integers(1, 8))))
@settings(max_examples=40, deadline=None)
def test_property_placement_bijection(args):
    c2, r = args
    c = int(c2 ** 0.5)
    p = c2 * r
    tp = topo.StarTrailTopology(p, c)
    perm = tp.init_placement_permutation()
    assert sorted(s for s, _ in perm) == list(range(p))
    assert sorted(d for _, d in perm) == list(range(p))
    inv = dict(tp.inverse_placement_permutation())
    for s, d in perm:
        assert inv[d] == s


@given(st.sampled_from(factorizations()))
@settings(max_examples=30, deadline=None)
def test_property_coverage_exact(pc):
    """Every team's members jointly see every K/V chunk exactly once."""
    p, c = pc
    tp = topo.StarTrailTopology(p, c)
    for g in range(c):
        for j in range(tp.ring_size):
            seen = []
            for t in range(c):
                seen.extend(tp.coverage(g, j, t))
            assert sorted(seen) == list(range(tp.num_teams))


def test_invalid_c_rejected():
    with pytest.raises(ValueError):
        topo.StarTrailTopology(16, 3)
    with pytest.raises(ValueError):
        topo.StarTrailTopology(16, 8)


# ---- zigzag ---------------------------------------------------------------

@given(st.integers(1, 6), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_property_zigzag_partition(log2p, mult):
    p = 2 ** log2p
    seq = 2 * p * mult
    pos = zz.zigzag_positions(seq, p)
    flat = sorted(pos.reshape(-1).tolist())
    assert flat == list(range(seq))


@pytest.mark.parametrize("p", [2, 4, 8, 16, 64])
def test_zigzag_balance(p):
    seq = 16 * p
    bal_zz = zz.balance_ratio(zz.zigzag_positions(seq, p), seq)
    bal_ct = zz.balance_ratio(zz.contiguous_positions(seq, p), seq)
    assert bal_zz < 1.07          # near-perfect balance
    assert bal_ct > 1.4           # contiguous is badly unbalanced
    assert bal_zz < bal_ct


def test_shard_unshard_roundtrip():
    import numpy as np

    pos = zz.zigzag_positions(32, 4)
    x = np.arange(2 * 32).reshape(2, 32)
    y = zz.shard_tokens(x, pos, axis=1)
    z = zz.unshard_tokens(y, pos, axis=1)
    assert (x == z).all()
