"""End-to-end system tests.

The distributed checks need >1 device, so they run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (keeping this pytest
session on 1 device, as required for the smoke/bench paths). Checks are
batched per subprocess to amortise startup.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

BATCHES = {
    "attention_correctness": [
        "topology", "ring_causal_zigzag", "ring_full_contig",
        "st2_causal_zigzag", "st2_causal_contig", "st2_full", "st2_window",
        "st2_window_skip", "st2_mha", "st2_mqa", "st2_bf16", "st2_r1",
    ],
    "attention_pallas_and_baselines": ["st2_pallas", "ulysses", "decode"],
    "spmd_model_equivalence": [
        "spmd_dense_swa", "spmd_dense_c1", "spmd_moe", "spmd_vlm",
        "spmd_encdec", "spmd_hybrid", "spmd_xlstm_runs",
    ],
    "spmd_train_and_serve": [
        "spmd_train_step", "serve_dense", "serve_moe", "serve_hybrid",
        "serve_xlstm", "serve_encdec",
    ],
    "engine_serving": [
        "greedy_tie", "engine_sampling", "engine_mixed", "engine_moe",
    ],
    "engine_paged_kernel": [
        "paged_decode_dist", "engine_paged_kernel", "chunked_prefill_dist",
    ],
    "gateway_serving": [
        "gateway_prefix_cow", "gateway_replicas", "gateway_disagg",
    ],
    "plan_and_microbatch": [
        "microbatch_equiv", "scheme_crosscheck", "ulysses_rejected",
        "plan_constructs", "commlog_c2",
    ],
    "pipelined_scan": [
        "pipelined_bitexact", "bwd_skip_equiv",
    ],
}


BATCHES_16DEV = {
    "c4_and_16dev_rings": ["st4_p16", "st2_p16_r4", "st2_p16_window"],
}
BATCHES.update(BATCHES_16DEV)


@pytest.mark.parametrize("batch", sorted(BATCHES))
def test_distributed(batch):
    env = dict(os.environ)
    n = 16 if batch in BATCHES_16DEV else 8
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.dist_checks", *BATCHES[batch]],
        env=env, capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, (
        f"distributed batch {batch} failed:\n{proc.stdout[-4000:]}\n"
        f"{proc.stderr[-2000:]}")


def test_dryrun_one_cell():
    """The 512-device dry-run machinery works (fast cell)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # dryrun sets its own 512-device flag
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "h2o-danube-1.8b", "--shape", "decode_32k"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-2000:]
    assert "[ok]" in proc.stdout


def test_train_driver_end_to_end(tmp_path):
    """launch.train runs, checkpoints, and restores in a fresh process;
    the jsonl metrics stream carries every step (the trainer buffers
    metrics on-device between log boundaries — the stream must not)."""
    import json

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    metrics = tmp_path / "metrics.jsonl"
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "h2o-danube-1.8b", "--smoke", "--devices", "8", "--data", "2",
            "--c", "2", "--steps", "6", "--ckpt-dir", str(tmp_path),
            "--metrics", str(metrics)]
    p1 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=1200)
    assert p1.returncode == 0, p1.stdout[-3000:] + p1.stderr[-2000:]
    args[args.index("6")] = "8"
    p2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=1200)
    assert p2.returncode == 0, p2.stdout[-3000:] + p2.stderr[-2000:]
    assert "restored step 6" in p2.stdout
    recs = [json.loads(l) for l in metrics.read_text().splitlines()]
    assert [r["step"] for r in recs] == list(range(1, 7)) + [7, 8]
    assert all("loss" in r and "grad_norm" in r for r in recs)
    # per-phase wall-time breakdown from the obs span layer (host
    # perf_counter only — no per-step device sync): every record carries
    # data/step/ckpt seconds, and the ckpt launch cost lands on boundaries
    assert all({"data_s", "step_s", "ckpt_s"} <= r.keys() for r in recs)
    assert all(r["data_s"] >= 0 and r["step_s"] > 0 for r in recs)
    ckpt_steps = [r["step"] for r in recs if r["ckpt_s"] > 0]
    assert ckpt_steps and set(ckpt_steps) <= {3, 6, 4, 8}, ckpt_steps
