"""SSM recurrence + roofline/HLO-parser tests (single device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.ssm import chunked_gla
from repro.roofline import hlo as hlo_lib


def naive_gla(q, k, v, ld):
    B, S, H, N = q.shape
    P = v.shape[-1]

    def step(h, inp):
        qt, kt, vt, lt = inp
        h = h * jnp.exp(lt)[..., None, None] + kt[..., :, None] * vt[..., None, :]
        return h, jnp.einsum("bhn,bhnp->bhp", qt, h)

    h0 = jnp.zeros((B, H, N, P))
    hf, ys = jax.lax.scan(step, h0,
                          tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, ld)))
    return jnp.moveaxis(ys, 0, 1), hf


@given(st.integers(0, 100), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=20, deadline=None)
def test_property_chunked_gla_exact(seed, chunk):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B, S, H, N, P = 1, 16, 2, 3, 4
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ld = -jnp.abs(jax.random.normal(ks[3], (B, S, H))) * 0.5
    y_ref, h_ref = naive_gla(q, k, v, ld)
    y, h_fin, ld_tot, la = chunked_gla(q, k, v, ld, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ld_tot),
                               np.asarray(ld.sum(axis=1)), atol=1e-5)


def test_gla_zero_decay_is_cumulative_sum():
    """With decay == 1 (log 0) and q=k=1-dim ones, y_t = sum_{j<=t} v_j."""
    B, S, H = 1, 8, 1
    q = jnp.ones((B, S, H, 1))
    k = jnp.ones((B, S, H, 1))
    v = jnp.arange(1.0, S + 1).reshape(1, S, 1, 1)
    ld = jnp.zeros((B, S, H))
    y, _, _, _ = chunked_gla(q, k, v, ld, 4)
    np.testing.assert_allclose(np.asarray(y[0, :, 0, 0]),
                               np.cumsum(np.arange(1.0, S + 1)))


# ---- HLO collective parser ---------------------------------------------------

def test_hlo_parser_counts_real_collectives():
    import os

    # build a tiny module with known collectives on 1 device? No — parse a
    # handcrafted HLO snippet with known shapes instead.
    text = """
  %ag = bf16[2,512,64]{2,1,0} all-gather(bf16[2,256,64]{2,1,0} %x), replica_groups={}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %y), to_apply=%add
  %rs = f32[2,128]{1,0} reduce-scatter(f32[2,256]{1,0} %z), dimensions={1}
  %cp = (bf16[64]{0}, bf16[64]{0}) collective-permute-start(bf16[64]{0} %w), source_target_pairs={{0,1}}
  %cpd = bf16[64]{0} collective-permute-done((bf16[64]{0}, bf16[64]{0}) %cp)
  %a2a = f32[4,32]{1,0} all-to-all(f32[4,32]{1,0} %v), dimensions={0}
"""
    out = hlo_lib.collective_bytes(text)
    assert out["count_by_kind"] == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "collective-permute": 1, "all-to-all": 1}
    assert out["bytes_by_kind"]["all-gather"] == 2 * 512 * 64 * 2
    assert out["bytes_by_kind"]["all-reduce"] == 128 * 4
    assert out["bytes_by_kind"]["reduce-scatter"] == 2 * 128 * 4
    # permute-start result is a (in, out) tuple: only the output is traffic
    assert out["bytes_by_kind"]["collective-permute"] == 64 * 2
    assert out["bytes_by_kind"]["all-to-all"] == 4 * 32 * 4


def test_hlo_parser_on_compiled_module():
    """Parse a real compiled psum and find its all-reduce."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    from jax.sharding import PartitionSpec as P

    f = jax.jit(jax.shard_map(
        lambda a: jax.lax.psum(a, "x"), mesh=mesh, in_specs=P("x"),
        out_specs=P()))
    txt = f.lower(jnp.ones(8)).compile().as_text()
    out = hlo_lib.collective_bytes(txt)
    # single-device psum may be optimised away; just assert no crash and
    # sane structure
    assert "total_bytes" in out


def test_roofline_from_record():
    from repro.roofline import model as rl

    rec = {
        "status": "ok", "arch": "minitron-8b", "shape": "train_4k",
        "mesh": "16x16", "kind": "train", "devices": 256, "c": 2,
        "flops_per_device": 2e14, "bytes_accessed_per_device": 1e9,
        "collectives": {"total_bytes": 1e8},
        "memory": {"peak_bytes_per_device": 8 * 2**30},
    }
    r = rl.from_record(rec)
    assert r.dominant == "compute"
    assert 0 < r.roofline_fraction < 2.0
    assert r.useful_ratio > 0
