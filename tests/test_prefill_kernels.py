"""Interpret-mode parity suite for the two serving prefill kernels:

  * ``kernels/ragged_prefill.py`` — batched per-row positions via scalar
    prefetch (retires the block_fwd batched-positions fallback), checked
    against ``kernels.ref.block_attention``;
  * ``kernels/paged_prefill.py`` — suffix queries vs the page-table-indexed
    cached prefix, checked against the dense-gather reference path of
    ``kernels.dispatch.paged_prefill`` and, combined across shards, against
    the dense oracle.

Bit-level discipline: when the kernel's online softmax takes a *single*
accumulation step per row (one K tile / one live page) it executes the
exact instruction sequence of the reference (same max/exp/sum/divide order
in f32) and the comparison is ``np.array_equal`` — bit identical. Across
multiple tiles/pages the online rescaling reorders floating-point sums, so
those cases assert a tight ``allclose`` (2e-5, the repo-wide kernel
tolerance) plus *exact* dead-row semantics: rows with no visible key must
finalise to precisely (o=0, lse=NEG_INF), or the downstream lse-combines
drift.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import combine
from repro.core.combine import NEG_INF
from repro.kernels import dispatch, ref
from repro.kernels.paged_prefill import paged_prefill_attention
from repro.kernels.ragged_prefill import choose_block, ragged_prefill_fwd


def _qkv(key, B, Sq, Sk, Hq, Hkv, D, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, Hq, D), dtype)
    k = jax.random.normal(kk, (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(kv, (B, Sk, Hkv, D), dtype)
    return q, k, v


def _ragged_positions(B, Sq, Sk, lens):
    """The engine's validity encoding: row b sees ``lens[b]`` keys at
    positions 0..lens[b]-1; the rest are pushed past every query."""
    lens = jnp.asarray(lens, jnp.int32)
    idx = jnp.arange(Sk, dtype=jnp.int32)
    pos_k = jnp.where(idx[None] < lens[:, None], idx[None], Sq + Sk)
    base = jnp.maximum(lens - 1, 0)          # queries start at the last key
    pos_q = base[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    return pos_q.astype(jnp.int32), pos_k.astype(jnp.int32)


def test_choose_block():
    assert choose_block(128, 128) == 128
    assert choose_block(256, 128) == 128
    assert choose_block(24, 128) == 24       # non-pow2, single tile
    assert choose_block(192, 128) == 96      # non-pow2, two tiles
    assert choose_block(17, 8) == 1          # prime vs small pref
    assert choose_block(1, 128) == 1


# ---------------------------------------------------------------------------
# ragged-prefill kernel vs ref.block_attention
# ---------------------------------------------------------------------------

# (name, B, Sq, Sk, Hq, Hkv, D, window, lens) — lens None = full causal.
# single_acc: Sk tiles into one K block -> bit-identical to the reference.
RAGGED_CASES = [
    ("mha_single_tile", 2, 16, 16, 2, 2, 32, None, [16, 7]),
    ("gqa", 2, 16, 16, 4, 2, 32, None, [16, 5]),
    ("len_zero_and_full", 3, 8, 8, 2, 1, 16, None, [0, 8, 3]),
    ("sliding_window", 2, 16, 32, 2, 2, 32, 4, [32, 11]),
    ("non_pow2_rows", 2, 24, 24, 2, 2, 16, None, [24, 13]),
    ("multi_tile", 1, 16, 256, 2, 1, 32, None, None),
    ("multi_tile_non_pow2", 1, 16, 192, 2, 2, 16, None, [192, ]),
    ("multi_tile_ragged", 2, 8, 256, 2, 2, 16, None, [256, 130]),
]


@pytest.mark.parametrize(
    "name,B,Sq,Sk,Hq,Hkv,D,window,lens",
    RAGGED_CASES, ids=[c[0] for c in RAGGED_CASES])
def test_ragged_prefill_matches_ref(name, B, Sq, Sk, Hq, Hkv, D, window,
                                    lens):
    key = jax.random.PRNGKey(hash(name) % (2 ** 31))
    q, k, v = _qkv(key, B, Sq, Sk, Hq, Hkv, D)
    if lens is None:
        pos_q = jnp.broadcast_to(
            (Sk - Sq) + jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
        pos_k = jnp.broadcast_to(
            jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))
        lens_arr = [Sk] * B
    else:
        lens_arr = list(lens) + [Sk] * (B - len(lens))
        pos_q, pos_k = _ragged_positions(B, Sq, Sk, lens_arr)
    o_pl, lse_pl = ragged_prefill_fwd(
        q, k, v, pos_q, pos_k, causal=True, window=window, interpret=True)
    o_ref, lse_ref = ref.block_attention(
        q, k, v, pos_q, pos_k, causal=True, window=window)
    o_pl, lse_pl = np.asarray(o_pl), np.asarray(lse_pl)
    o_ref, lse_ref = np.asarray(o_ref), np.asarray(lse_ref)

    single_acc = Sk // choose_block(Sk, 128) == 1
    if single_acc:
        # one accumulation step == the reference instruction sequence
        np.testing.assert_array_equal(o_pl, o_ref)
        np.testing.assert_array_equal(lse_pl, lse_ref)
    else:
        np.testing.assert_allclose(o_pl, o_ref, atol=2e-5, rtol=2e-5)
        live = lse_ref > NEG_INF / 2
        np.testing.assert_allclose(lse_pl[live], lse_ref[live],
                                   atol=2e-5, rtol=2e-5)
    # dead rows are exact regardless of tiling: (o=0, lse=NEG_INF)
    dead = lse_ref <= NEG_INF / 2
    assert np.array_equal(lse_pl <= NEG_INF / 2, dead)
    if dead.any():
        np.testing.assert_array_equal(
            o_pl[np.moveaxis(dead, -1, 1)], 0.0)


def test_ragged_prefill_len_zero_rows_are_dead():
    """A row whose every key is pushed past the queries (len = 0) must
    finalise to exactly (o=0, lse=NEG_INF) so combine_pair treats it as
    'no keys seen' rather than polluting the merge."""
    B, Sq, Sk, Hq, Hkv, D = 2, 8, 8, 2, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, Sq, Sk, Hq, Hkv, D)
    pos_q, pos_k = _ragged_positions(B, Sq, Sk, [0, 8])
    o, lse = ragged_prefill_fwd(q, k, v, pos_q, pos_k, causal=True,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(o)[0], 0.0)
    np.testing.assert_array_equal(np.asarray(lse)[0], np.float32(NEG_INF))
    assert np.all(np.asarray(lse)[1] > NEG_INF / 2)


def test_ragged_prefill_shared_positions_broadcast():
    """1-D (S,) positions broadcast to every row — same contract as ref."""
    B, S, H, D = 2, 16, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, S, H, H, D)
    pos = jnp.arange(S, dtype=jnp.int32)
    o1, lse1 = ragged_prefill_fwd(q, k, v, pos, pos, causal=True,
                                  interpret=True)
    o2, lse2 = ragged_prefill_fwd(
        q, k, v, jnp.broadcast_to(pos[None], (B, S)),
        jnp.broadcast_to(pos[None], (B, S)), causal=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(lse1), np.asarray(lse2))


def test_ragged_prefill_prefix_lm():
    """prefix_len (bidirectional prefix) flows through the tile mask."""
    B, S, H, D = 2, 16, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, S, H, H, D)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    o_pl, lse_pl = ragged_prefill_fwd(q, k, v, pos, pos, causal=True,
                                      prefix_len=6, interpret=True)
    o_ref, lse_ref = ref.block_attention(q, k, v, pos, pos, causal=True,
                                         prefix_len=6)
    np.testing.assert_array_equal(np.asarray(o_pl), np.asarray(o_ref))
    np.testing.assert_array_equal(np.asarray(lse_pl), np.asarray(lse_ref))


def test_dispatch_batched_fwd_routes_to_ragged_kernel():
    """dispatch.block_fwd(impl='pallas') with (B, S) positions returns the
    ragged kernel's result (not the ref fallback) and counts nothing."""
    B, S, H, D = 2, 8, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, S, H, H, D)
    pos_q, pos_k = _ragged_positions(B, S, S, [8, 3])
    dispatch.reset_pallas_fallbacks()
    o_d, lse_d = dispatch.block_fwd(q, k, v, pos_q, pos_k, causal=True,
                                    impl="pallas")
    assert dispatch.pallas_fallbacks() == {}
    o_k, lse_k = ragged_prefill_fwd(q, k, v, pos_q, pos_k, causal=True)
    np.testing.assert_array_equal(np.asarray(o_d), np.asarray(o_k))
    np.testing.assert_array_equal(np.asarray(lse_d), np.asarray(lse_k))


# ---------------------------------------------------------------------------
# paged-suffix prefill kernel vs the dense-gather reference
# ---------------------------------------------------------------------------

def _paged_fixture(key, *, B, Sq, sp, page_size, pages_loc, cached_lens,
                   Hq, Hkv, D, rank):
    """Round-robin scatter of a dense prefix into one shard's pool.

    Returns (q, pool_k, pool_v, table, cached_len) plus the dense per-shard
    gather ingredients so the reference path sees the same bytes. Rows may
    have fewer pages than the table width (unallocated = -1) and partial
    last pages (cached_len not page-aligned).
    """
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, Hq, D), jnp.float32)
    cl_max = max(cached_lens)
    # global dense prefix per row
    k_all = jax.random.normal(kk, (B, cl_max, Hkv, D), jnp.float32)
    v_all = jax.random.normal(kv, (B, cl_max, Hkv, D), jnp.float32)
    W = max(1, -(-(-(-cl_max // page_size)) // sp))
    pool_k = np.zeros((pages_loc, page_size, Hkv, D), np.float32)
    pool_v = np.zeros((pages_loc, page_size, Hkv, D), np.float32)
    table = np.full((B, W), -1, np.int32)
    next_page = 0
    for b, cl in enumerate(cached_lens):
        n_blocks = -(-cl // page_size)
        for blk in range(n_blocks):
            if blk % sp != rank:
                continue
            w = blk // sp
            page = next_page
            next_page += 1
            assert page < pages_loc
            table[b, w] = page
            lo = blk * page_size
            hi = min(lo + page_size, cl)
            pool_k[page, :hi - lo] = np.asarray(k_all[b, lo:hi])
            pool_v[page, :hi - lo] = np.asarray(v_all[b, lo:hi])
    cached_len = np.asarray(cached_lens, np.int32)
    return (q, jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(table), jnp.asarray(cached_len), k_all, v_all)


# (name, B, Sq, sp, page_size, cached_lens, window)
PAGED_CASES = [
    ("single_shard", 2, 8, 1, 8, [24, 16], None),
    ("partial_pages", 2, 8, 1, 8, [13, 21], None),
    ("empty_prefix", 2, 8, 1, 8, [0, 16], None),
    ("multi_shard_rank", 2, 8, 4, 4, [29, 7], None),
    ("windowed", 1, 8, 1, 8, [32], 6),
    ("non_pow2_suffix", 1, 12, 2, 4, [17], None),
]


@pytest.mark.parametrize(
    "name,B,Sq,sp,page_size,cached_lens,window",
    PAGED_CASES, ids=[c[0] for c in PAGED_CASES])
def test_paged_prefill_matches_dense_gather(name, B, Sq, sp, page_size,
                                            cached_lens, window):
    """Kernel vs dispatch's dense-gather ref path, per shard rank — partial
    pages, unallocated entries and empty prefixes included."""
    Hq, Hkv, D = 4, 2, 16
    for rank in range(sp):
        q, pool_k, pool_v, table, cached_len, _, _ = _paged_fixture(
            jax.random.PRNGKey(hash(name) % (2 ** 31)), B=B, Sq=Sq, sp=sp,
            page_size=page_size, pages_loc=32, cached_lens=cached_lens,
            Hq=Hq, Hkv=Hkv, D=D, rank=rank)
        o_pl, lse_pl = paged_prefill_attention(
            q, pool_k, pool_v, table, cached_len, jnp.asarray(rank),
            sp=sp, page_size=page_size, window=window, interpret=True)
        o_ref, lse_ref = dispatch.paged_prefill(
            q, pool_k, pool_v, table, cached_len, jnp.asarray(rank),
            sp=sp, page_size=page_size, window=window, impl="ref")
        o_pl, lse_pl = np.asarray(o_pl), np.asarray(lse_pl)
        o_ref, lse_ref = np.asarray(o_ref), np.asarray(lse_ref)

        live_pages = max(
            sum(1 for blk in range(-(-cl // page_size)) if blk % sp == rank)
            for cl in cached_lens)
        if live_pages <= 1:
            # at most one accumulation step per row: bit-identical
            np.testing.assert_array_equal(o_pl, o_ref, err_msg=f"rank {rank}")
            np.testing.assert_array_equal(lse_pl, lse_ref)
        else:
            np.testing.assert_allclose(o_pl, o_ref, atol=2e-5, rtol=2e-5,
                                       err_msg=f"rank {rank}")
            live = lse_ref > NEG_INF / 2
            np.testing.assert_allclose(lse_pl[live], lse_ref[live],
                                       atol=2e-5, rtol=2e-5)
        # dead rows exact: every row with no key on this shard
        dead = lse_ref <= NEG_INF / 2
        assert np.array_equal(lse_pl <= NEG_INF / 2, dead), f"rank {rank}"
        if dead.any():
            np.testing.assert_array_equal(o_pl[np.moveaxis(dead, -1, 1)], 0.0)


def test_paged_prefill_empty_prefix_all_dead():
    """cached_len = 0: no page is live, every row must be exactly
    (o=0, lse=NEG_INF) — the combine then keeps only the dense suffix
    partial, which is what makes chunk 0 == monolithic prefill."""
    q, pool_k, pool_v, table, cached_len, _, _ = _paged_fixture(
        jax.random.PRNGKey(9), B=2, Sq=8, sp=1, page_size=8, pages_loc=8,
        cached_lens=[0, 0], Hq=2, Hkv=2, D=16, rank=0)
    o, lse = paged_prefill_attention(
        q, pool_k, pool_v, table, cached_len, jnp.asarray(0), sp=1,
        page_size=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(o), 0.0)
    np.testing.assert_array_equal(np.asarray(lse), np.float32(NEG_INF))


def test_paged_prefill_combined_across_all_shards():
    """Prefix spanning every shard: the per-rank kernel partials, merged
    with combine_pair, equal full dense attention of the suffix queries
    over the whole prefix — layout, masking and lse all exact end-to-end."""
    B, Sq, sp, ps = 2, 8, 4, 4
    Hq, Hkv, D = 4, 2, 16
    cached_lens = [61, 35]                   # partial pages on most shards
    parts = []
    for rank in range(sp):
        q, pool_k, pool_v, table, cached_len, k_all, v_all = _paged_fixture(
            jax.random.PRNGKey(7), B=B, Sq=Sq, sp=sp, page_size=ps,
            pages_loc=32, cached_lens=cached_lens, Hq=Hq, Hkv=Hkv, D=D,
            rank=rank)
        o, lse = paged_prefill_attention(
            q, pool_k, pool_v, table, cached_len, jnp.asarray(rank),
            sp=sp, page_size=ps, interpret=True)
        parts.append((o, lse))
    o, lse = parts[0]
    for o2, lse2 in parts[1:]:
        o, lse = combine.combine_pair(o, lse, o2, lse2)

    # dense oracle: suffix queries (pos cached_len + i) over keys < cached_len
    cl_max = max(cached_lens)
    pos_k = jnp.broadcast_to(
        jnp.arange(cl_max, dtype=jnp.int32)[None], (B, cl_max))
    cl = jnp.asarray(cached_lens, jnp.int32)
    # invalid (>= cached_len) keys pushed past every query
    pos_k = jnp.where(pos_k < cl[:, None], pos_k, (cl + Sq)[:, None])
    pos_q = cl[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    o_ref, lse_ref = ref.block_attention(q, k_all, v_all, pos_q, pos_k,
                                         causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=2e-5, rtol=2e-5)
