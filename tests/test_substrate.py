"""Substrate tests: combine, optimizer, checkpoint, elastic, data, grads."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import combine
from repro.dist import checkpoint, elastic
from repro.optim import adamw, grad as grad_lib


# ---- lse combine ------------------------------------------------------------

@given(st.integers(0, 1000), st.integers(2, 6), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_property_combine_matches_joint_softmax(seed, sq, blocks):
    """Combining per-block (o, lse) over disjoint key blocks == softmax over
    the union — for random splits (associativity + exactness)."""
    from repro.kernels import ref

    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    B, H, D, Sk = 1, 2, 8, 4 * blocks
    q = jax.random.normal(kq, (B, sq, H, D))
    k = jax.random.normal(kk, (B, Sk, H, D))
    v = jax.random.normal(kv, (B, Sk, H, D))
    pos_q = jnp.arange(sq, dtype=jnp.int32) + Sk  # all keys visible (causal)
    pos_k = jnp.arange(Sk, dtype=jnp.int32)

    o_ref, lse_ref = ref.block_attention(q, k, v, pos_q, pos_k, causal=True)

    o_acc = jnp.zeros((B, sq, H, D), jnp.float32)
    lse_acc = jnp.full((B, H, sq), combine.NEG_INF, jnp.float32)
    for i in range(blocks):
        sl = slice(4 * i, 4 * (i + 1))
        o_i, lse_i = ref.block_attention(q, k[:, sl], v[:, sl], pos_q,
                                         pos_k[sl], causal=True)
        o_acc, lse_acc = combine.combine_pair(o_acc, lse_acc, o_i, lse_i)
    np.testing.assert_allclose(np.asarray(o_acc), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_acc), np.asarray(lse_ref),
                               atol=1e-5, rtol=1e-5)


def test_combine_dead_blocks():
    o = jnp.ones((1, 2, 2, 4))
    lse = jnp.zeros((1, 2, 2))
    dead_o = jnp.zeros_like(o)
    dead_lse = jnp.full_like(lse, combine.NEG_INF)
    oc, lc = combine.combine_pair(dead_o, dead_lse, o, lse)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(o))
    oc, lc = combine.combine_pair(dead_o, dead_lse, dead_o, dead_lse)
    assert np.all(np.asarray(lc) <= combine.NEG_INF / 2)
    assert np.all(np.asarray(oc) == 0)


# ---- optimizer --------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                            warmup_steps=0, decay_steps=10_000)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.apply(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert m["grad_norm"] > 0


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params, cfg)
    _, _, m = adamw.apply(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    assert np.isfinite(m["grad_norm"])


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                            decay_steps=100, min_lr_ratio=0.1)
    lr0 = adamw.schedule(jnp.asarray(1), cfg)
    lr_mid = adamw.schedule(jnp.asarray(10), cfg)
    lr_end = adamw.schedule(jnp.asarray(100), cfg)
    assert float(lr0) < float(lr_mid)
    assert abs(float(lr_mid) - 1.0) < 1e-6
    assert abs(float(lr_end) - 0.1) < 1e-3


# ---- gradient compression ---------------------------------------------------

def test_int8_roundtrip_error_bounded():
    g = {"a": jnp.linspace(-3, 7, 100)}
    d = grad_lib.int8_roundtrip(g)
    err = float(jnp.abs(d["a"] - g["a"]).max())
    assert err <= 7 / 127.0 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the accumulated compressed sum tracks the true
    sum (the residual stays bounded)."""
    g = {"a": jnp.array([0.001, -0.5, 2.0])}
    res = grad_lib.zeros_like_residual(g)
    total_c = jnp.zeros(3)
    for i in range(50):
        d, res = grad_lib.error_feedback_compress(g, res)
        total_c = total_c + d["a"]
    total_true = g["a"] * 50
    rel = float(jnp.abs(total_c - total_true).max() /
                jnp.abs(total_true).max())
    assert rel < 0.02


# ---- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    checkpoint.save(tmp_path, 7, tree)
    assert checkpoint.latest_step(tmp_path) == 7
    out = checkpoint.restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.zeros(3)}
    checkpoint.save(tmp_path, 1, tree)
    # a stale tmp dir from a "crashed" writer must not be visible
    (tmp_path / "step_00000002.tmp").mkdir()
    assert checkpoint.latest_step(tmp_path) == 1


def test_checkpoint_async(tmp_path):
    tree = {"a": jnp.ones(10)}
    t = checkpoint.save(tmp_path, 3, tree, blocking=False)
    t.join()
    assert checkpoint.latest_step(tmp_path) == 3


# ---- elastic ---------------------------------------------------------------

def test_plan_mesh_full_and_degraded():
    p = elastic.plan_mesh(512, model_axis_target=16)
    assert (p.data, p.model) == (32, 16)
    p = elastic.plan_mesh(511, model_axis_target=16)   # one node lost
    assert p.model == 16 and p.data == 31
    p = elastic.plan_mesh(12, model_axis_target=16)    # small pool
    assert p.devices <= 12 and p.model >= 4
    with pytest.raises(ValueError):
        elastic.plan_mesh(2, model_axis_target=16)


def test_straggler_detector_flags_persistent_slow():
    durations = [1.0] * 10 + [5.0] * 5
    ticks = []
    t = 0.0
    for d in durations:
        ticks.extend([t, t + d])
        t += d
    times = iter(ticks)
    det = elastic.StragglerDetector(window=10, threshold=2.0, patience=3,
                                    clock=lambda: next(times))
    flags = []
    for _ in durations:
        det.step_start()
        flags.append(det.step_end())
    assert not any(flags[:10])      # healthy phase: no false positives
    assert any(flags[10:])          # persistent slowdown flagged


# ---- data pipeline -----------------------------------------------------------

def test_synthetic_deterministic_and_zigzagged():
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.core import zigzag as zz
    from repro.data.pipeline import SyntheticLM

    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    src1 = SyntheticLM(cfg, shape, seed=1, sp_size=4)
    src2 = SyntheticLM(cfg, shape, seed=1, sp_size=4)
    b1, b2 = src1.get_batch(5), src2.get_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token in GLOBAL order: unshard and check
    pos = zz.make_positions(32, 4, "zigzag")
    toks = zz.unshard_tokens(b1["tokens"], pos, axis=1)
    labs = zz.unshard_tokens(b1["labels"], pos, axis=1)
    np.testing.assert_array_equal(labs[:, :-1], toks[:, 1:])


def test_token_file_source(tmp_path):
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenFile

    cfg = registry.get_smoke("h2o-danube-1.8b")
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    data = np.arange(3 * 2 * 17, dtype=np.uint16)
    f = tmp_path / "toks.bin"
    data.tofile(f)
    src = TokenFile(str(f), cfg, shape, sp_size=2)
    b0 = src.get_batch(0)
    b3 = src.get_batch(3)  # wraps around
    assert b0["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b0["tokens"], b3["tokens"])


# ---- scheduler ---------------------------------------------------------------

def test_scheduler_prefers_larger_c_when_comm_bound():
    from repro.core import scheduler as sch

    w = sch.AttnWorkload(batch=1, seq_len=512 * 1024, num_heads=32,
                         num_kv_heads=8, head_dim=128)
    # very slow links -> communication dominates -> big C wins
    slow = sch.ClusterModel(sp_size=16, link_bw=1e9)
    out = sch.schedule(w, slow)
    assert out["best"]["c"] >= 2
    # infinitely fast links -> compute bound -> C=1 is fine (no worse)
    fast = sch.ClusterModel(sp_size=16, link_bw=1e15, step_latency=0.0)
    out_f = sch.schedule(w, fast)
    costs = {g["c"]: g["total_s"] for g in out_f["grid"]
             if g["placement"] == "team_inner"}
    assert abs(costs[1] - min(costs.values())) / costs[1] < 0.05


def test_scheduler_profile_fn_hook():
    from repro.core import scheduler as sch

    w = sch.AttnWorkload(batch=1, seq_len=1024, num_heads=4, num_kv_heads=4,
                         head_dim=64)
    cl = sch.ClusterModel(sp_size=16)
    out = sch.schedule(w, cl, profile_fn=lambda c, p: abs(c - 2) + (p == "ring_inner") * 0.1)
    assert out["best"]["c"] == 2 and out["best"]["placement"] == "team_inner"
