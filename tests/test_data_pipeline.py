"""Dedicated tests for repro/data/pipeline.py: sequence-layout pack/shard
round-trips, (seed, step) determinism (the restore-from-checkpoint and
elastic-replan contract), the memory-mapped token-file source, and the
background prefetcher."""

import numpy as np
import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import zigzag as zz
from repro.data.pipeline import Prefetcher, SyntheticLM, TokenFile


def _cfg(vocab=256):
    return ModelConfig(name="t", family="dense", num_layers=1, d_model=8,
                       num_heads=2, num_kv_heads=2, d_ff=16,
                       vocab_size=vocab)


def _shape(seq=32, batch=2):
    return ShapeConfig("test", seq_len=seq, global_batch=batch, kind="train")


# ---------------------------------------------------------------------------
# layout round-trip: the perm is a bijection and inverts exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,sp", [("zigzag", 4), ("zigzag", 8),
                                       ("contiguous", 4), ("zigzag", 1)])
def test_pack_shard_round_trip(scheme, sp):
    src = SyntheticLM(_cfg(), _shape(), seed=3, seq_scheme=scheme,
                      sp_size=sp)
    assert sorted(src.perm.tolist()) == list(range(32)), "perm not a bijection"
    batch = src.get_batch(step=5)
    inv = np.argsort(src.perm)
    raw_tokens = src._tokens(5)
    assert (batch["tokens"][:, inv] == raw_tokens).all(), \
        "unsharding the layout must recover the packed stream"
    # labels are the next token in *global* position order
    unshard_labels = batch["labels"][:, inv]
    assert (unshard_labels[:, :-1] == raw_tokens[:, 1:]).all()
    # per-shard slices are exactly the positions zz assigns to each rank
    pos = zz.make_positions(32, sp, scheme)       # (sp, s_loc)
    s_loc = 32 // sp
    for r in range(sp):
        shard = batch["tokens"][:, r * s_loc:(r + 1) * s_loc]
        assert (shard == raw_tokens[:, pos[r]]).all(), f"rank {r} slice"


def test_determinism_and_elastic_resharding():
    a = SyntheticLM(_cfg(), _shape(), seed=7, sp_size=4)
    b = SyntheticLM(_cfg(), _shape(), seed=7, sp_size=4)
    for step in (0, 3, 11):
        ba, bb = a.get_batch(step), b.get_batch(step)
        assert (ba["tokens"] == bb["tokens"]).all()
        assert (ba["labels"] == bb["labels"]).all()
    assert not (a.get_batch(0)["tokens"] == a.get_batch(1)["tokens"]).all()
    assert not (SyntheticLM(_cfg(), _shape(), seed=8, sp_size=4)
                .get_batch(0)["tokens"] == a.get_batch(0)["tokens"]).all()
    # elastic contract: a different SP width re-shards the SAME stream
    wide = SyntheticLM(_cfg(), _shape(), seed=7, sp_size=8)
    inv4, inv8 = np.argsort(a.perm), np.argsort(wide.perm)
    assert (a.get_batch(4)["tokens"][:, inv4]
            == wide.get_batch(4)["tokens"][:, inv8]).all()


def test_frontend_emb_present_only_for_frontend_archs():
    cfg = _cfg()
    assert "frontend_emb" not in SyntheticLM(cfg, _shape()).get_batch(0)
    import dataclasses
    vlm = dataclasses.replace(cfg, frontend_stub="vision")
    batch = SyntheticLM(vlm, _shape()).get_batch(0)
    assert batch["frontend_emb"].shape == (2, 32, 8)


# ---------------------------------------------------------------------------
# TokenFile (memory-mapped packed tokens)
# ---------------------------------------------------------------------------

def test_token_file_round_trip(tmp_path):
    shape = _shape(seq=16, batch=2)
    rng = np.random.default_rng(0)
    # 3 batches of (seq+1) tokens per row, packed flat
    flat = rng.integers(0, 250, 3 * 2 * 17, dtype=np.uint16)
    path = tmp_path / "tokens.bin"
    flat.tofile(path)
    src = TokenFile(str(path), _cfg(), shape, sp_size=4)
    assert src.num_batches == 3
    inv = np.argsort(src.perm)
    for step in range(4):                         # step 3 wraps to batch 0
        batch = src.get_batch(step)
        chunk = flat[(step % 3) * 2 * 17:(step % 3 + 1) * 2 * 17]
        chunk = chunk.reshape(2, 17).astype(np.int32)
        assert (batch["tokens"][:, inv] == chunk[:, :-1]).all()
        assert (batch["labels"][:, inv] == chunk[:, 1:]).all(), \
            "labels must be the next token of the packed stream"
    assert (src.get_batch(0)["tokens"] == src.get_batch(3)["tokens"]).all()


def test_token_file_too_small_raises(tmp_path):
    path = tmp_path / "tiny.bin"
    np.arange(10, dtype=np.uint16).tofile(path)
    with pytest.raises(ValueError, match="too small"):
        TokenFile(str(path), _cfg(), _shape(seq=16, batch=2))


# ---------------------------------------------------------------------------
# Prefetcher
# ---------------------------------------------------------------------------

def test_prefetcher_sequential_and_matching():
    src = SyntheticLM(_cfg(), _shape(seq=16), seed=1, sp_size=2)
    pf = Prefetcher(src, start_step=4, depth=2)
    try:
        for expect in range(4, 9):
            step, batch = pf.next()
            assert step == expect
            assert (batch["tokens"] == src.get_batch(step)["tokens"]).all()
    finally:
        pf.stop()
