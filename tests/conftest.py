"""Test-session setup: src/ on sys.path and a gate for optional deps.

``hypothesis`` is optional: when the real library is installed it is used
unchanged; otherwise a minimal deterministic stand-in is registered so the
property tests still run (strategy corner values + a fixed pseudo-random
sample of the strategy space) instead of failing at collection. CI pins
real hypothesis; the stand-in keeps bare-container runs green.
"""

import os
import random
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_stub() -> None:
    class _Strategy:
        """Deterministic stand-in: ``corners()`` lists boundary examples,
        ``sample(rng)`` draws from the interior."""

        def corners(self):
            return []

        def sample(self, rng):
            raise NotImplementedError

        def map(self, f):
            return _Mapped(self, f)

        def flatmap(self, f):
            return _FlatMapped(self, f)

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def corners(self):
            return [self.lo, self.hi]

        def sample(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Just(_Strategy):
        def __init__(self, value):
            self.value = value

        def corners(self):
            return [self.value]

        def sample(self, rng):
            return self.value

    class _SampledFrom(_Strategy):
        def __init__(self, seq):
            self.seq = list(seq)

        def corners(self):
            return [self.seq[0], self.seq[-1]]

        def sample(self, rng):
            return rng.choice(self.seq)

    class _Tuples(_Strategy):
        def __init__(self, *strats):
            self.strats = strats

        def corners(self):
            lows = tuple(s.corners()[0] for s in self.strats)
            highs = tuple(s.corners()[-1] for s in self.strats)
            return [lows, highs]

        def sample(self, rng):
            return tuple(s.sample(rng) for s in self.strats)

    class _Mapped(_Strategy):
        def __init__(self, base, f):
            self.base, self.f = base, f

        def corners(self):
            return [self.f(c) for c in self.base.corners()]

        def sample(self, rng):
            return self.f(self.base.sample(rng))

    class _FlatMapped(_Strategy):
        def __init__(self, base, f):
            self.base, self.f = base, f

        def corners(self):
            out = []
            for c in self.base.corners():
                out.extend(self.f(c).corners())
            return out

        def sample(self, rng):
            return self.f(self.base.sample(rng)).sample(rng)

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = lambda min_value, max_value: _Integers(min_value,
                                                                 max_value)
    strategies.just = _Just
    strategies.sampled_from = _SampledFrom
    strategies.tuples = _Tuples

    def given(*strats):
        def deco(fn):
            # cap examples: the stand-in hits all corners anyway and
            # unjitted CPU examples are slow
            n = min(getattr(fn, "_max_examples", 12), 12)

            def run():
                examples = []
                for i in range(max(len(s.corners()) for s in strats)):
                    examples.append(tuple(
                        s.corners()[min(i, len(s.corners()) - 1)]
                        for s in strats))
                rng = random.Random(0)
                while len(examples) < n:
                    examples.append(tuple(s.sample(rng) for s in strats))
                for args in examples[:max(n, 2)]:
                    fn(*args)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run

        return deco

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
