"""Unit tests for the tiered KV store and disaggregated-serving plumbing:
HostTier LRU semantics, KVConnector spill/flush/reload/handoff against a
fake numpy "pool" (no devices), cost-aware prefix-cache eviction, host-hit
admission accounting, the spill-vs-recompute cost crossover, role-plan
validation, and eligible-restricted routing."""

import numpy as np
import pytest

from repro.configs import registry as arch_registry
from repro.engine import Request, Scheduler
from repro.engine.kv_connector import HostTier, KVConnector, _HostPage
from repro.engine.paged_cache import PagePool
from repro.gateway import PrefixCache, Router, block_hashes
from repro.gateway.gateway import Gateway
from repro.plan import ExecutionPlan, cost, make_role_plans, make_serve_plan

ARCH = "h2o-danube-1.8b"


# ---------------------------------------------------------------------------
# HostTier: committed-page LRU store (no devices)
# ---------------------------------------------------------------------------

def _hp(key, tokens=4):
    return _HostPage(key=key, chain_tokens=tokens, data=np.zeros(1))


def test_host_tier_capacity_lru():
    tier = HostTier(capacity_bytes=2 * 64, page_bytes=64)
    assert tier.capacity_pages == 2
    tier.put(_hp(1))
    tier.put(_hp(2))
    tier.get(1)                          # touch: 1 is now most recent
    dropped = tier.put(_hp(3))           # over capacity: LRU (2) goes
    assert dropped == 1 and tier.evicted_pages == 1
    assert tier.has(1) and tier.has(3) and not tier.has(2)
    assert tier.bytes_resident == 2 * 64


def test_host_tier_has_is_pure():
    tier = HostTier(capacity_bytes=2 * 64, page_bytes=64)
    tier.put(_hp(1))
    tier.put(_hp(2))
    tier.has(1)                          # probe must NOT touch LRU order
    assert tier.put(_hp(3)) == 1
    assert not tier.has(1)               # 1 stayed LRU and was evicted


def test_host_tier_put_dedupes():
    tier = HostTier(capacity_bytes=4 * 64, page_bytes=64)
    tier.put(_hp(1))
    assert tier.put(_hp(1)) == 0
    assert len(tier) == 1


def test_host_tier_rejects_bad_page_bytes():
    with pytest.raises(ValueError, match="page_bytes"):
        HostTier(capacity_bytes=64, page_bytes=0)


# ---------------------------------------------------------------------------
# KVConnector against a fake numpy pool (the two transfer islands are
# plain ndarray gathers/scatters — same shapes, no jit, no devices)
# ---------------------------------------------------------------------------

class _FakePool:
    """Stand-in for the engine's transfer islands: one (n_per, pages, ps,
    Hkv, hd) array; read gathers a bucket, write scatters one back."""

    def __init__(self, n_pages=8, ps=4, hkv=2, hd=3):
        self.arr = np.arange(n_pages * ps * hkv * hd, dtype=np.float32) \
            .reshape(1, n_pages, ps, hkv, hd)

    def read(self, idx):
        return self.arr[:, np.clip(idx, 0, None)].copy()

    def write(self, idx, data):
        for j, g in enumerate(np.asarray(idx)):
            if g >= 0:
                self.arr[:, g] = data[:, j]


def _connector(fake, capacity_pages=8, spill_fn=None):
    page_bytes = fake.arr[:, 0].nbytes
    return KVConnector(read_fn=fake.read, write_fn=fake.write, bucket=2,
                       page_size=4, pages_per_shard=fake.arr.shape[1],
                       page_bytes=page_bytes,
                       capacity_bytes=capacity_pages * page_bytes,
                       spill_fn=spill_fn)


def test_torn_spill_not_hittable_until_flush():
    fake = _FakePool()
    conn = _connector(fake)
    assert conn.spill(key=11, page=(0, 2), chain_tokens=4)
    assert not conn.has(11)              # staged only: a torn spill can
    #                                      never satisfy a lookup
    assert conn.stats()["staged_pages"] == 1
    assert conn.flush() == 1
    assert conn.has(11)
    np.testing.assert_array_equal(conn.tier.get(11).data, fake.arr[:, 2])
    assert conn.stats()["spill_pages"] == 1


def test_spill_captures_value_before_page_reuse():
    fake = _FakePool()
    conn = _connector(fake)
    snapshot = fake.arr[:, 2].copy()
    conn.spill(key=11, page=(0, 2), chain_tokens=4)
    fake.arr[:, 2] = -1.0                # page recycled before the flush
    conn.flush()
    np.testing.assert_array_equal(conn.tier.get(11).data, snapshot)


def test_spill_dedupe_staged_and_committed():
    fake = _FakePool()
    conn = _connector(fake)
    assert conn.spill(key=11, page=(0, 2), chain_tokens=4)
    assert not conn.spill(key=11, page=(0, 2), chain_tokens=4)  # staged dup
    conn.flush()
    assert not conn.spill(key=11, page=(0, 3), chain_tokens=4)  # committed
    assert conn.stats()["spill_pages"] == 1


def test_spill_fn_gates_only_under_pressure():
    fake = _FakePool()
    gate = {"ok": False}
    conn = _connector(fake, capacity_pages=1,
                      spill_fn=lambda tokens: gate["ok"])
    # free capacity always admits, even with a refusing cost model
    assert conn.spill(key=1, page=(0, 0), chain_tokens=4)
    conn.flush()
    # at capacity the cost model decides
    assert not conn.spill(key=2, page=(0, 1), chain_tokens=4)
    assert conn.stats()["spills_skipped"] == 1
    gate["ok"] = True
    assert conn.spill(key=3, page=(0, 2), chain_tokens=8)
    conn.flush()                         # displaces the LRU committed page
    assert conn.stats()["host_evicted_pages"] == 1
    assert conn.has(3) and not conn.has(1)


def test_disabled_connector_never_spills():
    conn = _connector(_FakePool(), capacity_pages=0)
    assert not conn.enabled
    assert not conn.spill(key=1, page=(0, 0), chain_tokens=4)


def test_reload_roundtrip_and_missing_key():
    fake = _FakePool()
    conn = _connector(fake)
    want = {11: fake.arr[:, 1].copy(), 12: fake.arr[:, 2].copy(),
            13: fake.arr[:, 3].copy()}
    for key, page in ((11, 1), (12, 2), (13, 3)):
        conn.spill(key=key, page=(0, page), chain_tokens=4)
    conn.flush()
    fake.arr[:] = 0.0                    # device pages recycled
    # reload into fresh pages 5, 6, 7 — spans two transfer buckets
    conn.reload([(11, (0, 5)), (12, (0, 6)), (13, (0, 7))])
    for key, page in ((11, 5), (12, 6), (13, 7)):
        np.testing.assert_array_equal(fake.arr[:, page], want[key])
    assert conn.stats()["reload_pages"] == 3
    assert conn.has(11)                  # entries stay resident after reload
    with pytest.raises(RuntimeError, match="missing chain hash"):
        conn.reload([(999, (0, 4))])


def test_export_inject_handoff_between_pools():
    src, dst = _FakePool(), _FakePool()
    dst.arr[:] = 0.0
    a = _connector(src, capacity_pages=0)     # handoff works with tier off
    b = _connector(dst, capacity_pages=0)
    blocks = a.export([(0, 1), (0, 2), (0, 3)])
    assert len(blocks) == 3
    b.inject([(0, 4), (0, 5), (0, 6)], blocks)
    for s, d in ((1, 4), (2, 5), (3, 6)):
        np.testing.assert_array_equal(dst.arr[:, d], src.arr[:, s])
    assert a.stats()["handoff_out_pages"] == 3
    assert b.stats()["handoff_in_pages"] == 3


def test_connector_reset_drops_everything():
    fake = _FakePool()
    conn = _connector(fake)
    conn.spill(key=1, page=(0, 0), chain_tokens=4)
    conn.flush()
    conn.spill(key=2, page=(0, 1), chain_tokens=4)   # left staged
    conn.note_probe(2, 1)
    conn.reset()
    s = conn.stats()
    assert s["resident_pages"] == 0 and s["staged_pages"] == 0
    assert s["spill_pages"] == 0 and s["hit_tokens"] == 0
    assert conn.hit_rate == 0.0


# ---------------------------------------------------------------------------
# Cost-aware prefix-cache eviction (satellite: works with the tier off)
# ---------------------------------------------------------------------------

def _insert_chain(cache, pool, tokens):
    hashes = block_hashes(tokens, cache.page_size)
    pages = [(b % cache.sp, pool.alloc(b % cache.sp))
             for b in range(len(hashes))]
    cache.insert(hashes, pages)
    for s, p in pages:
        pool.decref(s, p)                # cache-only holds remain
    return hashes


def test_evict_cheap_shallow_before_expensive_deep():
    pool = PagePool(sp=1, pages_per_shard=8)
    cache = PrefixCache(pool, page_size=4, sp=1)
    deep = _insert_chain(cache, pool, list(range(12)))       # 3 blocks, old
    shallow = _insert_chain(cache, pool, [100, 101, 102, 103])  # 1, recent
    assert cache.evict(0, 1) == 1
    # the recent-but-cheap chain went; the deep expensive one survived
    assert cache.match_len(shallow) == 0
    assert cache.match_len(deep) == 3


def test_evict_quadratic_cost_fn_same_ordering():
    pool = PagePool(sp=1, pages_per_shard=8)
    cache = PrefixCache(pool, page_size=4, sp=1,
                        cost_fn=lambda t: float(t) ** 2)
    deep = _insert_chain(cache, pool, list(range(12)))
    shallow = _insert_chain(cache, pool, [100, 101, 102, 103])
    cache.evict(0, 1)
    assert cache.match_len(shallow) == 0 and cache.match_len(deep) == 3


def test_evict_lru_breaks_cost_ties():
    pool = PagePool(sp=1, pages_per_shard=8)
    cache = PrefixCache(pool, page_size=4, sp=1)
    old = _insert_chain(cache, pool, [1, 2, 3, 4])
    new = _insert_chain(cache, pool, [5, 6, 7, 8])
    cache.evict(0, 1)
    assert cache.match_len(old) == 0 and cache.match_len(new) == 1


def test_evict_offers_victim_to_connector_before_drop():
    pool = PagePool(sp=1, pages_per_shard=8)

    class _Rec:
        calls = []

        def spill(self, *, key, page, chain_tokens):
            # the pool page must still be held when the spill is staged
            _Rec.calls.append((key, tuple(page), chain_tokens,
                               pool.refs[tuple(page)]))
            return True

    cache = PrefixCache(pool, page_size=4, sp=1, connector=_Rec())
    hashes = _insert_chain(cache, pool, list(range(8)))
    cache.evict(0, 2)
    assert [(c[0], c[2]) for c in _Rec.calls] == \
        [(hashes[1], 8), (hashes[0], 4)]          # leaf-first, chain depth
    assert all(c[3] == 1 for c in _Rec.calls)     # spilled before release


# ---------------------------------------------------------------------------
# Scheduler admission with host-tier hits
# ---------------------------------------------------------------------------

class _StubConnector:
    enabled = True

    def __init__(self, keys):
        self.keys = set(keys)
        self.probes = []

    def has(self, key):
        return key in self.keys

    def note_probe(self, lookup_blocks, hit_blocks):
        self.probes.append((lookup_blocks, hit_blocks))


def test_admit_counts_host_hits_and_records_reloads():
    sched = Scheduler(max_slots=2, page_size=4, sp=1, pages_per_shard=8,
                      max_len=64)
    sched.prefix_cache = PrefixCache(sched.pool, page_size=4, sp=1)
    tokens = list(range(13))             # 3 full blocks + tail, usable=3
    hashes = block_hashes(tokens, 4)
    conn = _StubConnector(hashes[:2])    # blocks 0,1 live on host
    sched.connector = conn
    sched.enqueue(Request("r", tokens, 2))
    st, = sched.admit(0)
    assert st.cached_len == 8 and st.host_len == 8
    assert st.prefill_pos == 8           # suffix prefill starts past hits
    # host hits still consumed fresh pool pages (cheap, not free)
    assert len(st.pages) == 4 and sched.pool.pages_in_use() == 4
    assert [h for h, _ in st.pending_reload] == hashes[:2]
    assert [p for _, p in st.pending_reload] == st.pages[:2]
    assert conn.probes == [(3, 2)]


def test_blocked_admission_is_side_effect_free_with_host_hits():
    sched = Scheduler(max_slots=2, page_size=4, sp=1, pages_per_shard=4,
                      max_len=64)
    sched.prefix_cache = PrefixCache(sched.pool, page_size=4, sp=1)
    for _ in range(2):                   # live sequences pin half the pool
        sched.pool.alloc(0)
    tokens = list(range(13))             # needs 4 pages; only 2 are free
    conn = _StubConnector(block_hashes(tokens, 4))
    sched.connector = conn
    sched.enqueue(Request("r", tokens, 2))
    assert sched.admit(0) == []
    assert conn.probes == []             # no hit-rate skew
    assert sched.pool.pages_in_use() == 2
    assert len(sched.queue) == 1


# ---------------------------------------------------------------------------
# Spill-vs-recompute pricing (plan.cost)
# ---------------------------------------------------------------------------

def _cfg():
    return arch_registry.get_smoke(ARCH)


def test_spill_decision_fields_and_validation():
    cfg = _cfg()
    d = cost.spill_decision(cfg, chain_tokens=64, page_size=4)
    assert d["bytes"] == 64 * cost.kv_bytes_per_token(cfg)
    assert d["spill"] == (d["transfer_s"] < d["recompute_s"])
    with pytest.raises(ValueError, match="chain_tokens"):
        cost.spill_decision(cfg, chain_tokens=0)


def test_spill_threshold_matches_brute_force():
    cfg = _cfg()
    ps, max_blocks = 4, 256

    def brute(link_bw):
        for b in range(1, max_blocks + 1):
            if cost.spill_decision(cfg, chain_tokens=b * ps, page_size=ps,
                                   link_bw=link_bw)["spill"]:
                return b * ps
        return None

    for link_bw in (1e3, 1e6, 1e9, 1e12, 1e15):
        th = cost.spill_threshold_tokens(cfg, page_size=ps,
                                         max_tokens=max_blocks * ps,
                                         link_bw=link_bw)
        assert th == brute(link_bw), f"link_bw={link_bw}"
    # a faster link can only lower the crossover (monotone in bandwidth)
    ths = [cost.spill_threshold_tokens(cfg, page_size=ps,
                                       max_tokens=max_blocks * ps,
                                       link_bw=bw) or (max_blocks + 1) * ps
           for bw in (1e6, 1e9, 1e12, 1e15)]
    assert ths == sorted(ths, reverse=True)


def test_transfer_cost_linear_not_sp_divided():
    cfg = _cfg()
    a = cost.kv_transfer_cost(cfg, tokens=100)
    b = cost.kv_transfer_cost(cfg, tokens=200)
    assert b["bytes"] == 2 * a["bytes"]
    assert b["roundtrip_s"] == pytest.approx(2 * a["roundtrip_s"])
    assert a["roundtrip_s"] == pytest.approx(a["d2h_s"] + a["h2d_s"])


# ---------------------------------------------------------------------------
# Role plans + gateway validation (no engines are ever built)
# ---------------------------------------------------------------------------

def _role_plan(role, **kw):
    args = dict(arch=ARCH, n_devices=1, decode_batch=2, page_size=4,
                max_len=64, mesh_kind="local", prefix_cache=True)
    args.update(kw)
    return make_serve_plan(_cfg(), role=role, **args)


def test_role_plan_roundtrip():
    plan = _role_plan("prefill", host_tier_bytes=1 << 20)
    back = ExecutionPlan.from_dict(plan.to_dict())
    assert back.role == "prefill" and back.host_tier_bytes == 1 << 20


def test_role_plan_validation():
    with pytest.raises(ValueError, match="role"):
        _role_plan("bogus")
    with pytest.raises(ValueError, match="prefix_cache"):
        _role_plan("unified", prefix_cache=False, host_tier_bytes=1)


def test_make_role_plans():
    plans = make_role_plans(_cfg(), roles=["prefill", "decode"], n_devices=1,
                            arch=ARCH, decode_batch=2, page_size=4,
                            max_len=64, mesh_kind="local", prefix_cache=True)
    assert [p.role for p in plans] == ["prefill", "decode"]
    assert all(p.n_devices == 1 and p.replicas == 1 for p in plans)
    with pytest.raises(ValueError, match="roles"):
        make_role_plans(_cfg(), roles=[], n_devices=1, arch=ARCH)


def test_gateway_rejects_bad_role_topologies():
    prefill, decode = _role_plan("prefill"), _role_plan("decode")
    # model=None proves validation fires before any engine is built
    with pytest.raises(ValueError, match="unified"):
        Gateway(None, prefill)                    # single plan, wrong role
    with pytest.raises(ValueError, match="admit"):
        Gateway(None, None, plans=[decode])       # no entry replica
    with pytest.raises(ValueError, match="decode"):
        Gateway(None, None, plans=[prefill])      # nowhere to hand off
    with pytest.raises(ValueError, match="agree"):
        Gateway(None, None,
                plans=[prefill, _role_plan("decode", page_size=8)])


# ---------------------------------------------------------------------------
# Router: eligible-restricted routing
# ---------------------------------------------------------------------------

class _StubSched:
    def __init__(self, load):
        self.queue = [Request("q", [0] * load, 1)] if load else []

    def active(self):
        return []


class _StubEngine:
    prefix_cache = None

    def __init__(self, load):
        self.scheduler = _StubSched(load)


def test_router_respects_eligible():
    engines = [_StubEngine(5), _StubEngine(0)]
    r = Router(engines, prefix_aware=False, eligible=[0])
    req = Request("a", [1, 2, 3], 2)
    assert r.route(req) == 0             # engine 1 is idle but ineligible
    r2 = Router(engines, prefix_aware=False)
    assert r2.route(req) == 1            # default: least-loaded wins
