"""Paper Fig. 8 + Table 4: memory cost of StarTrail vs Ring Attention.

  (theory)   eqs. (5)-(7): peak activation memory PM_Ring = M + (Y+4)A,
             PM_Wall = M + (Y+3C+1)A -> relative overhead per C.
  (measured) compiled peak bytes (memory_analysis) of the attention island
             at C=1 vs C=2 on 8 host devices: the measured extra footprint
             must track the 3(C-1)A prediction.
  (table4)   supported sequence lengths: compute the paper's Table-4 style
             feasibility (fits-in-HBM) for the dry-run cells from
             results/dryrun (full-model numbers on v5e budgets).
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import startrail as st
from repro.roofline import hw


def theory(emit):
    # llama-30B case study from the paper's §3.2.2
    Y, C = 64, 4
    ring = Y + 4
    wall = Y + 3 * C + 1
    emit("fig8_theory_llama30b_c4", wall / ring,
         f"extra_mem_ratio={(wall - ring) / ring:.3f} (paper: <13.2%)")
    for c in (2, 4):
        emit(f"fig8_theory_generic_c{c}", (Y + 3 * c + 1) / (Y + 4),
             f"Y={Y}")


def measured(emit):
    if len(jax.devices()) < 8:
        emit("fig8_measured", 0, "skipped=needs 8 devices")
        return
    B, S, hq, hkv, d, p = 1, 8192, 8, 8, 64, 8
    peaks = {}
    for c in (1, 2):
        cfg = st.StarTrailConfig(seq_len=S, seq_scheme="zigzag", causal=True)
        r = p // (c * c)
        devs = np.array(jax.devices()[:p]).reshape(c, r, c)
        mesh = jax.sharding.Mesh(devs, cfg.axes)
        spec = P(None, cfg.axes, None, None)
        f = jax.jit(jax.shard_map(
            lambda q, k, v: st.startrail_attention(q, k, v, cfg),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec, check_vma=False))
        args = [jax.ShapeDtypeStruct((B, S, h, d), jnp.bfloat16)
                for h in (hq, hkv, hkv)]
        m = f.lower(*args).compile().memory_analysis()
        peaks[c] = (m.argument_size_in_bytes + m.output_size_in_bytes
                    + m.temp_size_in_bytes - m.alias_size_in_bytes)
    emit("fig8_measured_attn_island", peaks[2] / peaks[1],
         f"c1_MiB={peaks[1]/2**20:.1f},c2_MiB={peaks[2]/2**20:.1f}")


def table4(emit):
    results = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not results.exists():
        emit("tab4_fits", 0, "skipped=run launch.dryrun first")
        return
    for f in sorted(results.glob("*__single__c2.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        peak = rec["memory"]["peak_bytes_per_device"]
        fits = peak <= hw.HBM_BYTES
        emit(f"tab4_{rec['arch']}_{rec['shape']}", peak / 2**30,
             f"fits_16GiB_v5e={'yes' if fits else 'NO'}")


def run(emit):
    theory(emit)
    measured(emit)
    table4(emit)


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v},{d}"))
