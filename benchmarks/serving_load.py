"""Serving load benchmark: Poisson arrivals through the continuous-batching
engine vs. sequential single-request serving of the same workload.

Arrivals are Poisson in *engine-step* time (deterministic given --seed):
request i becomes visible to the scheduler once ``step >= arrival[i]``.
Both modes run on the same ``Engine`` instance (reset between phases) so
the compiled prefill/decode buckets are shared; a full untimed warmup pass
populates every bucket first, making the timed phases compile-free — the
numbers compare *steady-state serving*, not jit time.

Reports aggregate tokens/s, per-request latency (steps and seconds), batch
occupancy and page utilization, and writes the result JSON (default
``results/BENCH_serving.json``).

A second, reduced phase compares the two paged-decode kernels on the same
workload: ``kernel_impl='ref'`` (dense page gather + jnp oracle) vs
``kernel_impl='pallas'`` (the page-table-indexed Pallas kernel —
interpret mode on CPU, so its CPU tokens/s is diagnostic only; the bit
that matters off-TPU is **bit-identical tokens** and **zero recompiles
after warmup**, both of which ``--check`` gates). Per-kernel tokens/s and
the analytical byte/flop pricing (`plan.cost.decode_step_cost`) land in
the ``kernels`` section of the JSON.

  PYTHONPATH=src python benchmarks/serving_load.py --smoke
  PYTHONPATH=src python benchmarks/serving_load.py --smoke --check  # CI gate
"""

import argparse
import json
import os
import time


def build_workload(engine, args):
    import numpy as np

    from repro.engine import Request

    rng = np.random.default_rng(args.seed)
    inter = rng.exponential(1.0 / args.rate, args.requests)
    arrivals = np.floor(np.cumsum(inter)).astype(int)
    vocab = engine.cfg.vocab_size
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        gen = int(rng.integers(args.min_gen, args.max_gen + 1))
        temperature, top_k, top_p = 0.0, 0, 1.0
        if args.sampled and i % 2 == 1:
            temperature, top_k, top_p = 0.8, 32, 0.95
        reqs.append(Request(
            uid=f"req{i}", tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, temperature=temperature, top_k=top_k,
            top_p=top_p, seed=args.seed + i))
    return list(zip(arrivals.tolist(), reqs))


def run_continuous(engine, workload, max_steps=100_000):
    """Feed requests at their arrival steps; drain with continuous batching."""
    pending = sorted(workload, key=lambda p: p[0])
    arrived_at = {}
    t0 = time.monotonic()
    i = 0
    while pending or not engine.idle():
        step = engine.metrics.steps
        while pending and pending[0][0] <= step:
            _, req = pending.pop(0)
            arrived_at[req.uid] = step
            engine.add_request(req)
        engine.step()
        i += 1
        if i > max_steps:
            raise RuntimeError("continuous phase did not drain")
    wall = time.monotonic() - t0
    out = engine.collect()
    lat_steps = [st.done_step - arrived_at[uid]
                 for uid, st in engine.scheduler.finished.items()] or [0]
    return {
        "wall_s": wall,
        "tokens": engine.metrics.tokens_out,
        "tokens_per_s": engine.metrics.tokens_out / wall,
        "steps": engine.metrics.steps,
        "occupancy": engine.metrics.to_dict()["occupancy"],
        "page_utilization": engine.metrics.to_dict()["page_utilization"],
        "latency_steps_mean": sum(lat_steps) / len(lat_steps),
        "latency_steps_max": max(lat_steps),
        "decode_compiles": engine.metrics.decode_compiles,
        "prefill_compiles": engine.metrics.prefill_compiles,
    }, out


def run_sequential(engine, workload):
    """Serve each request alone, back-to-back. Only serving time is summed
    — the engine reset between requests (pool reallocation) is bookkeeping
    the continuous phase doesn't pay either, so it stays untimed."""
    out = {}
    wall = 0.0
    tokens = steps = 0
    for _, req in sorted(workload, key=lambda p: p[0]):
        engine.reset()
        engine.add_request(req)
        t0 = time.monotonic()
        out.update(engine.run())
        wall += time.monotonic() - t0
        tokens += engine.metrics.tokens_out
        steps += engine.metrics.steps
    return {
        "wall_s": wall,
        "tokens": tokens,
        "tokens_per_s": tokens / wall,
        "steps": steps,
    }, out


def run_kernel_compare(args, workload):
    """Same (reduced) workload through both paged-decode kernels.

    Each kernel gets its own engine (fresh compile caches), an untimed
    warmup pass, then a timed replay — so the numbers are steady-state and
    the replay must add zero compiles. Returns the per-kernel stats plus
    the cross-kernel output comparison.
    """
    from repro.engine import EngineConfig, build_engine

    sub = sorted(workload, key=lambda p: p[0])[:args.kernel_requests]
    out = {}
    stats = {}
    for kern in ("ref", "pallas"):
        engine = build_engine(
            args.arch, smoke=args.smoke, c=args.c, kernel=kern,
            eng=EngineConfig(max_slots=args.max_slots,
                             page_size=args.page_size,
                             pages_per_shard=args.pages_per_shard,
                             max_len=args.max_len))
        run_continuous(engine, sub)          # untimed warmup
        engine.reset()
        compiles0 = (engine.metrics.prefill_compiles,
                     engine.metrics.decode_compiles)
        timed, toks = run_continuous(engine, sub)
        compiles1 = (engine.metrics.prefill_compiles,
                     engine.metrics.decode_compiles)
        out[kern] = toks
        stats[kern] = {
            "tokens_per_s": timed["tokens_per_s"],
            "wall_s": timed["wall_s"],
            "tokens": timed["tokens"],
            "compiles_after_warmup": compiles1 == compiles0,
        }
        # analytical decode pricing at this phase's shape (per step)
        from repro.plan import cost as plan_cost

        stats[kern]["analytical"] = plan_cost.decode_step_cost(
            engine.cfg, batch=args.max_slots, cache_len=args.max_len,
            sp=engine.sp, page_size=args.page_size, kernel=kern)
    stats["outputs_identical"] = out["ref"] == out["pallas"]
    stats["requests"] = len(sub)
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--c", type=int, default=1)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--min-prompt", type=int, default=3)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--min-gen", type=int, default=4)
    ap.add_argument("--max-gen", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages-per-shard", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--sampled", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="every other request samples (T=0.8, k=32, p=0.95); "
                         "--no-sampled for a pure-greedy workload")
    ap.add_argument("--kernel-requests", type=int, default=3,
                    help="requests in the ref-vs-pallas kernel phase "
                         "(0 disables it; interpret mode is slow on CPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/BENCH_serving.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless continuous beats sequential "
                         "and batched == solo outputs")
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.smoke:
        args.requests = min(args.requests, 8)

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    from repro.engine import EngineConfig, build_engine

    engine = build_engine(
        args.arch, smoke=args.smoke, c=args.c,
        eng=EngineConfig(max_slots=args.max_slots, page_size=args.page_size,
                         pages_per_shard=args.pages_per_shard,
                         max_len=args.max_len))
    workload = build_workload(engine, args)

    # untimed warmup pass: populates every prefill/decode bucket
    warm, _ = run_continuous(engine, workload)
    engine.reset()
    compiles0 = (engine.metrics.prefill_compiles,
                 engine.metrics.decode_compiles)

    cont, cont_out = run_continuous(engine, workload)
    engine.reset()
    seq, seq_out = run_sequential(engine, workload)
    compiles1 = (engine.metrics.prefill_compiles,
                 engine.metrics.decode_compiles)

    kernels = (run_kernel_compare(args, workload)
               if args.kernel_requests > 0 else None)

    identical = cont_out == seq_out
    result = {
        "bench": "serving_load",
        "arch": args.arch,
        "smoke": args.smoke,
        "devices": args.devices,
        "c": args.c,
        "workload": {
            "requests": args.requests, "rate": args.rate,
            "prompt_len": [args.min_prompt, args.max_prompt],
            "gen": [args.min_gen, args.max_gen],
            "sampled": args.sampled, "seed": args.seed,
        },
        "engine": {"max_slots": args.max_slots, "page_size": args.page_size,
                   "pages_per_shard": args.pages_per_shard,
                   "max_len": args.max_len},
        "continuous": cont,
        "sequential": seq,
        "speedup": cont["tokens_per_s"] / seq["tokens_per_s"],
        "outputs_identical_to_solo": identical,
        "compiles_after_warmup": compiles1 == compiles0,
        "kernels": kernels,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps(result, indent=2, sort_keys=True))
    print(f"[serving_load] continuous {cont['tokens_per_s']:.2f} tok/s vs "
          f"sequential {seq['tokens_per_s']:.2f} tok/s "
          f"(speedup {result['speedup']:.2f}x), outputs identical: "
          f"{identical}, wrote {args.out}")
    if kernels is not None:
        print(f"[serving_load] kernels: "
              f"ref {kernels['ref']['tokens_per_s']:.2f} tok/s vs "
              f"pallas(interpret) {kernels['pallas']['tokens_per_s']:.2f} "
              f"tok/s, identical: {kernels['outputs_identical']}")
    if args.check:
        assert identical, "batched outputs diverged from solo serving"
        assert result["compiles_after_warmup"], "recompiled after warmup"
        assert result["speedup"] > 1.0, (
            f"continuous batching slower than sequential: "
            f"{result['speedup']:.2f}x")
        if kernels is not None:
            assert kernels["outputs_identical"], (
                "paged-decode kernel tokens diverged from the ref path")
            for kern in ("ref", "pallas"):
                assert kernels[kern]["compiles_after_warmup"], (
                    f"{kern} paged-kernel path recompiled after warmup")
    return result


if __name__ == "__main__":
    main()
