"""Serving load benchmark: Poisson arrivals through the continuous-batching
engine vs. sequential single-request serving of the same workload.

Arrivals are Poisson in *engine-step* time (deterministic given --seed):
request i becomes visible to the scheduler once ``step >= arrival[i]``.
Both modes run on the same ``Engine`` instance (reset between phases) so
the compiled prefill/decode buckets are shared; a full untimed warmup pass
populates every bucket first, making the timed phases compile-free — the
numbers compare *steady-state serving*, not jit time.

Reports aggregate tokens/s, per-request latency (steps and seconds), batch
occupancy and page utilization, and writes the result JSON (default
``results/BENCH_serving.json``). Latency quantiles (TTFT and inter-token
p50/p95/p99) come from the engine's ``repro.obs`` histograms — the same
fixed-bucket series a Prometheus scrape would see — and the ``--check``
zero-recompile gates likewise read the compile counters back off the
*exported* metric surface (Prometheus text round-trip), not in-process
attributes.

A second, reduced phase compares the two paged-decode kernels on the same
workload: ``kernel_impl='ref'`` (dense page gather + jnp oracle) vs
``kernel_impl='pallas'`` (the page-table-indexed Pallas kernel —
interpret mode on CPU, so its CPU tokens/s is diagnostic only; the bit
that matters off-TPU is **bit-identical tokens** and **zero recompiles
after warmup**, both of which ``--check`` gates). Per-kernel tokens/s and
the analytical byte/flop pricing (`plan.cost.decode_step_cost`) land in
the ``kernels`` section of the JSON.

A third, prefix-heavy phase (``prefix`` section of the JSON) drives Poisson
arrivals sharing a long system prompt through the ``repro.gateway`` serving
gateway, prefix cache ON vs OFF (cache-off replays the cache-on routing so
tokens compare bit-for-bit): hit rate, prefill tokens saved, per-phase
tokens/s and the analytical capacity pricing
(``plan.cost.prefix_cache_value``). ``--check`` additionally gates
bit-identical cached-vs-cold tokens, hit rate > 0, >50% prefill-token
savings, a tokens/s improvement, and zero recompiles after warmup.

A fifth phase (``offload`` section) cycles Poisson arrivals over several
shared-prompt *families* whose combined KV exceeds an undersized device
page pool, pinned-host KV tier ON vs OFF (`engine.kv_connector`): with
the tier on, prefix-cache evictions spill to host and returning families
reload instead of re-prefilling. ``--check`` gates a strictly higher
prefix hit rate AND tokens/s with the tier on, bit-identical tokens, a
nonzero spill/reload count, and zero recompiles (including the transfer
islands) after warmup; the analytical transfer-vs-recompute crossover
(``plan.cost.spill_decision`` / ``spill_threshold_tokens``) lands in the
JSON alongside.

A fourth phase (``chunked`` section) replays a mixed long/short Poisson
workload with chunked prefill ON vs OFF (one engine each, shared params).
Step time is priced on an *analytical clock* (``plan.cost``): CPU wall
time cannot see the shorter per-step critical path chunking buys, so each
step costs its decode launch plus each ``engine.last_step_prefills`` entry
priced by ``prefill_step_cost``. ``--check`` gates bit-identical tokens,
zero recompiles after warmup, and a lower p99 inter-token gap with
chunking ON.

  PYTHONPATH=src python benchmarks/serving_load.py --smoke
  PYTHONPATH=src python benchmarks/serving_load.py --smoke --check  # CI gate
"""

import argparse
import json
import os
import time


def exported_transfer_compiles(registry):
    """Host-transfer island compiles (read/write pages) off the exported
    metric surface — the offload gate requires these to stay flat after
    warmup too: one fixed transfer bucket shape, compiled once."""
    from repro import obs

    parsed = obs.parse_prometheus(registry.render_prometheus())
    return sum(v for (name, _), v in parsed.items()
               if name == "engine_transfer_compiles_total")


def exported_compiles(registry):
    """(prefill, decode) bucket-compile totals read back off the *exported*
    metric surface: render the obs registry to Prometheus text and parse
    it, so the zero-recompile gate checks exactly what a scraper would
    see rather than the in-process attribute shims. Sums over labels, so
    a gateway's shared registry aggregates its replicas."""
    from repro import obs

    parsed = obs.parse_prometheus(registry.render_prometheus())
    pf = sum(v for (name, _), v in parsed.items()
             if name == "engine_prefill_compiles_total")
    dc = sum(v for (name, _), v in parsed.items()
             if name == "engine_decode_compiles_total")
    return pf, dc


def build_workload(engine, args):
    import numpy as np

    from repro.engine import Request

    rng = np.random.default_rng(args.seed)
    inter = rng.exponential(1.0 / args.rate, args.requests)
    arrivals = np.floor(np.cumsum(inter)).astype(int)
    vocab = engine.cfg.vocab_size
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        gen = int(rng.integers(args.min_gen, args.max_gen + 1))
        temperature, top_k, top_p = 0.0, 0, 1.0
        if args.sampled and i % 2 == 1:
            temperature, top_k, top_p = 0.8, 32, 0.95
        reqs.append(Request(
            uid=f"req{i}", tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, temperature=temperature, top_k=top_k,
            top_p=top_p, seed=args.seed + i))
    return list(zip(arrivals.tolist(), reqs))


def run_continuous(engine, workload, max_steps=100_000):
    """Feed requests at their arrival steps; drain with continuous batching."""
    pending = sorted(workload, key=lambda p: p[0])
    arrived_at = {}
    t0 = time.monotonic()
    i = 0
    while pending or not engine.idle():
        step = engine.metrics.steps
        while pending and pending[0][0] <= step:
            _, req = pending.pop(0)
            arrived_at[req.uid] = step
            engine.add_request(req)
        engine.step()
        i += 1
        if i > max_steps:
            raise RuntimeError("continuous phase did not drain")
    wall = time.monotonic() - t0
    out = engine.collect()
    lat_steps = [st.done_step - arrived_at[uid]
                 for uid, st in engine.scheduler.finished.items()] or [0]
    return {
        "wall_s": wall,
        "tokens": engine.metrics.tokens_out,
        "tokens_per_s": engine.metrics.tokens_out / wall,
        "steps": engine.metrics.steps,
        "occupancy": engine.metrics.to_dict()["occupancy"],
        "page_utilization": engine.metrics.to_dict()["page_utilization"],
        "latency_steps_mean": sum(lat_steps) / len(lat_steps),
        "latency_steps_max": max(lat_steps),
        "decode_compiles": engine.metrics.decode_compiles,
        "prefill_compiles": engine.metrics.prefill_compiles,
        # TTFT / inter-token p50/p95/p99 off the obs histograms (wall
        # seconds; the engine reset before this phase cleared warmup's
        # observations, so these are the timed phase's alone)
        "latency": engine.metrics.latency_quantiles(),
    }, out


def run_sequential(engine, workload):
    """Serve each request alone, back-to-back. Only serving time is summed
    — the engine reset between requests (pool reallocation) is bookkeeping
    the continuous phase doesn't pay either, so it stays untimed."""
    out = {}
    wall = 0.0
    tokens = steps = 0
    for _, req in sorted(workload, key=lambda p: p[0]):
        engine.reset()
        engine.add_request(req)
        t0 = time.monotonic()
        out.update(engine.run())
        wall += time.monotonic() - t0
        tokens += engine.metrics.tokens_out
        steps += engine.metrics.steps
    return {
        "wall_s": wall,
        "tokens": tokens,
        "tokens_per_s": tokens / wall,
        "steps": steps,
    }, out


def run_kernel_compare(args, workload):
    """Same (reduced) workload through both paged-decode kernels.

    Each kernel gets its own engine (fresh compile caches), an untimed
    warmup pass, then a timed replay — so the numbers are steady-state and
    the replay must add zero compiles. Returns the per-kernel stats plus
    the cross-kernel output comparison.
    """
    from repro.engine import EngineConfig, build_engine

    sub = sorted(workload, key=lambda p: p[0])[:args.kernel_requests]
    out = {}
    stats = {}
    for kern in ("ref", "pallas"):
        engine = build_engine(
            args.arch, smoke=args.smoke, c=args.c, kernel=kern,
            eng=EngineConfig(max_slots=args.max_slots,
                             page_size=args.page_size,
                             pages_per_shard=args.pages_per_shard,
                             max_len=args.max_len))
        run_continuous(engine, sub)          # untimed warmup
        engine.reset()
        compiles0 = exported_compiles(engine.registry)
        timed, toks = run_continuous(engine, sub)
        compiles1 = exported_compiles(engine.registry)
        out[kern] = toks
        stats[kern] = {
            "tokens_per_s": timed["tokens_per_s"],
            "wall_s": timed["wall_s"],
            "tokens": timed["tokens"],
            "latency": timed["latency"],
            "compiles_after_warmup": compiles1 == compiles0,
        }
        # analytical decode pricing at this phase's shape (per step)
        from repro.plan import cost as plan_cost

        stats[kern]["analytical"] = plan_cost.decode_step_cost(
            engine.cfg, batch=args.max_slots, cache_len=args.max_len,
            sp=engine.sp, page_size=args.page_size, kernel=kern)
    stats["outputs_identical"] = out["ref"] == out["pallas"]
    stats["requests"] = len(sub)
    return stats


def build_prefix_workload(vocab, args):
    """Poisson arrivals all sharing one long system prompt (the StarTrail
    regime: enormous shared prefixes) with short unique tails."""
    import numpy as np

    from repro.engine import Request

    rng = np.random.default_rng(args.seed + 7)
    inter = rng.exponential(1.0 / args.rate, args.prefix_requests)
    arrivals = np.floor(np.cumsum(inter)).astype(int)
    shared = rng.integers(0, vocab, args.system_prompt).tolist()
    reqs = []
    for i in range(args.prefix_requests):
        tail = int(rng.integers(4, 13))
        gen = int(rng.integers(2, 5))       # prefill-dominated on purpose
        reqs.append(Request(
            uid=f"px{i}", tokens=shared + rng.integers(0, vocab, tail).tolist(),
            max_new_tokens=gen, seed=args.seed + 100 + i))
    return list(zip(arrivals.tolist(), reqs))


def run_gateway(gw, workload, pins=None, max_steps=100_000):
    """Drive a gateway through arrival-stamped requests; returns stats+out.

    ``pins`` replays recorded request->replica placements so a cache-off
    phase serves the identical per-replica workload (bit-comparability)."""
    pending = sorted(workload, key=lambda p: p[0])
    t0 = time.monotonic()
    ticks = 0
    while pending or not gw.idle():
        while pending and pending[0][0] <= ticks:
            _, req = pending.pop(0)
            gw.add_request(req, replica=None if pins is None
                           else pins[req.uid])
        gw.step()
        ticks += 1
        if ticks > max_steps:
            raise RuntimeError("gateway phase did not drain")
    wall = time.monotonic() - t0
    out = gw.collect()
    m = gw.metrics_dict()
    return {
        "wall_s": wall,
        "tokens": m["tokens_out"],
        "tokens_per_s": m["tokens_out"] / wall,
        "prefill_tokens_computed": m["prefill_tokens_computed"],
        "prefill_tokens_cached": m["prefill_tokens_cached"],
        "hit_rate": m["prefix_hit_rate"],
        "prefix_evictions": m["prefix_evictions"],
        "routed": m["routed"],
        "latency": gw.latency_quantiles(),
    }, out


def run_prefix_phase(args):
    """Shared-system-prompt workload, prefix cache ON vs OFF.

    Both gateways get an untimed warmup pass over the same workload (all
    prefill/suffix/decode buckets compile), reset, then a timed replay that
    must add zero compiles. The OFF phase replays the ON phase's routing so
    tokens are comparable bit-for-bit; cached prefill tokens are the ones
    the ON phase never forwarded through the model.
    """
    from repro.engine import EngineConfig
    from repro.gateway import build_gateway
    from repro.plan import cost as plan_cost

    gws = {}
    stats = {}
    outs = {}
    compiles0 = {}
    pins = None
    workload = None
    for mode in ("cached", "cold"):                  # build + warm both
        gw = build_gateway(
            args.arch, smoke=args.smoke, c=args.c,
            replicas=args.replicas, prefix_cache=(mode == "cached"),
            eng=EngineConfig(max_slots=args.max_slots,
                             page_size=args.page_size,
                             pages_per_shard=args.pages_per_shard,
                             max_len=args.max_len))
        if workload is None:
            workload = build_prefix_workload(gw.cfg.vocab_size, args)
        run_gateway(gw, workload, pins=pins)         # untimed warmup
        if mode == "cached":
            pins = dict(gw._owner)                   # replay placements
        compiles0[mode] = exported_compiles(gw.registry)
        gws[mode] = gw
    # best-of-N timed replays, cached/cold INTERLEAVED so ambient machine
    # noise hits both modes equally (the phases run in fractions of a
    # second on the smoke mesh — a single wall sample is scheduler noise)
    for _ in range(max(args.prefix_reps, 1)):
        for mode, gw in gws.items():
            gw.reset()
            rep, rep_out = run_gateway(gw, workload, pins=pins)
            assert outs.get(mode) is None or rep_out == outs[mode], \
                "replay diverged"
            outs[mode] = rep_out
            if mode not in stats or rep["wall_s"] < stats[mode]["wall_s"]:
                stats[mode] = rep
    for mode, gw in gws.items():
        stats[mode]["compiles_after_warmup"] = \
            exported_compiles(gw.registry) == compiles0[mode]
    total_prompt = (stats["cached"]["prefill_tokens_computed"]
                    + stats["cached"]["prefill_tokens_cached"])
    stats["outputs_identical"] = outs["cached"] == outs["cold"]
    stats["prefill_savings_frac"] = (
        stats["cached"]["prefill_tokens_cached"] / total_prompt
        if total_prompt else 0.0)
    stats["speedup"] = (stats["cached"]["tokens_per_s"]
                        / stats["cold"]["tokens_per_s"])
    stats["requests"] = args.prefix_requests
    stats["system_prompt"] = args.system_prompt
    stats["replicas"] = args.replicas
    cfg = gws["cached"].cfg
    plan = gws["cached"].plan
    stats["analytical"] = plan_cost.prefix_cache_value(
        cfg, prompt_len=args.system_prompt + 8,
        shared_len=args.system_prompt,
        requests=max(args.prefix_requests // args.replicas, 2),
        sp=plan.sp_size, page_size=plan.page_size,
        pages_per_shard=args.pages_per_shard, max_len=8)
    return stats


def build_offload_workload(vocab, args):
    """Poisson arrivals cycling over F prompt families whose combined KV
    working set exceeds the device page pool. Each family is one long
    shared prompt; requests carry a short unique tail. Round-robin family
    order means a family always returns *after* the other families have
    crowded its pages out of the pool — the regime where the pinned-host
    tier turns recompute misses into reload hits."""
    import numpy as np

    from repro.engine import Request

    rng = np.random.default_rng(args.seed + 23)
    inter = rng.exponential(1.0 / args.rate, args.offload_requests)
    arrivals = np.floor(np.cumsum(inter)).astype(int)
    fams = [rng.integers(0, vocab, args.family_prompt).tolist()
            for _ in range(args.offload_families)]
    reqs = []
    for i in range(args.offload_requests):
        tail = int(rng.integers(2, 7))
        gen = int(rng.integers(2, 5))
        reqs.append(Request(
            uid=f"of{i}",
            tokens=fams[i % len(fams)]
            + rng.integers(0, vocab, tail).tolist(),
            max_new_tokens=gen, seed=args.seed + 300 + i))
    return list(zip(arrivals.tolist(), reqs))


def run_offload_phase(args):
    """Family-cycling workload under pool pressure, host tier ON vs OFF.

    Both gateways run the identical single-replica plan with the prefix
    cache on and a page pool sized *below* the families' combined working
    set; the only difference is ``host_tier_bytes``. The ON gateway's
    evictions spill to pinned host memory and returning families reload
    instead of re-prefilling, so (gated under ``--check``) it must see a
    strictly higher prefix hit rate AND higher tokens/s than OFF, with
    bit-identical tokens and zero recompiles — including the transfer
    islands — after warmup.
    """
    from repro.configs import registry as arch_registry
    from repro.engine import EngineConfig
    from repro.gateway import build_gateway
    from repro.plan import cost as plan_cost, make_serve_plan

    cfg = (arch_registry.get_smoke(args.arch) if args.smoke
           else arch_registry.get(args.arch))
    gws = {}
    stats = {}
    outs = {}
    compiles0 = {}
    workload = None
    for mode in ("on", "off"):
        # a single-device submesh: host transfers then carry no collective
        # machinery per call, and recompute pays its full serial cost —
        # the same overhead balance a real deployment sees (one host DMA
        # link per device vs. SP-parallel recompute is priced separately
        # by the analytical section below)
        plan = make_serve_plan(
            cfg, arch=args.arch, n_devices=1,
            decode_batch=args.max_slots, page_size=args.page_size,
            max_len=args.max_len, mesh_kind="local", prefix_cache=True,
            host_tier_bytes=args.host_tier_bytes if mode == "on" else 0)
        gw = build_gateway(
            args.arch, smoke=args.smoke, plan=plan,
            eng=EngineConfig(max_slots=args.max_slots,
                             page_size=args.page_size,
                             pages_per_shard=args.offload_pages,
                             max_len=args.max_len))
        if workload is None:
            workload = build_offload_workload(gw.cfg.vocab_size, args)
        run_gateway(gw, workload)                    # untimed warmup
        compiles0[mode] = (exported_compiles(gw.registry),
                           exported_transfer_compiles(gw.registry))
        gws[mode] = gw
    # interleaved timed replays, best wall per mode (noise rejection —
    # same reasoning as the prefix phase)
    for _ in range(max(args.offload_reps, 1)):
        for mode, gw in gws.items():
            gw.reset()
            rep, rep_out = run_gateway(gw, workload)
            assert outs.get(mode) is None or rep_out == outs[mode], \
                "offload replay diverged"
            outs[mode] = rep_out
            rep["host_tier"] = {
                k: v for k, v in gw.stats()["host_tier"].items()
                if k != "per_replica"}
            if mode not in stats or rep["wall_s"] < stats[mode]["wall_s"]:
                stats[mode] = rep
    for mode, gw in gws.items():
        stats[mode]["compiles_after_warmup"] = (
            (exported_compiles(gw.registry),
             exported_transfer_compiles(gw.registry)) == compiles0[mode])
    stats["outputs_identical"] = outs["on"] == outs["off"]
    stats["hit_rate_gain"] = (stats["on"]["hit_rate"]
                              - stats["off"]["hit_rate"])
    stats["speedup"] = (stats["on"]["tokens_per_s"]
                        / stats["off"]["tokens_per_s"])
    stats["requests"] = args.offload_requests
    stats["families"] = args.offload_families
    stats["family_prompt"] = args.family_prompt
    stats["pages_per_shard"] = args.offload_pages
    stats["host_tier_bytes"] = args.host_tier_bytes
    # analytical transfer-vs-recompute pricing at the family chain length
    plan = gws["on"].plan
    stats["analytical"] = plan_cost.spill_decision(
        cfg, chain_tokens=args.family_prompt, sp=plan.sp_size,
        page_size=plan.page_size)
    stats["analytical"]["threshold_tokens"] = \
        plan_cost.spill_threshold_tokens(cfg, sp=plan.sp_size,
                                         page_size=plan.page_size)
    return stats


def build_chunked_workload(vocab, args):
    """Mixed long/short Poisson arrivals: short decode-heavy requests keep
    the batch busy while occasional long prompts arrive mid-stream — the
    regime where a monolithic prefill stalls every decoding neighbour."""
    import numpy as np

    from repro.engine import Request

    rng = np.random.default_rng(args.seed + 13)
    inter = rng.exponential(1.0 / max(args.rate, 0.1), args.chunk_requests)
    arrivals = np.floor(np.cumsum(inter)).astype(int)
    reqs = []
    for i in range(args.chunk_requests):
        if i % 3 == 2:                       # every third request is long
            plen = args.long_prompt
            gen = int(rng.integers(2, 5))
        else:
            plen = int(rng.integers(3, 9))
            gen = int(rng.integers(8, 17))
        reqs.append(Request(
            uid=f"ck{i}", tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, seed=args.seed + 200 + i))
    return list(zip(arrivals.tolist(), reqs))


def run_analytical_clock(engine, workload, *, decode_s, prefill_s,
                         max_steps=100_000):
    """Drive the engine while accumulating an *analytical* per-step clock.

    On CPU every device launch takes roughly constant wall time, so the
    latency benefit of chunking (shorter per-step critical path on real
    hardware) is invisible in wall seconds. Instead each step is priced
    with the plan.cost model: the decode launch (if one ran) plus one
    ``prefill_s(start, end)`` per entry in ``engine.last_step_prefills``.
    Token emission times on this clock give per-request inter-token gaps.
    """
    pending = sorted(workload, key=lambda p: p[0])
    clock = 0.0
    token_times = {}
    decode_steps0 = engine.metrics.decode_steps
    i = 0
    while pending or not engine.idle():
        step = engine.metrics.steps
        while pending and pending[0][0] <= step:
            _, req = pending.pop(0)
            engine.add_request(req)
        emitted = engine.step()
        dt = sum(prefill_s(s, e) for s, e in engine.last_step_prefills)
        if engine.metrics.decode_steps > decode_steps0:
            dt += decode_s
            decode_steps0 = engine.metrics.decode_steps
        clock += dt
        for uid, _ in emitted:
            token_times.setdefault(uid, []).append(clock)
        i += 1
        if i > max_steps:
            raise RuntimeError("chunked phase did not drain")
    gaps = sorted(t1 - t0 for times in token_times.values()
                  for t0, t1 in zip(times, times[1:]))
    p99 = gaps[int(0.99 * (len(gaps) - 1))] if gaps else 0.0
    return {
        "model_s": clock,
        "gaps": len(gaps),
        "p99_gap_s": p99,
        "max_gap_s": gaps[-1] if gaps else 0.0,
        "mean_gap_s": sum(gaps) / len(gaps) if gaps else 0.0,
    }, engine.collect()


def run_chunked_phase(args):
    """Mixed long/short workload, chunked prefill ON vs OFF.

    Both engines share one parameter set and serve the identical workload;
    each gets an untimed warmup pass (compiling every chunk/prefill/decode
    bucket), a reset, then a replay on the analytical clock. Gates (under
    --check): bit-identical tokens, zero recompiles after warmup, and a
    *lower p99 inter-token gap* with chunking ON — long prompts no longer
    stall their decoding neighbours for a whole monolithic prefill.
    """
    from repro.engine import EngineConfig, build_engine
    from repro.plan import cost as plan_cost

    common = dict(max_slots=args.max_slots, page_size=args.page_size,
                  pages_per_shard=args.pages_per_shard, max_len=args.max_len)
    engines = {}
    engines["off"] = build_engine(
        args.arch, smoke=args.smoke, c=args.c, eng=EngineConfig(**common))
    engines["on"] = build_engine(
        args.arch, smoke=args.smoke, c=args.c,
        eng=EngineConfig(prefill_chunk=args.prefill_chunk, **common),
        params=engines["off"].params)
    workload = build_chunked_workload(engines["off"].cfg.vocab_size, args)

    cfg = engines["off"].cfg
    sp = engines["off"].sp
    decode_s = plan_cost.decode_step_cost(
        cfg, batch=args.max_slots, cache_len=args.max_len, sp=sp,
        page_size=args.page_size, kernel="pallas")["total_s"]

    def prefill_s(start, end):
        return plan_cost.prefill_step_cost(
            cfg, prompt_len=end, cached_len=start, sp=sp,
            page_size=args.page_size)["total_s"]

    stats = {}
    outs = {}
    for mode, engine in engines.items():
        run_continuous(engine, workload)            # untimed warmup
        engine.reset()
        compiles0 = exported_compiles(engine.registry)
        rep, outs[mode] = run_analytical_clock(
            engine, workload, decode_s=decode_s, prefill_s=prefill_s)
        rep["compiles_after_warmup"] = \
            compiles0 == exported_compiles(engine.registry)
        rep["latency"] = engine.metrics.latency_quantiles()
        rep["steps"] = engine.metrics.steps
        rep["prefill_chunks"] = engine.metrics.prefill_chunks
        rep["pallas_fallbacks"] = engine.pallas_fallbacks()
        stats[mode] = rep
    stats["outputs_identical"] = outs["on"] == outs["off"]
    stats["p99_improvement"] = (
        stats["off"]["p99_gap_s"] / stats["on"]["p99_gap_s"]
        if stats["on"]["p99_gap_s"] else 0.0)
    stats["requests"] = args.chunk_requests
    stats["prefill_chunk"] = args.prefill_chunk
    stats["long_prompt"] = args.long_prompt
    stats["analytical"] = plan_cost.chunked_prefill_cost(
        cfg, prompt_len=args.long_prompt, chunk=args.prefill_chunk, sp=sp,
        page_size=args.page_size)
    return stats


def build_frontend_workload(vocab, args, n):
    """Deterministic mixed greedy/sampled request kwargs for the
    frontend phases (orchestrator submit signature)."""
    import numpy as np

    rng = np.random.default_rng(args.seed + 99)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        gen = int(rng.integers(args.min_gen, args.max_gen + 1))
        t, k, p = (0.8, 32, 0.95) if args.sampled and i % 2 else \
            (0.0, 0, 1.0)
        reqs.append(dict(prompt=rng.integers(0, vocab, plen).tolist(),
                         max_new_tokens=gen, temperature=t, top_k=k,
                         top_p=p, seed=args.seed + i))
    return reqs


def drive_orchestrator(orch, reqs, arrivals, *, cls=None,
                       max_steps=100_000):
    """Feed orchestrator submissions at their arrival steps and drive to
    drain. Returns (rids, wall_s, steps)."""
    pending = sorted(zip(arrivals, range(len(reqs))))
    rids = []
    step = 0
    t0 = time.monotonic()
    while pending or not orch.idle():
        while pending and pending[0][0] <= step:
            _, i = pending.pop(0)
            kw = dict(reqs[i])
            if cls is not None:
                kw["cls"] = cls
            rid = orch.submit(kw.pop("prompt"), kw.pop("max_new_tokens"),
                              **kw)
            assert isinstance(rid, int), f"frontend rejected: {rid}"
            rids.append(rid)
        orch.step()
        step += 1
        if step > max_steps:
            raise RuntimeError("frontend phase did not drain")
    return rids, time.monotonic() - t0, step


def _point_stats(orch, rids, wall_s):
    import numpy as np

    toks = sum(len(orch.streams[r].tokens) for r in rids)
    ttfts = [orch.streams[r].first_token_t - orch.streams[r].submitted_t
             for r in rids if orch.streams[r].first_token_t is not None]
    return {
        "requests": len(rids), "tokens": toks, "wall_s": wall_s,
        "tokens_per_s": toks / max(wall_s, 1e-9),
        "ttft_p50_s": float(np.quantile(ttfts, 0.5)) if ttfts else None,
        "ttft_p99_s": float(np.quantile(ttfts, 0.99)) if ttfts else None,
    }


def run_frontend_phase(args):
    """Process-separated frontend (``repro.frontend``): 2 worker
    processes x 1 device vs 1 worker process x 2 devices — equal total
    devices, so any aggregate-tokens/s edge is genuine cross-process
    overlap of engine steps. Streamed tokens are bit-compared against an
    in-process 2-replica ``repro.gateway`` baseline built from the same
    per-replica plan. A Poisson rate sweep on the 2-process deployment
    then finds the knee: the lowest offered rate whose saturated
    tokens/s is within 10% of the best measured."""
    from repro.configs import registry as arch_registry
    from repro.engine import EngineConfig, Request
    from repro.frontend.orchestrator import Orchestrator
    from repro.frontend.protocol import make_worker_spec
    from repro.frontend.worker import ProcReplica
    from repro.gateway import build_gateway
    from repro.plan import make_serve_plan

    import numpy as np

    cfg = (arch_registry.get_smoke(args.arch) if args.smoke
           else arch_registry.get(args.arch))
    eng = EngineConfig(max_slots=args.max_slots, page_size=args.page_size,
                       pages_per_shard=args.pages_per_shard,
                       max_len=args.max_len)
    plans = {}
    for n_dev in (1, 2):
        plans[n_dev] = make_serve_plan(
            cfg, arch=args.arch, n_devices=n_dev,
            decode_batch=args.max_slots, page_size=args.page_size,
            max_len=args.max_len, mesh_kind="local")
    reqs = build_frontend_workload(cfg.vocab_size, args,
                                   args.frontend_requests)
    zeros = [0] * len(reqs)
    stats = {}

    # --- 2 processes x 1 device ---
    print("[serving_load] frontend: spawning 2x1-device workers...",
          flush=True)
    spec1 = make_worker_spec(plan=plans[1], eng=eng)
    orch2 = Orchestrator([ProcReplica(0, spec1), ProcReplica(1, spec1)])
    drive_orchestrator(orch2, reqs, zeros)            # untimed warmup
    rids2, wall, _ = drive_orchestrator(orch2, reqs, zeros)  # saturated
    stats["two_proc"] = _point_stats(orch2, rids2, wall)
    out2 = {i: list(orch2.streams[r].tokens) for i, r in enumerate(rids2)}

    # rate sweep on the 2-process deployment: find the knee
    rng = np.random.default_rng(args.seed + 7)
    sweep = []
    for rate in [float(r) for r in args.frontend_rates.split(",") if r]:
        inter = rng.exponential(1.0 / rate, len(reqs))
        arrivals = np.floor(np.cumsum(inter)).astype(int).tolist()
        rids, wall, steps = drive_orchestrator(orch2, reqs, arrivals)
        sweep.append({"rate": rate, "steps": steps,
                      **_point_stats(orch2, rids, wall)})
    best = max(s["tokens_per_s"] for s in sweep)
    knee = next((s["rate"] for s in sweep
                 if s["tokens_per_s"] >= 0.9 * best), None)
    orch2.shutdown(drain=False)

    # --- 1 process x 2 devices ---
    print("[serving_load] frontend: spawning 1x2-device worker...",
          flush=True)
    orch1 = Orchestrator([ProcReplica(0, make_worker_spec(plan=plans[2],
                                                          eng=eng))])
    drive_orchestrator(orch1, reqs, zeros)            # untimed warmup
    rids1, wall, _ = drive_orchestrator(orch1, reqs, zeros)
    stats["one_proc"] = _point_stats(orch1, rids1, wall)
    orch1.shutdown(drain=False)

    # --- in-process gateway baseline: same per-replica plan, bit-compare
    gw_plan = make_serve_plan(
        cfg, arch=args.arch, n_devices=1, decode_batch=args.max_slots,
        page_size=args.page_size, max_len=args.max_len, mesh_kind="local",
        replicas=2)
    gw = build_gateway(args.arch, smoke=args.smoke, plan=gw_plan, eng=eng)
    greqs = [Request(uid=f"g{i}", tokens=list(kw["prompt"]),
                     max_new_tokens=kw["max_new_tokens"],
                     temperature=kw["temperature"], top_k=kw["top_k"],
                     top_p=kw["top_p"], seed=kw["seed"])
             for i, kw in enumerate(reqs)]
    for r in greqs:
        gw.add_request(r)
    gout = gw.run()
    stats["outputs_identical"] = all(
        gout[f"g{i}"] == out2[i] for i in range(len(reqs)))
    stats["speedup"] = (stats["two_proc"]["tokens_per_s"]
                        / stats["one_proc"]["tokens_per_s"])
    stats["sweep"] = sweep
    stats["knee_rate"] = knee
    stats["requests"] = args.frontend_requests
    return stats


def run_preempt_phase(args):
    """Mixed interactive/batch Poisson workload through the frontend
    orchestrator (single in-process replica, 2 decode slots), priority
    preemption ON vs OFF. With the slots pinned by long batch streams,
    arriving interactive requests sit queued unless preemption spills a
    batch stream (valid KV into the prefix cache; resume re-queued).
    Gates (under --check): interactive p99 TTFT from the obs histogram
    strictly better with preemption ON, at least one preemption, and
    every stream — including preempted-and-resumed ones — bit-identical
    to the preemption-OFF run."""
    from repro.configs import registry as arch_registry
    from repro.engine import EngineConfig
    from repro.frontend.orchestrator import Orchestrator
    from repro.frontend.protocol import make_worker_spec
    from repro.frontend.slo import PriorityClass
    from repro.frontend.worker import LocalReplica
    from repro.plan import make_serve_plan

    import numpy as np

    cfg = (arch_registry.get_smoke(args.arch) if args.smoke
           else arch_registry.get(args.arch))
    plan = make_serve_plan(
        cfg, arch=args.arch, n_devices=1, decode_batch=2,
        page_size=args.page_size, max_len=args.max_len, mesh_kind="local",
        prefix_cache=True)
    eng = EngineConfig(max_slots=2, page_size=args.page_size,
                       pages_per_shard=args.pages_per_shard,
                       max_len=args.max_len)
    spec = make_worker_spec(plan=plan, eng=eng)
    classes = {
        "interactive": PriorityClass("interactive", rank=0),
        "batch": PriorityClass("batch", rank=1, preemptible=True),
    }
    rng = np.random.default_rng(args.seed + 5)
    vocab = cfg.vocab_size
    batch_reqs = [dict(prompt=rng.integers(0, vocab, 12).tolist(),
                       max_new_tokens=args.preempt_batch_gen,
                       temperature=0.8 if i % 2 else 0.0,
                       top_k=16 if i % 2 else 0, top_p=1.0,
                       seed=args.seed + 50 + i)
                  for i in range(2)]
    inter_reqs = build_frontend_workload(vocab, args,
                                         args.preempt_requests)
    for kw in inter_reqs:
        kw["max_new_tokens"] = min(kw["max_new_tokens"], 4)
    # interactive Poisson arrivals land after the batch streams hold
    # both slots
    inter = rng.exponential(3.0, len(inter_reqs))
    arrivals = (3 + np.floor(np.cumsum(inter)).astype(int)).tolist()

    def one_run(preempt):
        orch = Orchestrator([LocalReplica(0, spec)], classes=classes,
                            preempt=preempt)
        brids = []
        for kw in batch_reqs:
            kw = dict(kw)
            rid = orch.submit(kw.pop("prompt"), kw.pop("max_new_tokens"),
                              cls="batch", **kw)
            assert isinstance(rid, int), f"batch rejected: {rid}"
            brids.append(rid)
        irids, wall, steps = drive_orchestrator(orch, inter_reqs, arrivals,
                                                cls="interactive")
        out = {("b", i): list(orch.streams[r].tokens)
               for i, r in enumerate(brids)}
        out.update({("i", i): list(orch.streams[r].tokens)
                    for i, r in enumerate(irids)})
        preempted = sum(orch.streams[r].preemptions for r in brids)
        return {
            "wall_s": wall, "steps": steps, "preemptions": preempted,
            "interactive_ttft_p99_s": orch.ttft_quantile(
                0.99, cls="interactive"),
            "interactive_ttft_p50_s": orch.ttft_quantile(
                0.5, cls="interactive"),
            **{k: v for k, v in _point_stats(
                orch, brids + irids, wall).items()
               if k in ("tokens", "tokens_per_s")},
        }, out

    on, out_on = one_run(True)
    off, out_off = one_run(False)
    return {
        "on": on, "off": off,
        "outputs_identical": out_on == out_off,
        "ttft_improvement": (off["interactive_ttft_p99_s"]
                             / max(on["interactive_ttft_p99_s"], 1e-9)),
        "batch_requests": 2, "interactive_requests": args.preempt_requests,
        "batch_gen": args.preempt_batch_gen,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--c", type=int, default=1)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--min-prompt", type=int, default=3)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--min-gen", type=int, default=4)
    ap.add_argument("--max-gen", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages-per-shard", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--sampled", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="every other request samples (T=0.8, k=32, p=0.95); "
                         "--no-sampled for a pure-greedy workload")
    ap.add_argument("--kernel-requests", type=int, default=3,
                    help="requests in the ref-vs-pallas kernel phase "
                         "(0 disables it; interpret mode is slow on CPU)")
    ap.add_argument("--prefix-requests", type=int, default=8,
                    help="requests in the shared-prefix gateway phase "
                         "(0 disables it)")
    ap.add_argument("--system-prompt", type=int, default=96,
                    help="shared system-prompt length of the prefix phase "
                         "(page-aligned lengths maximise hits)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="gateway replicas in the prefix phase (--devices "
                         "is split evenly across them)")
    ap.add_argument("--prefix-reps", type=int, default=3,
                    help="timed replays per prefix sub-phase (best wall "
                         "wins — sub-second phases need noise rejection)")
    ap.add_argument("--offload-requests", type=int, default=9,
                    help="requests in the host-tier offload phase "
                         "(0 disables it)")
    ap.add_argument("--offload-families", type=int, default=3,
                    help="distinct shared-prompt families cycled through "
                         "the undersized pool")
    ap.add_argument("--family-prompt", type=int, default=128,
                    help="shared prompt length per family (the spilled/"
                         "reloaded chain)")
    ap.add_argument("--offload-pages", type=int, default=20,
                    help="pages per shard in the offload phase — sized so "
                         "one family fits but two do not")
    ap.add_argument("--host-tier-bytes", type=int, default=1 << 30,
                    help="pinned-host tier capacity of the offload phase's "
                         "ON gateway")
    ap.add_argument("--offload-reps", type=int, default=3,
                    help="timed replays per offload sub-phase (best wall "
                         "wins)")
    ap.add_argument("--chunk-requests", type=int, default=9,
                    help="requests in the chunked-prefill latency phase "
                         "(0 disables it)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunk size (tokens) of the chunked-prefill phase")
    ap.add_argument("--long-prompt", type=int, default=48,
                    help="long-prompt length of the chunked-prefill phase")
    ap.add_argument("--frontend-requests", type=int, default=6,
                    help="requests in the process-separated frontend "
                         "phase (0 disables it; spawns worker processes)")
    ap.add_argument("--frontend-rates", default="0.25,0.5,1.0,2.0",
                    help="comma-separated Poisson rates (requests per "
                         "step) swept on the 2-process frontend to find "
                         "the saturation knee")
    ap.add_argument("--preempt-requests", type=int, default=4,
                    help="interactive requests in the priority-preemption "
                         "phase (0 disables it)")
    ap.add_argument("--preempt-batch-gen", type=int, default=32,
                    help="decode budget of the slot-pinning batch streams "
                         "in the preemption phase")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/BENCH_serving.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless continuous beats sequential "
                         "and batched == solo outputs")
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.smoke:
        args.requests = min(args.requests, 8)

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    from repro.engine import EngineConfig, build_engine

    engine = build_engine(
        args.arch, smoke=args.smoke, c=args.c,
        eng=EngineConfig(max_slots=args.max_slots, page_size=args.page_size,
                         pages_per_shard=args.pages_per_shard,
                         max_len=args.max_len))
    workload = build_workload(engine, args)

    # untimed warmup pass: populates every prefill/decode bucket
    warm, _ = run_continuous(engine, workload)
    engine.reset()
    compiles0 = exported_compiles(engine.registry)

    cont, cont_out = run_continuous(engine, workload)
    engine.reset()
    seq, seq_out = run_sequential(engine, workload)
    compiles1 = exported_compiles(engine.registry)

    kernels = (run_kernel_compare(args, workload)
               if args.kernel_requests > 0 else None)
    prefix = (run_prefix_phase(args)
              if args.prefix_requests > 0 else None)
    chunked = (run_chunked_phase(args)
               if args.chunk_requests > 0 else None)
    offload = (run_offload_phase(args)
               if args.offload_requests > 0 else None)
    frontend = (run_frontend_phase(args)
                if args.frontend_requests > 0 else None)
    preempt = (run_preempt_phase(args)
               if args.preempt_requests > 0 else None)

    identical = cont_out == seq_out
    result = {
        "bench": "serving_load",
        "arch": args.arch,
        "smoke": args.smoke,
        "devices": args.devices,
        "c": args.c,
        "workload": {
            "requests": args.requests, "rate": args.rate,
            "prompt_len": [args.min_prompt, args.max_prompt],
            "gen": [args.min_gen, args.max_gen],
            "sampled": args.sampled, "seed": args.seed,
        },
        "engine": {"max_slots": args.max_slots, "page_size": args.page_size,
                   "pages_per_shard": args.pages_per_shard,
                   "max_len": args.max_len},
        "continuous": cont,
        "sequential": seq,
        "speedup": cont["tokens_per_s"] / seq["tokens_per_s"],
        "outputs_identical_to_solo": identical,
        "compiles_after_warmup": compiles1 == compiles0,
        "kernels": kernels,
        "prefix": prefix,
        "chunked": chunked,
        "offload": offload,
        "frontend": frontend,
        "preempt": preempt,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps(result, indent=2, sort_keys=True))
    print(f"[serving_load] continuous {cont['tokens_per_s']:.2f} tok/s vs "
          f"sequential {seq['tokens_per_s']:.2f} tok/s "
          f"(speedup {result['speedup']:.2f}x), outputs identical: "
          f"{identical}, wrote {args.out}")
    if kernels is not None:
        print(f"[serving_load] kernels: "
              f"ref {kernels['ref']['tokens_per_s']:.2f} tok/s vs "
              f"pallas(interpret) {kernels['pallas']['tokens_per_s']:.2f} "
              f"tok/s, identical: {kernels['outputs_identical']}")
    if prefix is not None:
        print(f"[serving_load] prefix cache: "
              f"{prefix['cached']['tokens_per_s']:.2f} tok/s vs cold "
              f"{prefix['cold']['tokens_per_s']:.2f} tok/s "
              f"(speedup {prefix['speedup']:.2f}x), hit rate "
              f"{prefix['cached']['hit_rate']:.2f}, prefill savings "
              f"{prefix['prefill_savings_frac']:.2f}, identical: "
              f"{prefix['outputs_identical']}")
    if chunked is not None:
        print(f"[serving_load] chunked prefill: p99 gap "
              f"{chunked['on']['p99_gap_s']:.3g}s (on) vs "
              f"{chunked['off']['p99_gap_s']:.3g}s (off) "
              f"({chunked['p99_improvement']:.2f}x better), identical: "
              f"{chunked['outputs_identical']}")
    if offload is not None:
        tier = offload["on"]["host_tier"]
        print(f"[serving_load] host tier: "
              f"{offload['on']['tokens_per_s']:.2f} tok/s (on) vs "
              f"{offload['off']['tokens_per_s']:.2f} tok/s (off) "
              f"(speedup {offload['speedup']:.2f}x), hit rate "
              f"{offload['on']['hit_rate']:.2f} vs "
              f"{offload['off']['hit_rate']:.2f}, spilled "
              f"{tier['spill_pages']} pages / reloaded "
              f"{tier['reload_pages']}, identical: "
              f"{offload['outputs_identical']}")
    if frontend is not None:
        print(f"[serving_load] frontend: "
              f"{frontend['two_proc']['tokens_per_s']:.2f} tok/s (2 proc) "
              f"vs {frontend['one_proc']['tokens_per_s']:.2f} tok/s "
              f"(1 proc, equal devices; speedup "
              f"{frontend['speedup']:.2f}x), knee rate "
              f"{frontend['knee_rate']}, identical to gateway: "
              f"{frontend['outputs_identical']}")
    if preempt is not None:
        print(f"[serving_load] preemption: interactive p99 TTFT "
              f"{preempt['on']['interactive_ttft_p99_s']:.3g}s (on, "
              f"{preempt['on']['preemptions']} preemptions) vs "
              f"{preempt['off']['interactive_ttft_p99_s']:.3g}s (off) "
              f"({preempt['ttft_improvement']:.2f}x better), identical: "
              f"{preempt['outputs_identical']}")
    if args.check:
        assert identical, "batched outputs diverged from solo serving"
        assert result["compiles_after_warmup"], "recompiled after warmup"
        assert result["speedup"] > 1.0, (
            f"continuous batching slower than sequential: "
            f"{result['speedup']:.2f}x")
        if kernels is not None:
            assert kernels["outputs_identical"], (
                "paged-decode kernel tokens diverged from the ref path")
            for kern in ("ref", "pallas"):
                assert kernels[kern]["compiles_after_warmup"], (
                    f"{kern} paged-kernel path recompiled after warmup")
        if prefix is not None:
            assert prefix["outputs_identical"], (
                "prefix-cached tokens diverged from the cold-cache run")
            assert prefix["cached"]["hit_rate"] > 0, "prefix cache never hit"
            assert prefix["prefill_savings_frac"] > 0.5, (
                f"prefill-token savings {prefix['prefill_savings_frac']:.2f}"
                " <= 0.5 on the shared-prompt workload")
            assert prefix["speedup"] > 1.0, (
                f"prefix caching slower than cold: "
                f"{prefix['speedup']:.2f}x")
            for mode in ("cached", "cold"):
                assert prefix[mode]["compiles_after_warmup"], (
                    f"prefix phase ({mode}) recompiled after warmup")
        if chunked is not None:
            assert chunked["outputs_identical"], (
                "chunked-prefill tokens diverged from monolithic prefill")
            assert chunked["on"]["p99_gap_s"] < chunked["off"]["p99_gap_s"], (
                f"chunking did not lower p99 decode gap: "
                f"{chunked['on']['p99_gap_s']:.3g}s >= "
                f"{chunked['off']['p99_gap_s']:.3g}s")
            for mode in ("on", "off"):
                assert chunked[mode]["compiles_after_warmup"], (
                    f"chunked phase ({mode}) recompiled after warmup")
        if offload is not None:
            assert offload["outputs_identical"], (
                "host-tier tokens diverged from the tier-off run")
            tier = offload["on"]["host_tier"]
            assert tier["spill_pages"] > 0, (
                "pool pressure never spilled to the host tier")
            assert tier["reload_pages"] > 0, (
                "returning families never reloaded from the host tier")
            assert offload["on"]["hit_rate"] > offload["off"]["hit_rate"], (
                f"host tier did not raise the prefix hit rate: "
                f"{offload['on']['hit_rate']:.2f} <= "
                f"{offload['off']['hit_rate']:.2f}")
            assert offload["speedup"] > 1.0, (
                f"host tier slower than recompute: "
                f"{offload['speedup']:.2f}x")
            for mode in ("on", "off"):
                assert offload[mode]["compiles_after_warmup"], (
                    f"offload phase ({mode}) recompiled after warmup")
        if frontend is not None:
            assert frontend["outputs_identical"], (
                "frontend streams diverged from the in-process gateway")
            assert frontend["speedup"] > 1.0, (
                f"2-process frontend not faster than 1 process at equal "
                f"devices: {frontend['speedup']:.2f}x")
        if preempt is not None:
            assert preempt["outputs_identical"], (
                "preempted/resumed streams diverged from the "
                "preemption-off run")
            assert preempt["on"]["preemptions"] > 0, (
                "the preemption-on run never preempted")
            assert preempt["off"]["preemptions"] == 0, (
                "the preemption-off run preempted")
            assert (preempt["on"]["interactive_ttft_p99_s"]
                    < preempt["off"]["interactive_ttft_p99_s"]), (
                f"preemption did not improve interactive p99 TTFT: "
                f"{preempt['on']['interactive_ttft_p99_s']:.3g}s >= "
                f"{preempt['off']['interactive_ttft_p99_s']:.3g}s")
    return result


if __name__ == "__main__":
    main()
