"""Paper Fig. 1: P2P communication volume, Ring vs StarTrail-2/-4.

Two parts:
  (theory)   closed forms, eqs. (2)-(4), via the plan layer's cost model
             (`repro.plan.cost.comm_volumes`): per-device P2P volume
             Ring = 2BNH_kv bytes; StarTrail = 2BNH_kv/C + collective
             4BN(H_q+H_kv)(C-1)/P.
  (measured) compile the attention island at each C on 16 SP host devices
             (mesh built from an ExecutionPlan) and parse the HLO
             collective bytes — the measured permute volume must match the
             closed form and show the ~(C-1)/C saving the paper claims
             (~50% for C=2, ~75% for C=4).
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import startrail as st
from repro.plan import ExecutionPlan, cost
from repro.roofline import hlo as hlo_lib


def theory_volumes(B, N, Hq_dim, Hkv_dim, p, c, bytes_per=4):
    """Implementation-exact per-device volumes (paper eqs. 3-4 with this
    system's R ring permutes) via the plan layer's cost model
    (`repro.plan.cost.comm_volumes` — tests/test_plan.py asserts its
    rankings reproduce this benchmark's (C-1)/C saving claims). bytes_per=4:
    the CPU backend legalises bf16 to f32 (documented in EXPERIMENTS.md);
    on TPU the wire dtype is bf16 (/2).
    """
    cfg = ModelConfig(name="fig1", family="dense", num_layers=1,
                      d_model=Hq_dim, num_heads=Hq_dim, num_kv_heads=Hkv_dim,
                      d_ff=0, vocab_size=1, head_dim=1)
    shape = ShapeConfig("fig1", seq_len=N, global_batch=B, kind="train")
    arr = cost.Arrangement("ring" if c == 1 else "startrail", c,
                           p // (c * c))
    vols = cost.comm_volumes(cfg, shape, p, arr, batch=B,
                             dtype_bytes=bytes_per)
    # the permute line matches the original closed form r * 2B(cN/p)Hkv;
    # the collective line keeps eq. 3's (Hq+Hkv)/2 convention
    per_dev_p2p = vols["ring_p2p"]
    coll = 4 * B * N / p * (c - 1) * (Hq_dim + Hkv_dim) / 2 * bytes_per
    return per_dev_p2p, coll


def measured_volumes(B, S, hq, hkv, d, c, p=16):
    cfg = st.StarTrailConfig(seq_len=S, seq_scheme="zigzag", causal=True,
                         unroll=True)  # while-loop bodies count once
    plan = ExecutionPlan(
        arch="fig1", shape="bench", seq_len=S, global_batch=B, n_devices=p,
        scheme="ring" if c == 1 else "startrail", c=c, mesh_kind="local")
    mesh = plan.build_mesh()
    spec = P(None, cfg.axes, None, None)

    def local(q, k, v):
        return st.startrail_attention(q, k, v, cfg)

    f = jax.jit(jax.shard_map(local, mesh=mesh, in_specs=(spec,) * 3,
                              out_specs=spec, check_vma=False))
    args = [jax.ShapeDtypeStruct((B, S, h, d), jnp.bfloat16)
            for h in (hq, hkv, hkv)]
    compiled = f.lower(*args).compile()
    out = hlo_lib.collective_bytes(compiled.as_text())
    return out["bytes_by_kind"]


def run(emit):
    B, S, hq, hkv, d, p = 1, 16384, 32, 8, 128, 16
    base_permute = None
    for c in (1, 2, 4):
        kinds = measured_volumes(B, S, hq, hkv, d, c, p)
        permute = kinds.get("collective-permute", 0)
        gather = kinds.get("all-gather", 0) + kinds.get("reduce-scatter", 0)
        th_p2p, th_coll = theory_volumes(B, S, hq * d, hkv * d, p, c)
        if c == 1:
            base_permute = permute
        saving = 1 - permute / max(base_permute, 1)
        emit(f"fig1_comm_volume_c{c}", permute / 2**20,
             f"p2p_MiB_meas={permute/2**20:.1f},p2p_MiB_theory={th_p2p/2**20:.1f},"
             f"coll_MiB={gather/2**20:.1f},p2p_saving_vs_ring={saving:.2%}")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v},{d}"))
