"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. The comm/memory/throughput-wall
benchmarks need 8 host devices — this launcher sets XLA_FLAGS before jax
imports (it must run as the entry point: ``python -m benchmarks.run``).
"""

import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=16")


def main() -> None:
    from benchmarks import comm_volume, memory, scaling, throughput

    print("name,us_per_call,derived")

    def emit(name, value, derived=""):
        print(f"{name},{value:.3f},{derived}", flush=True)

    comm_volume.run(emit)
    throughput.run(emit)
    memory.run(emit)
    scaling.run(emit)


if __name__ == "__main__":
    main()
