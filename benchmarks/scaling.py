"""Paper Figs. 9-10: strong and weak scaling, Ring vs StarTrail.

Evaluated with the plan layer's analytic arrangement ranking (CPU
container; v5e target):
  strong: fixed 128k sequence, devices 8 -> 64;
  weak:   sequence and devices scale together (128k@8 .. 512k@32).
Reports projected throughput (tokens/s) for Ring (C=1) and the best
arrangement at each point; the paper's qualitative claims to verify:
StarTrail's advantage grows with device count (strong) and stays constant
or grows with sequence (weak).
"""

from repro.configs import paper_models
from repro.configs.base import ShapeConfig
from repro.core import scheduler as sch
from repro.plan import cost


def _point(cfg, seq, p, link_bw):
    shape = ShapeConfig("scaling", seq_len=seq, global_batch=1, kind="train")
    cl = sch.ClusterModel(sp_size=p, link_bw=link_bw)
    # figs. 9-10 compare Ring vs StarTrail only (Ulysses is Fig. 1 turf)
    arrs = [a for a in cost.enumerate_arrangements(cfg, p)
            if a.scheme != "ulysses"]
    ranking = cost.rank_arrangements(cfg, shape, p, batch=1, cluster=cl,
                                     arrangements=arrs)
    ring = next(e["total_s"] for e in ranking
                if e["arrangement"].scheme == "ring")
    best = ranking[0]
    return ring, best


def run(emit):
    cfg = paper_models.GPT_7B
    # strong scaling: N fixed, P grows
    seq = 128 * 1024
    for p in (8, 16, 32, 64):
        ring, best = _point(cfg, seq, p, 25e9)
        emit(f"fig9_strong_p{p}", seq / best["total_s"],
             f"ring_tok_s={seq/ring:.0f},best_c={best['arrangement'].c},"
             f"best_scheme={best['arrangement'].scheme},"
             f"advantage={ring/best['total_s']-1:.2%}")
    # weak scaling: N and P grow together
    # paper Fig. 10a runs on the A100/Ethernet clusters -> slow links
    for k, p in ((1, 8), (2, 16), (4, 32)):
        seq = 128 * 1024 * k
        ring, best = _point(cfg, seq, p, 3e9)
        emit(f"fig10_weak_{seq//1024}k_p{p}", seq / best["total_s"],
             f"ring_tok_s={seq/ring:.0f},best_c={best['arrangement'].c},"
             f"best_scheme={best['arrangement'].scheme},"
             f"advantage={ring/best['total_s']-1:.2%}")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v},{d}"))
