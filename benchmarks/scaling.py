"""Paper Figs. 9-10: strong and weak scaling, Ring vs StarTrail.

Evaluated with the analytic cluster model (CPU container; v5e target):
  strong: fixed 128k sequence, devices 8 -> 64;
  weak:   sequence and devices scale together (128k@8 .. 512k@32).
Reports projected throughput (tokens/s) for Ring (C=1) and the best
StarTrail config at each point; the paper's qualitative claims to verify:
StarTrail's advantage grows with device count (strong) and stays constant
or grows with sequence (weak).
"""

from repro.configs import paper_models
from repro.core import scheduler as sch


def run(emit):
    cfg = paper_models.GPT_7B
    # strong scaling: N fixed, P grows
    seq = 128 * 1024
    for p in (8, 16, 32, 64):
        w = sch.AttnWorkload(batch=1, seq_len=seq, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.head_dim_)
        cl = sch.ClusterModel(sp_size=p, link_bw=25e9)
        out = sch.schedule(w, cl)
        ring = min(g["total_s"] for g in out["grid"] if g["c"] == 1)
        best = out["best"]
        emit(f"fig9_strong_p{p}", seq / best["total_s"],
             f"ring_tok_s={seq/ring:.0f},best_c={best['c']},"
             f"advantage={ring/best['total_s']-1:.2%}")
    # weak scaling: N and P grow together
    # paper Fig. 10a runs on the A100/Ethernet clusters -> slow links
    for k, p in ((1, 8), (2, 16), (4, 32)):
        seq = 128 * 1024 * k
        w = sch.AttnWorkload(batch=1, seq_len=seq, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.head_dim_)
        cl = sch.ClusterModel(sp_size=p, link_bw=3e9)
        out = sch.schedule(w, cl)
        ring = min(g["total_s"] for g in out["grid"] if g["c"] == 1)
        best = out["best"]
        emit(f"fig10_weak_{seq//1024}k_p{p}", seq / best["total_s"],
             f"ring_tok_s={seq/ring:.0f},best_c={best['c']},"
             f"advantage={ring/best['total_s']-1:.2%}")


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v},{d}"))
