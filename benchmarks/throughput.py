"""Paper Fig. 7: throughput, Ring Attention vs StarTrail (Wall-2 / Wall-4).

The paper measures tokens/s on GPU clusters; we are CPU-only with TPU v5e
as the target, so this benchmark has three parts:

  (model)    the plan layer's analytic cost model evaluated at the paper's
             own settings (GPT 3B/7B, DiT 1B; 32 devices; 64k-512k
             sequence) -> projected tokens/s per arrangement, reproducing
             the qualitative Fig. 7 result (StarTrail > Ring, best C varies
             with the interconnect).
  (wall)     real wall-clock of the attention island on 8 host devices at
             a reduced size: relative step times Ring vs StarTrail-2 (CPU
             timing, *relative* numbers only). Meshes come from
             ExecutionPlans, not hand-built grids.
  (compare)  ``--compare-arrangements``: full jitted train steps for every
             legal arrangement of the same P on the 8-device CPU mesh
             (ring / StarTrail-2 / Ulysses), cross-checked against the
             autotuner's pick; writes results/BENCH_plan.json and fails if
             the autotuned pick is the slowest measured arrangement.
  (overlap)  ``--overlap-sweep``: the pipelined double-buffered ring scan
             A/B — baseline (compute-then-permute) vs pipelined at
             comm_chunks 1/2/4 on the C=2 smoke mesh. Per cell: measured
             tokens/s, the HLO-derived overlap fraction
             (``obs.commlog.overlap_report``), the analytical prediction,
             and a one-train-step bit-identity comparison against the
             baseline. Writes results/BENCH_throughput.json; ``--check``
             gates bit-identity, overlap > 0, no tokens/s regression and
             zero pallas block_bwd fallbacks (the CI ``train-bench-smoke``
             job).
"""

import json
import os
import pathlib
import sys
import time

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_models
from repro.configs.base import ShapeConfig
from repro.core import scheduler as sch
from repro.core import startrail as st
from repro.plan import ExecutionPlan, autotune as autotune_lib, cost, make_plan

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


PAPER_SETTINGS = [
    # (model, seq_len, link_bw, tag)  bw ~ IB vs 100Gb ethernet
    (paper_models.GPT_7B, 128 * 1024, 25e9, "H100_IB_128k"),
    (paper_models.GPT_7B, 512 * 1024, 25e9, "H100_IB_512k"),
    (paper_models.GPT_3B, 256 * 1024, 3e9, "A100_eth_256k"),
    (paper_models.DIT_1B, 512 * 1024, 3e9, "A100_eth_512k"),
]


def model_part(emit):
    for cfg, seq, bw, tag in PAPER_SETTINGS:
        shape = ShapeConfig("fig7", seq_len=seq, global_batch=1, kind="train")
        cl = sch.ClusterModel(sp_size=32, link_bw=bw)
        ranking = cost.rank_arrangements(cfg, shape, 32, batch=1, cluster=cl)
        per_c = {}
        for e in ranking:
            arr = e["arrangement"]
            if arr.scheme == "ulysses":
                continue
            if arr.c not in per_c or e["total_s"] < per_c[arr.c]:
                per_c[arr.c] = e["total_s"]
        ring_t = per_c[1]
        best = next(e for e in ranking
                    if e["arrangement"].scheme != "ulysses")
        speedup = ring_t / best["total_s"] - 1
        emit(f"fig7_{tag}", best["total_s"] * 1e6,
             f"best_c={best['arrangement'].c},"
             f"placement={best['arrangement'].placement},"
             f"speedup_vs_ring={speedup:.2%},"
             + ",".join(f"c{c}_us={t*1e6:.0f}"
                        for c, t in sorted(per_c.items())))


def wall_part(emit):
    if len(jax.devices()) < 8:
        emit("fig7_wallclock", 0, "skipped=needs 8 devices")
        return
    from jax.sharding import PartitionSpec as P

    B, S, hq, hkv, d, p = 1, 4096, 8, 4, 64, 8
    for c in (1, 2):
        cfg = st.StarTrailConfig(seq_len=S, seq_scheme="zigzag", causal=True)
        plan = ExecutionPlan(
            arch="fig7-wall", shape="bench", seq_len=S, global_batch=B,
            n_devices=p, scheme="ring" if c == 1 else "startrail", c=c,
            mesh_kind="local")
        mesh = plan.build_mesh()
        spec = P(None, cfg.axes, None, None)
        f = jax.jit(jax.shard_map(
            lambda q, k, v: st.startrail_attention(q, k, v, cfg),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec, check_vma=False))
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, S, hq, d), jnp.float32)
        k = jax.random.normal(key, (B, S, hkv, d), jnp.float32)
        v = jax.random.normal(key, (B, S, hkv, d), jnp.float32)
        f(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        n = 3
        for _ in range(n):
            out = f(q, k, v)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        emit(f"fig7_wallclock_c{c}", us,
             f"tokens_per_s={B*S/(us/1e6):.0f},note=cpu-relative-only")


def compare_arrangements(emit, *, arch="h2o-danube-1.8b", seq=128, batch=4,
                         data=2, steps=3):
    """Measured step times for every legal arrangement of the same P.

    Uses a GQA variant of the smoke config whose head counts admit Ulysses
    at SP = devices/data, so the comparison covers all three scheme
    families: ring (C=1), StarTrail (C=2, both placements collapse at R=1)
    and Ulysses. Writes results/BENCH_plan.json.
    """
    from repro.configs import registry
    from repro.models.factory import build_model

    if len(jax.devices()) < 8:
        emit("bench_plan", 0, "skipped=needs 8 devices")
        return None
    cfg = registry.get_smoke(arch)
    sp = 8 // data
    # lift head counts to a GQA shape Ulysses can shard (Hq, Hkv % SP == 0)
    cfg = dc.replace(cfg, num_heads=2 * sp, num_kv_heads=sp)
    shape = ShapeConfig("bench", seq_len=seq, global_batch=batch,
                        kind="train")
    out = autotune_lib.autotune(
        cfg, shape, arch=arch, n_devices=8, data=data, mesh_kind="local",
        top_k=8, steps=steps, out_dir=RESULTS)
    measured = out["measured"]
    assert len(measured) >= 3, (
        f"need >=3 arrangements of the same P, got "
        f"{[e['arrangement'].key for e in measured]}")
    pick = out["plan"]
    record = {
        "arch": arch, "sp": sp, "data": data, "seq_len": seq, "batch": batch,
        "arrangements": [{
            "arrangement": e["arrangement"].key,
            "scheme": e["arrangement"].scheme, "c": e["arrangement"].c,
            "r": e["arrangement"].r, "step_time_s": e["measured_s"],
            "analytical_s": e["analytical_s"],
        } for e in measured],
        "autotune_pick": pick.to_dict(),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_plan.json").write_text(json.dumps(record, indent=2))
    for e in measured:
        emit(f"bench_plan_{e['arrangement'].key}", e["measured_s"] * 1e6,
             f"analytical_us={e['analytical_s'] * 1e6:.1f}")
    emit("bench_plan_pick", measured[0]["measured_s"] * 1e6,
         f"scheme={pick.scheme},c={pick.c},r={pick.r}")
    # the in-memory pick is measured-best by construction; guard what can
    # actually break: the persisted plan file must round-trip to the
    # measured winner, and it must strictly beat the worst arrangement
    assert measured[0] is not measured[-1], "only one arrangement measured"
    assert ExecutionPlan.load(out["path"]) == measured[0]["plan"], \
        "persisted plan is not the measured winner"
    assert measured[0]["measured_s"] < measured[-1]["measured_s"], \
        "timing degenerated: winner does not beat the slowest arrangement"
    return record


OVERLAP_CELLS = [
    # name, pipeline_scan, comm_chunks
    ("baseline", False, 1),
    ("pipelined", True, 1),
    ("pipelined_cc2", True, 2),
    ("pipelined_cc4", True, 4),
]


def overlap_sweep(emit, *, arch="h2o-danube-1.8b", seq=128, batch=4,
                  steps=3, check=False, slack=0.10):
    """Pipelined-ring A/B on the C=2 smoke mesh (8 host devices).

    Every cell trains the same smoke model from the same init; the
    pipelined cells must be *bit-identical* to the baseline after one
    optimizer step (the reorder changes op issue order, not math). CPU
    wall-clocks are noisy, so the tokens/s gate allows ``slack``
    regression on the best pipelined cell vs baseline.
    """
    from repro.configs import registry
    from repro.core import zigzag as zz
    from repro.kernels import dispatch
    from repro.models.factory import build_model
    from repro.obs import commlog
    from repro.optim import adamw

    if len(jax.devices()) < 8:
        emit("bench_overlap", 0, "skipped=needs 8 devices")
        return None
    cfg = registry.get_smoke(arch)
    shape = ShapeConfig("bench", seq_len=seq, global_batch=batch,
                        kind="train")
    model = build_model(cfg)
    adam_cfg = adamw.AdamWConfig(warmup_steps=0)

    plans = {name: make_plan(
        cfg, shape, arch=arch, n_devices=8, data=1, c=2, scheme="startrail",
        mesh_kind="local", pipeline_scan=pipe, comm_chunks=cc)
        for name, pipe, cc in OVERLAP_CELLS}
    mesh = plans["baseline"].build_mesh()

    def one_step(plan):
        """Params after one optimizer step from the shared init/batch."""
        jstep, sh = plan.build_train_step(model, adam_cfg, mesh=mesh)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.init_state(params, adam_cfg)
        b = model.make_batch(jax.random.PRNGKey(1), shape)
        perm = zz.make_positions(seq, plan.sp_size,
                                 plan.run_config().seq_scheme).reshape(-1)
        b = {k: jnp.take(v, perm, axis=1) for k, v in b.items()}
        params = jax.device_put(params, sh["params"])
        opt = jax.device_put(opt, sh["opt"])
        b = jax.device_put(b, sh["batch"])
        params, _, metrics = jstep(params, opt, b)
        return ([np.asarray(x) for x in jax.tree.leaves(params)],
                float(metrics["loss"]))

    base_params, base_loss = one_step(plans["baseline"])
    cells = []
    for name, pipe, cc in OVERLAP_CELLS:
        plan = plans[name]
        t = autotune_lib.measure_plan(model, plan, steps=steps,
                                      adam_cfg=adam_cfg, mesh=mesh)
        tok_s = batch * seq / t
        ov = commlog.overlap_report(cfg, plan, batch=1)
        analytical = cost.arrangement_time(
            cfg, shape, 8, cost.Arrangement("startrail", 2, 2,
                                            placement=plan.placement),
            batch=batch, overlap_frac=ov["overlap_fraction"],
            comm_chunks=cc)
        if name == "baseline":
            bit_identical = True
        else:
            p_leaves, loss = one_step(plan)
            bit_identical = (loss == base_loss and
                             len(p_leaves) == len(base_params) and
                             all(np.array_equal(a, b) for a, b in
                                 zip(p_leaves, base_params)))
        cells.append({
            "cell": name, "pipeline_scan": pipe, "comm_chunks": cc,
            "step_time_s": t, "tokens_per_s": tok_s,
            "overlap_fraction": ov["overlap_fraction"],
            "permutes_with_overlap_window":
                ov["permutes_with_overlap_window"],
            "analytical_s": analytical,
            "bit_identical_to_baseline": bit_identical,
        })
        emit(f"bench_overlap_{name}", tok_s,
             f"step_us={t*1e6:.0f},overlap={ov['overlap_fraction']:.3f},"
             f"bit_identical={bit_identical}")

    # the ragged backward kernels retired the block_bwd pallas->ref
    # fallback: probe it directly (batched per-row positions)
    dispatch.reset_pallas_fallbacks()
    pos = jnp.stack([jnp.arange(8, dtype=jnp.int32),
                     jnp.arange(8, dtype=jnp.int32) + 1])
    key = jax.random.PRNGKey(0)
    qp = jax.random.normal(key, (2, 8, 2, 16), jnp.float32)
    op, lsep = dispatch.block_fwd(qp, qp, qp, pos, pos, causal=True,
                                  impl="pallas")
    delta = jnp.sum(op * qp, axis=-1).swapaxes(1, 2).astype(jnp.float32)
    dispatch.block_bwd(qp, qp, qp, qp, lsep, delta, pos, pos, causal=True,
                       impl="pallas")
    fallbacks = dispatch.pallas_fallbacks()

    base = cells[0]
    best_piped = max((c for c in cells if c["pipeline_scan"]),
                     key=lambda c: c["tokens_per_s"])
    record = {
        "arch": arch, "seq_len": seq, "batch": batch, "steps_timed": steps,
        "c": 2, "sp": 8, "cells": cells,
        "baseline_tokens_per_s": base["tokens_per_s"],
        "best_pipelined_cell": best_piped["cell"],
        "best_pipelined_tokens_per_s": best_piped["tokens_per_s"],
        "pallas_fallbacks": fallbacks,
        "slack": slack,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_throughput.json").write_text(
        json.dumps(record, indent=2))

    if check:
        bad = [c["cell"] for c in cells
               if not c["bit_identical_to_baseline"]]
        assert not bad, f"pipelined cells not bit-identical: {bad}"
        piped = [c for c in cells if c["pipeline_scan"]]
        assert all(c["overlap_fraction"] > 0 for c in piped), (
            "no comm/compute overlap window measured in the pipelined "
            f"cells: { {c['cell']: c['overlap_fraction'] for c in piped} }")
        assert best_piped["tokens_per_s"] >= \
            base["tokens_per_s"] * (1 - slack), (
            f"pipelined throughput regressed: best "
            f"{best_piped['tokens_per_s']:.0f} tok/s vs baseline "
            f"{base['tokens_per_s']:.0f} (slack {slack:.0%})")
        assert fallbacks == {}, (
            f"pallas fallbacks traced (block_bwd ragged kernel should "
            f"have retired them): {fallbacks}")
        emit("bench_overlap_check", 1, "all gates passed")
    return record


def run(emit):
    model_part(emit)
    wall_part(emit)


if __name__ == "__main__":
    def _emit(n, v, d=""):
        print(f"{n},{v:.3f},{d}")

    if "--compare-arrangements" in sys.argv:
        compare_arrangements(_emit)
    elif "--overlap-sweep" in sys.argv:
        overlap_sweep(_emit, check="--check" in sys.argv)
    else:
        run(_emit)
