"""Paper Fig. 7: throughput, Ring Attention vs StarTrail (Wall-2 / Wall-4).

The paper measures tokens/s on GPU clusters; we are CPU-only with TPU v5e
as the target, so this benchmark has two parts:

  (model)    the topology scheduler's analytic cost model evaluated at the
             paper's own settings (GPT 3B/7B, DiT 1B; 32 devices; 64k-512k
             sequence) -> projected tokens/s per config, reproducing the
             qualitative Fig. 7 result (StarTrail > Ring, best C varies
             with the interconnect).
  (wall)     real wall-clock of the attention island on 8 host devices at
             a reduced size: relative step times Ring vs StarTrail-2 (CPU
             timing, *relative* numbers only).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import paper_models
from repro.core import scheduler as sch
from repro.core import startrail as st


PAPER_SETTINGS = [
    # (model, seq_len, link_bw, tag)  bw ~ IB vs 100Gb ethernet
    (paper_models.GPT_7B, 128 * 1024, 25e9, "H100_IB_128k"),
    (paper_models.GPT_7B, 512 * 1024, 25e9, "H100_IB_512k"),
    (paper_models.GPT_3B, 256 * 1024, 3e9, "A100_eth_256k"),
    (paper_models.DIT_1B, 512 * 1024, 3e9, "A100_eth_512k"),
]


def model_part(emit):
    for cfg, seq, bw, tag in PAPER_SETTINGS:
        w = sch.AttnWorkload(batch=1, seq_len=seq, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.head_dim_,
                             causal=(cfg.name != "dit-1b"))
        cl = sch.ClusterModel(sp_size=32, link_bw=bw)
        out = sch.schedule(w, cl)
        per_c = {}
        for g in out["grid"]:
            c = g["c"]
            if c not in per_c or g["total_s"] < per_c[c]:
                per_c[c] = g["total_s"]
        ring_t = per_c[1]
        best = out["best"]
        speedup = ring_t / best["total_s"] - 1
        emit(f"fig7_{tag}", best["total_s"] * 1e6,
             f"best_c={best['c']},placement={best['placement']},"
             f"speedup_vs_ring={speedup:.2%},"
             + ",".join(f"c{c}_us={t*1e6:.0f}" for c, t in sorted(per_c.items())))


def wall_part(emit):
    if len(jax.devices()) < 8:
        emit("fig7_wallclock", 0, "skipped=needs 8 devices")
        return
    B, S, hq, hkv, d, p = 1, 4096, 8, 4, 64, 8
    for c in (1, 2):
        cfg = st.StarTrailConfig(seq_len=S, seq_scheme="zigzag", causal=True)
        r = p // (c * c)
        devs = np.array(jax.devices()[:p]).reshape(c, r, c)
        mesh = jax.sharding.Mesh(devs, cfg.axes)
        spec = P(None, cfg.axes, None, None)
        f = jax.jit(jax.shard_map(
            lambda q, k, v: st.startrail_attention(q, k, v, cfg),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec, check_vma=False))
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, S, hq, d), jnp.float32)
        k = jax.random.normal(key, (B, S, hkv, d), jnp.float32)
        v = jax.random.normal(key, (B, S, hkv, d), jnp.float32)
        f(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        n = 3
        for _ in range(n):
            out = f(q, k, v)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        emit(f"fig7_wallclock_c{c}", us,
             f"tokens_per_s={B*S/(us/1e6):.0f},note=cpu-relative-only")


def run(emit):
    model_part(emit)
    wall_part(emit)


if __name__ == "__main__":
    run(lambda n, v, d: print(f"{n},{v},{d}"))
