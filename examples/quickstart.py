"""Quickstart: StarTrail concentric-ring attention in ~40 lines.

Runs on CPU with 8 forced host devices; computes exact full-sequence
attention of a sequence sharded over the (sp_grp, sp_ring, sp_team) mesh
and checks it against the single-device reference.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import StarTrailConfig, startrail_attention
from repro.core import zigzag as zz
from repro.kernels.dispatch import mha as mha_reference

# ---- mesh: P = 8 sequence-parallel devices, attention-parallel size C = 2
C, R = 2, 2                                # P = C^2 * R = 8
mesh = jax.sharding.Mesh(
    np.array(jax.devices()).reshape(C, R, C), ("sp_grp", "sp_ring", "sp_team"))

B, S, HQ, HKV, D = 2, 512, 8, 2, 64        # GQA 4:1
cfg = StarTrailConfig(seq_len=S, seq_scheme="zigzag", causal=True)

key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, S, HQ, D))
k = jax.random.normal(kk, (B, S, HKV, D))
v = jax.random.normal(kv, (B, S, HKV, D))

# shard the sequence in the zigzag layout (causal load balance, paper §3.5)
pos = zz.make_positions(S, 8, "zigzag")
perm = pos.reshape(-1)
spec = P(None, ("sp_grp", "sp_ring", "sp_team"), None, None)

attn = jax.jit(jax.shard_map(
    lambda q, k, v: startrail_attention(q, k, v, cfg),
    mesh=mesh, in_specs=(spec,) * 3, out_specs=spec, check_vma=False))

o_sharded = attn(q[:, perm], k[:, perm], v[:, perm])
o = np.asarray(o_sharded)[:, zz.inverse_permutation_for(pos)]

o_ref = np.asarray(mha_reference(q, k, v, causal=True))
err = np.abs(o - o_ref).max()
print(f"StarTrail(C={C}) vs reference: max err {err:.2e}")
assert err < 1e-4
print("OK — concentric-ring attention is exact.")
