"""Communication Topology Scheduler demo (paper §3.4, eq. 8).

Grid-searches (C, placement) for three cluster profiles and prints the
chosen config — reproducing the paper's observation that the best C
depends on the interconnect (their A100-16/node cluster preferred C=2,
the 8/node one C=4) — then resolves a full ExecutionPlan through the
plan layer's arrangement ranking (docs/TUNING.md).

    PYTHONPATH=src python examples/topology_tuning.py
"""

from repro.core import scheduler as sch


def plan_part():
    from repro.configs import registry
    from repro.configs.base import SHAPES
    from repro.plan import make_plan

    for arch in ("minitron-8b", "paligemma-3b"):
        plan = make_plan(registry.get(arch), SHAPES["train_4k"], arch=arch,
                         n_devices=256, data=16, mesh_kind="production")
        print(f"plan[{arch:13s}] -> scheme={plan.scheme} C={plan.c} "
              f"R={plan.r} placement={plan.placement} "
              f"microbatches={plan.microbatches}")


def main():
    w = sch.AttnWorkload(batch=1, seq_len=256 * 1024, num_heads=32,
                         num_kv_heads=8, head_dim=128)
    clusters = {
        "v5e_pod_ici (fast links)": sch.ClusterModel(sp_size=16, link_bw=50e9),
        "cross-pod dci (medium)": sch.ClusterModel(sp_size=16, link_bw=10e9),
        "ethernet-ish (slow)": sch.ClusterModel(sp_size=16, link_bw=1e9),
    }
    for name, cl in clusters.items():
        out = sch.schedule(w, cl)
        best = out["best"]
        ring = min(g["total_s"] for g in out["grid"] if g["c"] == 1)
        print(f"{name:28s} -> C={best['c']} placement={best['placement']} "
              f"({ring / best['total_s'] - 1:+.1%} vs Ring Attention)")
        for g in sorted(out["grid"], key=lambda g: g["total_s"])[:3]:
            print(f"    C={g['c']} {g['placement']:11s} "
                  f"t={g['total_s'] * 1e3:.2f} ms")
    plan_part()


if __name__ == "__main__":
    main()
