"""End-to-end serving example: continuous batching through ``repro.engine``.

A mixed workload — different prompt lengths, generation budgets and
sampling settings — is served concurrently from one paged, SP-sharded KV
cache on the 8-device CPU mesh. Per-request outputs are identical to
serving each request alone (the engine keys sampling noise by request seed
and token position, never by slot or step).

    PYTHONPATH=src python examples/serving.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")


def main():
    import numpy as np

    from repro.engine import EngineConfig, Request, build_engine

    engine = build_engine(
        "h2o-danube-1.8b", smoke=True, c=2, data=1,
        eng=EngineConfig(max_slots=3, page_size=4, pages_per_shard=32,
                         max_len=64))
    rng = np.random.default_rng(0)
    vocab = engine.cfg.vocab_size
    reqs = [
        Request("greedy-short", rng.integers(0, vocab, 5).tolist(), 4),
        Request("greedy-long", rng.integers(0, vocab, 19).tolist(), 6),
        Request("sampled", rng.integers(0, vocab, 9).tolist(), 5,
                temperature=0.8, top_k=16, top_p=0.95, seed=42),
        Request("late-arrival", rng.integers(0, vocab, 3).tolist(), 4),
    ]
    for r in reqs[:3]:
        engine.add_request(r)
    engine.step()                      # prefills 3 slots + first decode
    engine.add_request(reqs[3])        # joins the running batch next step
    out = engine.run()

    for r in reqs:
        print(f"{r.uid:>13}: prompt_len={r.prompt_len:2d} -> {out[r.uid]}")
    m = engine.metrics.to_dict()
    print(f"engine: {m['steps']} steps, occupancy {m['occupancy']:.2f}, "
          f"decode compiles {m['decode_compiles']}, "
          f"prefill compiles {m['prefill_compiles']}")

    # the continuous-batching guarantee: batched == solo, bit for bit
    solo = {}
    for r in reqs:
        engine.reset()
        engine.add_request(r)
        solo.update(engine.run())
    assert solo == out, "batched generation diverged from solo serving"
    print("batched outputs identical to solo serving ✓")
    return out


if __name__ == "__main__":
    main()
