"""End-to-end serving driver: batched requests, prefill + greedy decode.

Serves a reduced model with a batch of prompts through the SP-sharded
KV-cache path (the decode ring degenerates to a partial-attention psum —
the communication-optimal configuration for single-token queries).

    PYTHONPATH=src python examples/serving.py
"""

from repro.launch import serve as serve_driver


def main():
    out = serve_driver.main([
        "--arch", "h2o-danube-1.8b", "--smoke", "--devices", "8",
        "--data", "2", "--c", "2", "--batch", "4",
        "--prompt-len", "16", "--gen", "6",
    ])
    assert out.shape == (4, 6)
    print("serving example finished; generations:", out.tolist())


if __name__ == "__main__":
    main()
