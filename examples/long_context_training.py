"""Long-context training end-to-end: StarTrail SP + FSDP + AdamW + ckpt.

Trains a reduced h2o-danube (SWA) model on a longer-than-usual sequence
with the full production stack: zigzag sharding, C=2 concentric rings,
vocab-parallel loss, checkpoint/restore. CPU-runnable (~2 min):

    PYTHONPATH=src python examples/long_context_training.py
"""

import sys

from repro.launch import train as train_driver


def main():
    metrics = train_driver.main([
        "--arch", "h2o-danube-1.8b", "--smoke", "--devices", "8",
        "--data", "2", "--c", "2", "--steps", "30", "--seq-len", "256",
        "--batch", "2", "--lr", "3e-3",
        "--ckpt-dir", "/tmp/startrail_longctx_ckpt",
    ])
    assert metrics["loss"] < 7.0
    print("long-context training example finished:", metrics)


if __name__ == "__main__":
    main()
