"""Three-term roofline from the dry-run's compiled artifact.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

cost_analysis on the SPMD executable reports per-device FLOPs/bytes;
collective bytes come from the HLO parse (per-device shapes). The dominant
term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures how much compiled
compute is "useful" (catches remat/redundancy waste).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional

from repro.configs import registry
from repro.configs.base import SHAPES, model_flops_per_token
from repro.roofline import hw


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_device: float
    hlo_flops_per_device: float
    useful_ratio: float
    peak_gib: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: the score we hillclimb."""
        ideal = self.model_flops_per_device / hw.PEAK_FLOPS_BF16
        return ideal / max(self.bound_s, 1e-30)


def from_record(rec: Dict) -> Optional[Roofline]:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["devices"]
    cfg = registry.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    fpt = model_flops_per_token(cfg)
    if rec["kind"] == "train":
        # fwd (2) + bwd (4) = 6ND total; fpt already includes the 6x
        tokens = shape.global_batch * shape.seq_len
        model_flops = fpt * tokens
    elif rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = fpt / 3.0 * tokens            # fwd only = 2ND
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = fpt / 3.0 * tokens
    model_flops_dev = model_flops / n_dev

    compute_s = rec["flops_per_device"] / hw.PEAK_FLOPS_BF16
    memory_s = rec["bytes_accessed_per_device"] / hw.HBM_BW
    collective_s = rec["collectives"]["total_bytes"] / hw.ICI_BW_PER_LINK
    hlo_flops = max(rec["flops_per_device"], 1e-9)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops_per_device=model_flops_dev,
        hlo_flops_per_device=rec["flops_per_device"],
        useful_ratio=model_flops_dev / hlo_flops,
        peak_gib=rec["memory"]["peak_bytes_per_device"] / 2**30,
    )


def load_all(results_dir: pathlib.Path):
    out = []
    for f in sorted(results_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        rec["_file"] = f.name
        out.append(rec)
    return out


def format_table(records) -> str:
    rows = ["| arch | shape | mesh | C | compute s | memory s | collective s "
            "| dominant | useful | peak GiB | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for rec in records:
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | - | "
                        f"- | SKIP | - | - | - |")
            continue
        r = from_record(rec)
        if r is None:
            rows.append(f"| {rec['arch']} | {rec['shape']} | "
                        f"{rec.get('mesh','?')} | {rec.get('c','?')} | ERR "
                        f"| | | | | | |")
            continue
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {rec.get('c')} "
            f"| {r.compute_s:.4f} | {r.memory_s:.4f} | {r.collective_s:.4f} "
            f"| {r.dominant} | {r.useful_ratio:.2f} | {r.peak_gib:.2f} "
            f"| {r.roofline_fraction:.3f} |")
    return "\n".join(rows)
