"""HLO collective parsing: per-device collective bytes from compiled text.

``cost_analysis`` has FLOPs and memory-bytes but no collective traffic, so
we parse the compiled HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (+ their
-start async forms). Shapes in HLO are the *per-device* (already
partitioned) shapes, so the sums are per-device bytes moved per step —
exactly what the roofline's collective term needs.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[2,512,64]{2,1,0} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<shape>[a-z0-9]+\[[0-9,]*\]))[^=]*?\s"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _tuple_bytes(line: str) -> int:
    """Result bytes for the op on this line.

    Async ``-start`` ops return a tuple (input-alias, output[, scratch]):
    only the *output* buffer is traffic, so for tuple results we subtract
    the first (input-alias) shape from the tuple total.
    """
    lhs = line.split("=", 1)[1]
    for op in _COLLECTIVES:
        idx = lhs.find(op)
        if idx >= 0:
            lhs = lhs[:idx]
            break
    shapes = [_shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(lhs)]
    if not shapes:
        return 0
    is_tuple = lhs.strip().startswith("(")
    if is_tuple and len(shapes) >= 2:
        return sum(shapes) - shapes[0]
    return sum(shapes)


def collective_bytes(hlo_text: str) -> Dict[str, object]:
    """Per-op-kind per-device byte counts + op counts from HLO text."""
    by_kind_bytes: Dict[str, int] = defaultdict(int)
    by_kind_count: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        # skip -done ops (the -start carries the shapes; avoid double count)
        if "-done(" in stripped:
            continue
        for op in _COLLECTIVES:
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                by_kind_bytes[op] += _tuple_bytes(stripped)
                by_kind_count[op] += 1
                break
    total = sum(by_kind_bytes.values())
    return {
        "total_bytes": total,
        "bytes_by_kind": dict(by_kind_bytes),
        "count_by_kind": dict(by_kind_count),
    }
