"""HLO collective parsing: per-device collective bytes from compiled text.

``cost_analysis`` has FLOPs and memory-bytes but no collective traffic, so
we parse the compiled HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (+ their
-start async forms). Shapes in HLO are the *per-device* (already
partitioned) shapes, so the sums are per-device bytes moved per step —
exactly what the roofline's collective term needs.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[2,512,64]{2,1,0} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<shape>[a-z0-9]+\[[0-9,]*\]))[^=]*?\s"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _tuple_bytes(line: str) -> int:
    """Result bytes for the op on this line.

    Async ``-start`` ops return a tuple (input-alias, output[, scratch]):
    only the *output* buffer is traffic, so for tuple results we subtract
    the first (input-alias) shape from the tuple total.
    """
    lhs = line.split("=", 1)[1]
    for op in _COLLECTIVES:
        idx = lhs.find(op)
        if idx >= 0:
            lhs = lhs[:idx]
            break
    shapes = [_shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(lhs)]
    if not shapes:
        return 0
    is_tuple = lhs.strip().startswith("(")
    if is_tuple and len(shapes) >= 2:
        return sum(shapes) - shapes[0]
    return sum(shapes)


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(?P<name>%?[\w.-]+)\s*=")
_DOT_RE = re.compile(r"=\s*[^=]*?\bdot\(")
_PERMUTE_DEF_RE = re.compile(r"\bcollective-permute(?:-start)?\(")


def collective_overlap(hlo_text: str) -> Dict[str, object]:
    """Comm/compute overlap evidence from the *optimized* HLO's
    instruction order.

    The CPU backend emits synchronous ``collective-permute`` (no
    -start/-done pair), so async hiding is invisible in op *kinds*; what
    the scheduler does encode is *placement*. A permute issued early —
    with dot instructions scheduled between its definition and the first
    dot that actually consumes its data — overlaps those dots on any
    backend with async transfers (TPU rewrites exactly that window into a
    start/done pair). The consuming dot is found by tracing the permute's
    users transitively through converts/copies/fusions, but *not* through
    a later collective-permute (that is the data being forwarded around
    the ring, not computed on). ``overlap_fraction`` is the share of all
    dots sitting in at least one such window. The pipelined ring scan
    exists to widen these windows; ``overlap_fraction == 0`` means every
    transfer lands immediately before its consuming kernel (nothing can
    hide).
    """
    total_dots = 0
    overlapped_dots = 0
    permutes = 0
    permutes_with_window = 0

    def flush(instrs):
        nonlocal total_dots, overlapped_dots, permutes, permutes_with_window
        dots = {i for i, (_, line) in enumerate(instrs)
                if _DOT_RE.search(line)}
        total_dots += len(dots)
        if not instrs:
            return
        # name -> consumer indices (operands are %-prefixed on the RHS)
        users: Dict[str, list] = defaultdict(list)
        for j, (_, line) in enumerate(instrs):
            rhs = line.split("=", 1)[-1]
            for op_name in re.findall(r"%([\w.-]+)", rhs):
                users[op_name].append(j)
        comp_overlapped: set = set()
        for i, (name, line) in enumerate(instrs):
            if not (name and _PERMUTE_DEF_RE.search(line)):
                continue
            permutes += 1
            # first dot that (transitively) consumes this transfer's data,
            # tracing through converts/copies/fusions but NOT through a
            # later permute (that's the data being forwarded, not used)
            close = len(instrs)
            frontier = [name.lstrip("%")]
            seen = set(frontier)
            while frontier:
                nxt = []
                for nm in frontier:
                    for j in users.get(nm, ()):
                        if j <= i:
                            continue
                        jn, jline = instrs[j]
                        if j in dots:
                            close = min(close, j)
                            continue
                        if _PERMUTE_DEF_RE.search(jline):
                            continue
                        jn = jn.lstrip("%")
                        if jn and jn not in seen:
                            seen.add(jn)
                            nxt.append(jn)
                frontier = nxt
            window = {j for j in dots if i < j < close}
            if window:
                permutes_with_window += 1
                comp_overlapped |= window
        overlapped_dots += len(comp_overlapped)

    instrs: list = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{"):        # new computation body begins
            flush(instrs)
            instrs = []
            continue
        if "=" not in stripped:
            continue
        m = _NAME_RE.match(stripped)
        instrs.append((m.group("name") if m else "", stripped))
    flush(instrs)

    return {
        "overlap_fraction": (overlapped_dots / total_dots
                             if total_dots else 0.0),
        "dots_total": total_dots,
        "dots_overlapped": overlapped_dots,
        "permutes_total": permutes,
        "permutes_with_overlap_window": permutes_with_window,
    }


def collective_bytes(hlo_text: str) -> Dict[str, object]:
    """Per-op-kind per-device byte counts + op counts from HLO text."""
    by_kind_bytes: Dict[str, int] = defaultdict(int)
    by_kind_count: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        # skip -done ops (the -start carries the shapes; avoid double count)
        if "-done(" in stripped:
            continue
        for op in _COLLECTIVES:
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                by_kind_bytes[op] += _tuple_bytes(stripped)
                by_kind_count[op] += 1
                break
    total = sum(by_kind_bytes.values())
    return {
        "total_bytes": total,
        "bytes_by_kind": dict(by_kind_bytes),
        "count_by_kind": dict(by_kind_count),
    }
