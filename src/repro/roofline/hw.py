"""TPU v5e hardware constants (the target platform for this build)."""

PEAK_FLOPS_BF16 = 197e12       # per chip, bf16
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW_PER_LINK = 50e9         # bytes/s per ICI link (given constant)
CHIPS_PER_POD = 256
VMEM_BYTES = 128 * 2**20       # ~128 MiB VMEM per chip
HBM_BYTES = 16 * 2**30         # 16 GiB HBM per chip
HOST_LINK_BW = 32e9            # bytes/s device<->pinned-host DMA (PCIe-class)
