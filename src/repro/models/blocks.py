"""Composable transformer blocks (manual-SPMD aware via Runtime).

All blocks follow the spec-first pattern: ``<block>_specs(cfg)`` declares
parameters; ``<block>(rt, params, x, ...)`` applies them. Norms/residuals in
float32; matmuls in the model's param dtype with f32 accumulation.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.runtime import Runtime
from repro.models.spec import PSpec


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(d: int):
    return {"scale": PSpec((d,), ("embed_nosplit",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (S,) global token positions, or (B, S)
    per-sequence positions (continuous-batching decode)."""
    B, S, H, D = x.shape
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., :, None] * freqs  # (..., S, half)
    if ang.ndim == 2:
        ang = ang[None]                                        # (1|B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention block (StarTrail inside)
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    return {
        "wq": PSpec((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((hq, hd, d), ("heads", "head_dim", "embed_out")),
        "norm": rmsnorm_specs(d),
    }


def attention_block(rt: Runtime, params, x, cfg: ModelConfig, *,
                    causal: bool = True, window: Optional[int] = None,
                    prefix_len: Optional[int] = None,
                    return_kv: bool = False):
    """Pre-norm attention with residual. x: (B, S_local, D)."""
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    wq = rt.dense(params["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(params["wk"], ("embed", "kv_heads", "head_dim"))
    wv = rt.dense(params["wv"], ("embed", "kv_heads", "head_dim"))
    wo = rt.dense(params["wo"], ("heads", "head_dim", "embed_out"))

    q = jnp.einsum("bsd,dhk->bshk", h, wq)
    k = jnp.einsum("bsd,dhk->bshk", h, wk)
    v = jnp.einsum("bsd,dhk->bshk", h, wv)
    pos = rt.positions(x.shape[1])
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    o = rt.attention(q, k, v, causal=causal, window=window,
                     prefix_len=prefix_len)
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    if return_kv:
        return x + out, (k, v)
    return x + out


# ---------------------------------------------------------------------------
# MLP: SwiGLU, Megatron-style TP over the model axes (ffn stays sharded;
# activations all-gather over seq -> compute -> reduce-scatter back). In
# 'fsdp' rules the weights are gathered instead and no activation comm runs.
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w1": PSpec((d, f), ("embed", "ffn")),
        "w3": PSpec((d, f), ("embed", "ffn")),
        "w2": PSpec((f, d), ("ffn", "embed_out")),
        "norm": rmsnorm_specs(d),
    }


def mlp_block(rt: Runtime, params, x, cfg: ModelConfig):
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    if rt.mode == "spmd" and rt.rules == "default":
        # TP: gather tokens over the model axes, ffn dim stays sharded
        w1 = rt.dense(params["w1"], ("embed", "ffn"))
        w3 = rt.dense(params["w3"], ("embed", "ffn"))
        w2 = rt.dense(params["w2"], ("ffn", "embed_out"))
        hg = rt.all_gather_model(h, axis=1)              # (B, S_full_local, D)
        u = jnp.einsum("bsd,df->bsf", hg, w1)
        g = jnp.einsum("bsd,df->bsf", hg, w3)
        a = jax.nn.silu(u.astype(jnp.float32)).astype(u.dtype) * g
        o = jnp.einsum("bsf,fd->bsd", a, w2)
        o = rt.psum_scatter_model(o, axis=1)             # back to seq-sharded
    else:
        w1 = rt.dense(params["w1"], ("embed", "ffn"))
        w3 = rt.dense(params["w3"], ("embed", "ffn"))
        w2 = rt.dense(params["w2"], ("ffn", "embed_out"))
        u = jnp.einsum("bsd,df->bsf", h, w1)
        g = jnp.einsum("bsd,df->bsf", h, w3)
        a = jax.nn.silu(u.astype(jnp.float32)).astype(u.dtype) * g
        o = jnp.einsum("bsf,fd->bsd", a, w2)
    return x + o


# ---------------------------------------------------------------------------
# vocab-parallel embedding + logits/loss (Megatron-style over the SP axes)
# ---------------------------------------------------------------------------

def padded_vocab(cfg: ModelConfig, multiple: int = 32) -> int:
    """Megatron-style vocab padding so the table shards evenly over the
    model axes (e.g. seamless's 256206 -> 256224)."""
    v = cfg.vocab_size
    return ((v + multiple - 1) // multiple) * multiple


def embedding_specs(cfg: ModelConfig):
    # d^-0.5 scale keeps initial logits O(1) (the table doubles as the
    # vocab-parallel LM head)
    return {"table": PSpec((padded_vocab(cfg), cfg.d_model),
                           ("vocab", "embed"), scale=cfg.d_model ** -0.5)}


def _vocab_shard_lookup(rt: Runtime, table, ids):
    """Look up ids in this shard's vocab slice (zeros outside). ids: any shape."""
    v_local = table.shape[0]
    lo = rt.sp_rank() * v_local
    ids = ids - lo
    in_range = (ids >= 0) & (ids < v_local)
    ids = jnp.clip(ids, 0, v_local - 1)
    return table[ids] * in_range[..., None].astype(table.dtype)


def embed(rt: Runtime, params, tokens, cfg: ModelConfig, *,
          tokens_replicated: bool = False):
    """tokens: (B, S_local) int32 -> (B, S_local, D).

    Vocab-parallel over the model axes. Tokens are *sequence-sharded*, so
    each shard gathers all shards' token ids (tiny, int32), looks up the
    ones in its vocab slice, and a reduce-scatter over the model axes both
    sums the vocab-slice partials and returns each shard its own positions.
    """
    table = rt.dense(params["table"], ("vocab", "embed"))  # gather embed/data
    if rt.mode == "local":
        return table[tokens]
    if tokens_replicated:  # decode path: same ids on every shard
        return jax.lax.psum(_vocab_shard_lookup(rt, table, tokens), rt.sp_axes)
    tokens_all = rt.all_gather_model(tokens, axis=1)     # (B, S_full)
    out = _vocab_shard_lookup(rt, table, tokens_all)     # partial (B,S_f,D)
    return rt.psum_scatter_model(out, axis=1)


def lm_head_logits_and_loss(rt: Runtime, params, x, labels, cfg: ModelConfig,
                            mask=None):
    """Vocab-parallel cross-entropy. x: (B, S_local, D); labels (B, S_local).

    Sequence is sharded and vocab is sharded over the *same* model axes, so
    the loss runs chunk-by-chunk over the SP shards' activations: every
    shard computes its vocab-slice logits for the current chunk, a psum
    combines logsumexp/gold terms. Full logits are never materialised
    (peak extra memory: B x S_local x V/P_model).
    """
    table = rt.dense(params["table"], ("vocab", "embed"))
    tf32 = table.astype(jnp.float32)
    if rt.mode == "local":
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), tf32)
        if table.shape[0] > cfg.vocab_size:  # mask padded vocab rows
            logits = jnp.where(
                jnp.arange(table.shape[0]) < cfg.vocab_size, logits,
                -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        losses = logz - gold
        if mask is not None:
            losses = losses * mask
            denom = jnp.sum(mask)
        else:
            denom = jnp.asarray(losses.size, jnp.float32)
        return jnp.sum(losses) / denom

    v_local = table.shape[0]
    lo = rt.sp_rank() * v_local
    x_all = rt.all_gather_sp_stack(x)                 # (Psp, B, S_l, D)
    lab_all = rt.all_gather_sp_stack(labels)          # (Psp, B, S_l)
    if mask is not None:
        mask_all = rt.all_gather_sp_stack(mask)
    else:
        mask_all = jnp.ones(lab_all.shape, jnp.float32)

    row_valid = (lo + jnp.arange(v_local)) < cfg.vocab_size

    def body(acc, inp):
        xi, li, mi = inp
        logits = jnp.einsum("bsd,vd->bsv", xi.astype(jnp.float32), tf32)
        logits = jnp.where(row_valid, logits, -1e30)  # padded vocab rows
        m_loc = jnp.max(logits, axis=-1)
        # stop_gradient *before* pmax: the logsumexp shift constant is
        # gradient-invariant and pmax has no JVP rule, so it must not see
        # a tangent-carrying input
        m = jax.lax.pmax(jax.lax.stop_gradient(m_loc), rt.sp_axes)
        se = jax.lax.psum(
            jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), rt.sp_axes)
        logz = m + jnp.log(se)
        ids = li - lo
        in_range = (ids >= 0) & (ids < v_local)
        ids = jnp.clip(ids, 0, v_local - 1)
        gold_loc = jnp.take_along_axis(logits, ids[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(gold_loc * in_range.astype(jnp.float32),
                            rt.sp_axes)
        losses = (logz - gold) * mi
        return (acc[0] + jnp.sum(losses), acc[1] + jnp.sum(mi)), None

    (total, denom), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (x_all, lab_all, mask_all),
        unroll=x_all.shape[0] if rt.unroll_scans else 1)
    # total/denom are identical on every SP shard; reduce over batch axes only
    total = jax.lax.psum(total, tuple(rt.batch_axes))
    denom = jax.lax.psum(denom, tuple(rt.batch_axes))
    return total / denom
