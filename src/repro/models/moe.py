"""Mixture-of-Experts layer with manual expert parallelism.

Layout: EP over the ``data`` axis (experts sharded), TP over the model axes
(expert ffn dim sharded). Dataflow per MoE layer, all collectives explicit:

  route (top-k, capacity)  ->  dispatch einsum  ->  all_to_all over data
  -> all_gather tokens over model axes -> expert SwiGLU (ffn/16 slice)
  -> psum_scatter over model -> all_to_all back -> combine einsum

Token-choice top-k routing with a capacity factor (dropped tokens pass
through the residual, standard practice); load-balance + router-z auxiliary
losses are returned to the caller.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import blocks
from repro.models.runtime import Runtime
from repro.models.spec import PSpec


def moe_specs(cfg: ModelConfig):
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    specs = {
        "router": PSpec((d, e), ("embed_nosplit", None), scale=d ** -0.5),
        "w1": PSpec((e, d, f), ("experts", "expert_embed", "expert_ffn")),
        "w3": PSpec((e, d, f), ("experts", "expert_embed", "expert_ffn")),
        "w2": PSpec((e, f, d), ("experts", "expert_ffn", "expert_embed")),
        "norm": blocks.rmsnorm_specs(d),
    }
    if m.shared_expert:
        specs["shared"] = blocks.mlp_specs(cfg, d_ff=m.d_ff_expert)
    return specs


def _capacity(tokens: int, m: MoEConfig) -> int:
    cap = int(tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(cap, m.top_k)


def moe_block(rt: Runtime, params, x, cfg: ModelConfig):
    """x: (B, S_local, D) -> (B, S_local, D) residual-added; plus aux losses."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E = m.num_experts
    h = blocks.rmsnorm(params["norm"], x, cfg.norm_eps)
    ht = h.reshape(T, D)

    # ---- routing (float32) ----
    logits = jnp.einsum("td,de->te", ht.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, m.top_k)          # (T, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)             # renormalise

    # aux losses (Switch-style load balance + router z-loss), computed over
    # the GLOBAL token population (psum-mean over batch+seq shards) so the
    # objective is partition-invariant
    t_glob = rt.psum_all(jnp.asarray(T, jnp.float32))
    me = rt.psum_all(probs.sum(axis=0)) / t_glob             # (E,)
    ce = rt.psum_all(
        jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(1.0)
    ) / (t_glob * m.top_k)
    aux_lb = E * jnp.sum(me * ce)
    aux_z = rt.psum_all(
        jnp.sum(jax.nn.logsumexp(logits, axis=-1) ** 2)) / t_glob

    # ---- dispatch/combine tensors with capacity ----
    cap = _capacity(T, m)
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)       # (T, k, E)
    # position of each (t, k) within its expert queue
    flat = onehot.reshape(T * m.top_k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                    # (T*k, E)
    pos = (pos * flat).sum(-1).reshape(T, m.top_k).astype(jnp.int32)  # (T, k)
    keep = pos < cap
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    # dispatch (T, E, cap), combine = dispatch * gate
    disp = jnp.einsum("tke,tkc->tec", onehot, pos_oh)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, gate_vals)

    xe = jnp.einsum("tec,td->ecd", disp, ht.astype(jnp.float32)).astype(x.dtype)

    # ---- EP all_to_all over data ----
    if rt.mode == "spmd":
        ep = jax.lax.axis_size("data")
        if E % ep != 0:
            raise ValueError(f"experts {E} must divide over data axis {ep}")
    else:
        ep = 1
    # (E, cap, D) -> (E_local, ep*cap, D) on the owning shards
    xe = rt.all_to_all_data(xe, split_axis=0, concat_axis=1)
    if rt.rules == "fsdp" and rt.mode == "spmd":
        # gather the expert WEIGHTS over the model axes instead of the
        # dispatched tokens: weights (3*D*F_expert) are smaller than the
        # token set (SP_degree * cap * D) for the big-batch train shapes —
        # ~4x less all-gather traffic on jamba/llama4 (see EXPERIMENTS §Perf)
        w1 = rt.all_gather_model(params["w1"], axis=2)
        w3 = rt.all_gather_model(params["w3"], axis=2)
        w2 = rt.all_gather_model(params["w2"], axis=1)
        u = jnp.einsum("ecd,edf->ecf", xe, w1)
        g = jnp.einsum("ecd,edf->ecf", xe, w3)
        a = jax.nn.silu(u.astype(jnp.float32)).astype(u.dtype) * g
        o = jnp.einsum("ecf,efd->ecd", a, w2)
    else:
        # ---- TP over model axes: gather tokens, ffn stays sharded ----
        xg = rt.all_gather_model(xe, axis=1)              # (E_l, SPtok, D)
        u = jnp.einsum("ecd,edf->ecf", xg, params["w1"])
        g = jnp.einsum("ecd,edf->ecf", xg, params["w3"])
        a = jax.nn.silu(u.astype(jnp.float32)).astype(u.dtype) * g
        o = jnp.einsum("ecf,efd->ecd", a, params["w2"])
        o = rt.psum_scatter_model(o, axis=1)              # (E_l, ep*cap, D)
    o = rt.all_to_all_data(o, split_axis=1, concat_axis=0)  # (E, cap, D)

    y = jnp.einsum("tec,ecd->td", comb, o.astype(jnp.float32))
    y = y.reshape(B, S, D).astype(x.dtype)

    if m.shared_expert:
        y = y + (blocks.mlp_block(rt, params["shared"], h, cfg) - h)

    return x + y, {"moe_lb": aux_lb, "moe_z": aux_z}
