"""Spec-first parameter trees.

Modules declare their parameters once as ``PSpec`` trees (shape + logical
axis names + initialiser); the same tree then yields
  * materialised params       (``init_tree``, for real runs / smoke tests)
  * abstract params           (``abstract_tree``, ShapeDtypeStructs for the
                               dry-run: .lower() without any allocation)
  * PartitionSpecs            (``partition_tree`` via dist.sharding rules)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis name per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: Optional[float] = None         # stddev; default fan-in
    dtype: Optional[str] = None           # override model param_dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_spec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_specs(fn: Callable[[PSpec], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=_is_spec)


def init_tree(tree, key: jax.Array, default_dtype: str):
    leaves = jax.tree.leaves(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def init_one(spec: PSpec):
        i = next(it)
        dtype = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[0] if spec.shape else 1
        scale = spec.scale if spec.scale is not None else fan_in ** -0.5
        return (jax.random.normal(keys[i], spec.shape, jnp.float32) * scale).astype(dtype)

    return tree_map_specs(init_one, tree)


def abstract_tree(tree, default_dtype: str):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype)),
        tree,
    )


def axes_tree(tree):
    return tree_map_specs(lambda s: s.axes, tree)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scan) dimension to every spec in the tree."""
    return tree_map_specs(
        lambda s: PSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale,
                        s.dtype),
        tree,
    )


def count_params(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(tree, is_leaf=_is_spec))
