"""Runtime context threading mesh/axis/mode information through model code.

Two modes:
  * ``local``  — single device, no collectives (smoke tests, tiny runs).
    Gathers are identity, attention is the jnp reference, positions are
    ``arange``.
  * ``spmd``   — inside one big ``shard_map`` over the refined mesh; all
    communication is explicit (manual SPMD). Params arrive sharded per
    ``dist.sharding`` rules; ``dense()`` gathers FSDP leaves on use (their
    gradients reduce-scatter automatically via the all_gather transpose —
    ZeRO-3 semantics).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import startrail as st
from repro.core import ulysses as ulysses_lib
from repro.dist import sharding as shard_rules
from repro.kernels import dispatch as kernels


@dataclasses.dataclass(frozen=True)
class Runtime:
    mode: str                                  # 'local' | 'spmd'
    st_cfg: st.StarTrailConfig
    batch_axes: Tuple[str, ...] = ("data",)    # ('pod','data') multi-pod
    rules: str = "default"
    attention_impl: str = "startrail"          # 'startrail' | 'ulysses' | 'local'
    kernel_impl: str = "ref"                   # decode kernel: 'ref' | 'pallas'
    unroll_scans: bool = False                 # dry-run cost accounting

    # ---- axis info -----------------------------------------------------
    @property
    def sp_axes(self) -> Tuple[str, str, str]:
        return tuple(self.st_cfg.axes)

    def sp_size(self) -> int:
        if self.mode == "local":
            return 1
        n = 1
        for a in self.sp_axes:
            n *= jax.lax.axis_size(a)
        return n

    def sp_rank(self) -> jax.Array:
        if self.mode == "local":
            return jnp.int32(0)
        g, r, t = self.sp_axes
        c = jax.lax.axis_size(t)
        rr = jax.lax.axis_size(r)
        return (jax.lax.axis_index(g) * rr + jax.lax.axis_index(r)) * c + jax.lax.axis_index(t)

    def dp_size(self) -> int:
        if self.mode == "local":
            return 1
        n = 1
        for a in self.batch_axes:
            n *= jax.lax.axis_size(a)
        return n

    # ---- positions -----------------------------------------------------
    def positions(self, s_local: int) -> jax.Array:
        """Global token positions of this shard's sequence slice."""
        if self.mode == "local":
            return jnp.arange(s_local, dtype=jnp.int32)
        p = self.sp_size()
        return st.shard_positions(
            self.sp_rank(), s_local * p, p, self.st_cfg.seq_scheme)

    def positions_contig(self, s_local: int) -> jax.Array:
        """Contiguous positions (KV-cache layout), independent of scheme."""
        if self.mode == "local":
            return jnp.arange(s_local, dtype=jnp.int32)
        return self.sp_rank() * s_local + jnp.arange(s_local, dtype=jnp.int32)

    # ---- FSDP parameter gathering ---------------------------------------
    def dense(self, leaf: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
        """Gather a parameter leaf's FSDP-sharded dims for dense use."""
        if self.mode == "local":
            return leaf
        fsdp = shard_rules.fsdp_logical(self.rules)
        rules = shard_rules.RULES[self.rules]
        for dim, ax in enumerate(axes):
            if ax in fsdp and rules.get(ax):
                for mesh_ax in rules[ax]:
                    leaf = jax.lax.all_gather(leaf, mesh_ax, axis=dim, tiled=True)
        return leaf

    # ---- collectives (no-ops in local mode) ------------------------------
    def psum_model(self, x):
        if self.mode == "local":
            return x
        return jax.lax.psum(x, self.sp_axes)

    def psum_scatter_model(self, x, axis: int):
        if self.mode == "local":
            return x
        g, r, t = self.sp_axes
        for a in (g, r, t):
            x = jax.lax.psum_scatter(x, a, scatter_dimension=axis, tiled=True)
        return x

    def all_gather_model(self, x, axis: int):
        if self.mode == "local":
            return x
        g, r, t = self.sp_axes
        for a in (t, r, g):  # inverse order so tiling matches scatter
            x = jax.lax.all_gather(x, a, axis=axis, tiled=True)
        return x

    def psum_all(self, x):
        if self.mode == "local":
            return x
        return jax.lax.psum(x, tuple(self.batch_axes) + self.sp_axes)

    def all_to_all_data(self, x, split_axis: int, concat_axis: int):
        if self.mode == "local":
            return x
        return jax.lax.all_to_all(x, "data", split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)

    def ppermute_prev_shard(self, x):
        """Receive x from the previous SP shard (linear order); shard 0
        receives zeros. Used for conv halos / state passing."""
        if self.mode == "local":
            return jnp.zeros_like(x)
        # build (src, dst) pairs: src p -> dst p+1
        sizes = [jax.lax.axis_size(a) for a in self.sp_axes]
        p = sizes[0] * sizes[1] * sizes[2]
        perm = [(i, i + 1) for i in range(p - 1)]
        return jax.lax.ppermute(x, self.sp_axes, perm)

    def all_gather_sp_stack(self, x):
        """Gather per-shard values into a leading SP dim (P, ...)."""
        if self.mode == "local":
            return x[None]
        g, r, t = self.sp_axes
        y = jax.lax.all_gather(x, t, axis=0, tiled=False)
        y = jax.lax.all_gather(y, r, axis=0, tiled=False)
        y = jax.lax.all_gather(y, g, axis=0, tiled=False)
        # shape (G, R, T, ...) -> (P, ...) in linear rank order
        return y.reshape((-1,) + x.shape)

    # ---- attention -------------------------------------------------------
    def attention(self, q, k, v, *, causal=None, window=None,
                  prefix_len=None) -> jax.Array:
        cfg = self.st_cfg
        if causal is not None and causal != cfg.causal:
            cfg = dataclasses.replace(cfg, causal=causal)
        if window != cfg.window:
            cfg = dataclasses.replace(cfg, window=window)
        if prefix_len != cfg.prefix_len:
            cfg = dataclasses.replace(cfg, prefix_len=prefix_len)
        if self.mode == "local" or self.attention_impl == "local":
            s = q.shape[1]
            pos = self.positions(s)
            return kernels.prefill(
                q, k, v, pos, pos, causal=cfg.causal, window=cfg.window,
                prefix_len=cfg.prefix_len, impl=cfg.block_impl)
        if self.attention_impl == "ulysses":
            # per-layer dispatch: Ulysses only where this layer's head
            # counts divide the SP degree (the plan layer rejects configs
            # where *no* layer qualifies); others fall back to StarTrail
            sp = self.sp_size()
            if q.shape[2] % sp == 0 and k.shape[2] % sp == 0:
                return ulysses_lib.ulysses_attention(q, k, v, cfg)
            return st.startrail_attention(q, k, v, cfg)
        return st.startrail_attention(q, k, v, cfg)
