"""Encoder-decoder model (seamless-m4t family).

Encoder: full-mask StarTrail self-attention + MLP over frame embeddings
(audio frontend stubbed — ``input_specs`` supplies the frames).
Decoder: causal StarTrail self-attention + cross-attention + MLP.

Cross-attention: encoder K/V are static across decoding, so each layer
team-gathers them once over all SP axes (one all-gather, no ring — the
degenerate-but-optimal StarTrail configuration for a static K/V set) and
queries attend locally.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import blocks, spec
from repro.models.runtime import Runtime
from repro.kernels import dispatch as kernels


def cross_attention_specs(cfg: ModelConfig):
    return blocks.attention_specs(cfg)


def cross_attention_block(rt: Runtime, params, x, enc_kv, cfg: ModelConfig):
    """x: (B, S_local, D) decoder; enc_kv: (B, S_local, D) encoder output."""
    h = blocks.rmsnorm(params["norm"], x, cfg.norm_eps)
    wq = rt.dense(params["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(params["wk"], ("embed", "kv_heads", "head_dim"))
    wv = rt.dense(params["wv"], ("embed", "kv_heads", "head_dim"))
    wo = rt.dense(params["wo"], ("heads", "head_dim", "embed_out"))

    q = jnp.einsum("bsd,dhk->bshk", h, wq)
    k = jnp.einsum("bsd,dhk->bshk", enc_kv, wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_kv, wv)
    # static K/V: gather once over the SP axes (team gather, no ring)
    k = rt.all_gather_model(k, axis=1)
    v = rt.all_gather_model(v, axis=1)
    s_q = q.shape[1]
    pos_q = rt.positions(s_q)
    pos_k = jnp.arange(k.shape[1], dtype=jnp.int32)  # order-free (full mask)
    o = kernels.prefill(q, k, v, pos_q, pos_k, causal=False,
                        impl=rt.st_cfg.block_impl)
    return x + jnp.einsum("bshk,hkd->bsd", o, wo)


def encdec_specs(cfg: ModelConfig):
    enc_layer = {
        "attn": blocks.attention_specs(cfg),
        "mlp": blocks.mlp_specs(cfg),
    }
    dec_layer = {
        "attn": blocks.attention_specs(cfg),
        "cross": cross_attention_specs(cfg),
        "mlp": blocks.mlp_specs(cfg),
    }
    return {
        "frontend_proj": spec.PSpec((cfg.d_model, cfg.d_model),
                                    ("embed_nosplit", "embed_out")),
        "encoder": spec.stack_specs(enc_layer, cfg.num_encoder_layers),
        "enc_norm": blocks.rmsnorm_specs(cfg.d_model),
        "embed": blocks.embedding_specs(cfg),
        "decoder": spec.stack_specs(dec_layer, cfg.num_layers),
        "final_norm": blocks.rmsnorm_specs(cfg.d_model),
        "lm_head": blocks.embedding_specs(cfg),
    }


def encdec_loss(rt: Runtime, params, batch, cfg: ModelConfig, *,
                remat: str = "attn_out"):
    """batch: {frontend_emb (B,S,D), tokens (B,S), labels (B,S)}."""
    # ---- encoder (full mask) ----
    fp = rt.dense(params["frontend_proj"], ("embed_nosplit", "embed_out"))
    x = jnp.einsum("bsd,de->bse",
                   batch["frontend_emb"].astype(fp.dtype), fp)

    def enc_period(x, p):
        x = blocks.attention_block(rt, p["attn"], x, cfg, causal=False)
        x = checkpoint_name(x, "attn_out")
        x = blocks.mlp_block(rt, p["mlp"], x, cfg)
        return x, jnp.zeros((), jnp.float32)

    def dec_period_fn(enc_out):
        def dec_period(x, p):
            x = blocks.attention_block(rt, p["attn"], x, cfg, causal=True)
            x = checkpoint_name(x, "attn_out")
            x = cross_attention_block(rt, p["cross"], x, enc_out, cfg)
            x = checkpoint_name(x, "cross_out")
            x = blocks.mlp_block(rt, p["mlp"], x, cfg)
            return x, jnp.zeros((), jnp.float32)
        return dec_period

    policy = jax.checkpoint_policies.save_only_these_names(
        "attn_out", "cross_out")
    enc_fn = enc_period
    if remat == "attn_out":
        enc_fn = jax.checkpoint(enc_period, policy=policy)
    elif remat == "full":
        enc_fn = jax.checkpoint(enc_period)

    def enc_body(c, p):
        x, _ = enc_fn(c, p)
        return x, None

    n_enc = jax.tree.leaves(params["encoder"])[0].shape[0]
    x, _ = jax.lax.scan(enc_body, x, params["encoder"],
                        unroll=n_enc if rt.unroll_scans else 1)
    enc_out = blocks.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # ---- decoder (causal + cross) ----
    y = blocks.embed(rt, params["embed"], batch["tokens"], cfg)
    dec_period = dec_period_fn(enc_out)
    dec_fn = dec_period
    if remat == "attn_out":
        dec_fn = jax.checkpoint(dec_period, policy=policy)
    elif remat == "full":
        dec_fn = jax.checkpoint(dec_period)

    def dec_body(c, p):
        y, _ = dec_fn(c, p)
        return y, None

    n_dec = jax.tree.leaves(params["decoder"])[0].shape[0]
    y, _ = jax.lax.scan(dec_body, y, params["decoder"],
                        unroll=n_dec if rt.unroll_scans else 1)
    y = blocks.rmsnorm(params["final_norm"], y, cfg.norm_eps)
    return blocks.lm_head_logits_and_loss(rt, params["lm_head"], y,
                                          batch["labels"], cfg)
