"""Model factory: config -> (specs, init, abstract, partition, loss, inputs)."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shard_rules
from repro.models import blocks, encdec, spec, transformer
from repro.models.runtime import Runtime


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    specs: object

    # ---- parameters ------------------------------------------------------
    def init(self, key: jax.Array):
        return spec.init_tree(self.specs, key, self.cfg.param_dtype)

    def abstract(self):
        return spec.abstract_tree(self.specs, self.cfg.param_dtype)

    def axes(self):
        return spec.axes_tree(self.specs)

    def partition(self, rules: str = "default"):
        return shard_rules.partition_tree(self.axes(), rules)

    def param_count(self) -> int:
        return spec.count_params(self.specs)

    # ---- training loss ----------------------------------------------------
    def loss(self, rt: Runtime, params, batch, *, remat: str = "attn_out"):
        if self.cfg.encdec:
            return encdec.encdec_loss(rt, params, batch, self.cfg, remat=remat)
        return transformer.lm_loss(rt, params, batch, self.cfg, remat=remat)

    # ---- inputs -----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """Global-shape ShapeDtypeStruct stand-ins for every model input
        (weak-type-correct, shardable, no device allocation)."""
        b, s = shape.global_batch, shape.seq_len
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if self.cfg.frontend_stub is not None:
            out["frontend_emb"] = jax.ShapeDtypeStruct(
                (b, s, self.cfg.d_model), jnp.dtype(self.cfg.param_dtype))
        return out

    def make_batch(self, key: jax.Array, shape: ShapeConfig):
        """Random concrete batch matching input_specs (tests/examples)."""
        ks = jax.random.split(key, 3)
        b, s = shape.global_batch, shape.seq_len
        batch = {
            "tokens": jax.random.randint(ks[0], (b, s), 0,
                                         self.cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(ks[1], (b, s), 0,
                                         self.cfg.vocab_size, jnp.int32),
        }
        if self.cfg.frontend_stub is not None:
            batch["frontend_emb"] = jax.random.normal(
                ks[2], (b, s, self.cfg.d_model), jnp.float32).astype(
                    jnp.dtype(self.cfg.param_dtype))
        return batch


def build_model(cfg: ModelConfig) -> Model:
    if cfg.encdec:
        specs = encdec.encdec_specs(cfg)
    else:
        specs = transformer.lm_specs(cfg)
    return Model(cfg=cfg, specs=specs)
