"""Decoder LM assembly: pattern-based layer stack, scan-over-periods, remat.

Heterogeneous architectures (MoE interleave, Jamba's 1:7 attn:mamba, xLSTM's
7:1 mLSTM:sLSTM) are expressed as a repeating *period* of sub-layers; the
stack scans over ``num_layers / period`` period instances with stacked
params (one lowering of the period body — keeps dry-run HLO small).

Remat policy 'attn_out' is the paper's DistFlashAttn-style placement: the
attention output is checkpointed so backward never recomputes the ring
attention forward.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import blocks, moe, spec, ssm
from repro.models.runtime import Runtime


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------

def layer_pattern(cfg: ModelConfig) -> List[Tuple[str, Optional[str]]]:
    """The repeating (mixer, mlp) period of the architecture."""
    period = 1
    if cfg.moe is not None:
        period = max(period, cfg.moe.every_n_layers)
    if cfg.family == "hybrid":
        period = max(period, cfg.attn_every)
        if cfg.moe is not None:
            import math

            period = math.lcm(cfg.attn_every, cfg.moe.every_n_layers)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        period = max(period, cfg.xlstm.slstm_every)
    if cfg.num_layers % period:
        raise ValueError(f"{cfg.num_layers=} not divisible by {period=}")
    pat = []
    for i in range(period):
        mixer = cfg.mixer_on_layer(i)
        if cfg.d_ff == 0 and cfg.moe is None:
            mlp = None                      # xLSTM blocks have no FFN
        elif cfg.moe_on_layer(i):
            mlp = "moe"
        else:
            mlp = "mlp"
        pat.append((mixer, mlp))
    return pat


def _sublayer_specs(cfg: ModelConfig, mixer: str, mlp: Optional[str]):
    s: Dict[str, object] = {}
    if mixer == "attn":
        s["mixer"] = blocks.attention_specs(cfg)
    elif mixer == "mamba":
        s["mixer"] = ssm.mamba_specs(cfg)
    elif mixer == "mlstm":
        s["mixer"] = ssm.mlstm_specs(cfg)
    elif mixer == "slstm":
        s["mixer"] = ssm.slstm_specs(cfg)
    else:
        raise ValueError(mixer)
    if mlp == "mlp":
        s["mlp"] = blocks.mlp_specs(cfg)
    elif mlp == "moe":
        s["mlp"] = moe.moe_specs(cfg)
    return s


def stack_specs(cfg: ModelConfig, num_layers: Optional[int] = None):
    pat = layer_pattern(cfg)
    n_layers = num_layers or cfg.num_layers
    n_periods = n_layers // len(pat)
    period_specs = {f"sub{i}": _sublayer_specs(cfg, mx, ml)
                    for i, (mx, ml) in enumerate(pat)}
    return spec.stack_specs(period_specs, n_periods)


def _apply_sublayer(rt: Runtime, p, x, cfg: ModelConfig, mixer: str,
                    mlp: Optional[str], *, causal: bool, prefix_len):
    aux = {}
    if mixer == "attn":
        x = blocks.attention_block(rt, p["mixer"], x, cfg, causal=causal,
                                   window=cfg.window, prefix_len=prefix_len)
        x = checkpoint_name(x, "attn_out")
    elif mixer == "mamba":
        x = ssm.mamba_block(rt, p["mixer"], x, cfg)
    elif mixer == "mlstm":
        x = ssm.mlstm_block(rt, p["mixer"], x, cfg)
    elif mixer == "slstm":
        x = ssm.slstm_block(rt, p["mixer"], x, cfg)
    if mlp == "mlp":
        x = blocks.mlp_block(rt, p["mlp"], x, cfg)
    elif mlp == "moe":
        x, aux = moe.moe_block(rt, p["mlp"], x, cfg)
    return x, aux


def apply_stack(rt: Runtime, stack_params, x, cfg: ModelConfig, *,
                causal: bool = True, prefix_len=None, remat: str = "attn_out",
                num_layers: Optional[int] = None):
    """x: (B, S_local, D) -> (B, S_local, D). Returns (x, aux_losses)."""
    pat = layer_pattern(cfg)

    def period_fn(x, p):
        aux_tot = jnp.zeros((), jnp.float32)
        for i, (mx, ml) in enumerate(pat):
            x, aux = _apply_sublayer(rt, p[f"sub{i}"], x, cfg, mx, ml,
                                     causal=causal, prefix_len=prefix_len)
            if aux:
                aux_tot = aux_tot + 0.01 * aux["moe_lb"] + 1e-3 * aux["moe_z"]
        return x, aux_tot

    if remat == "attn_out":
        period_fn = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.save_only_these_names("attn_out"))
    elif remat == "full":
        period_fn = jax.checkpoint(period_fn)

    def body(carry, p):
        x, aux = carry
        x, a = period_fn(x, p)
        return (x, aux + a), None

    n_periods = jax.tree.leaves(stack_params)[0].shape[0]
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               stack_params,
                               unroll=n_periods if rt.unroll_scans else 1)
    return x, aux


# ---------------------------------------------------------------------------
# decoder LM
# ---------------------------------------------------------------------------

def lm_specs(cfg: ModelConfig):
    s = {
        "embed": blocks.embedding_specs(cfg),
        "stack": stack_specs(cfg),
        "final_norm": blocks.rmsnorm_specs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = blocks.embedding_specs(cfg)
    return s


def lm_loss(rt: Runtime, params, batch, cfg: ModelConfig, *,
            remat: str = "attn_out"):
    """batch: {tokens, labels[, frontend_emb]} (per-shard inside shard_map,
    global in local mode). Returns scalar mean loss (+ aux)."""
    tokens = batch["tokens"]
    x = blocks.embed(rt, params["embed"], tokens, cfg)
    prefix_len = None
    loss_mask = None
    if cfg.frontend_stub is not None and "frontend_emb" in batch:
        prefix_len = int(cfg.prefix_len_frac * rt.st_cfg.seq_len)
        pos = rt.positions(tokens.shape[1])
        is_prefix = (pos < prefix_len)[None, :, None]
        x = jnp.where(is_prefix, batch["frontend_emb"].astype(x.dtype), x)
        loss_mask = 1.0 - is_prefix[..., 0].astype(jnp.float32)
        loss_mask = jnp.broadcast_to(loss_mask, tokens.shape)
    x, aux = apply_stack(rt, params["stack"], x, cfg, causal=True,
                         prefix_len=prefix_len, remat=remat)
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    loss = blocks.lm_head_logits_and_loss(rt, head, x, batch["labels"], cfg,
                                          mask=loss_mask)
    return loss + aux
