"""Sequence-mixing recurrences: Mamba (SSD form), mLSTM, sLSTM.

All three share one primitive — a gated linear recurrence

    h_t = a_t * h_{t-1} + k_t v_t^T ;   y_t = q_t . h_t

computed in the chunked (SSD / gated-linear-attention) form: O(S * Lc)
intra-chunk work + an O(S / Lc) inter-chunk scan, no per-token state
materialisation. This is the TPU-friendly adaptation of Mamba's selective
scan (see DESIGN.md): MXU-shaped matmuls instead of a sequential kernel.

Sequence parallelism: shards compute locally with h0 = 0, then exchange
per-shard (final state, total decay) summaries — a single gather of tiny
state tensors — and add the linear h0-correction term. This applies the
paper's hierarchical-communication insight to the recurrence instead of a
P-step serial chain (StarTrail's K/V ring is attention-specific).
Requires *contiguous* sequence sharding (enforced by the factory for
ssm/hybrid archs).

sLSTM (nonlinear recurrence, not scannable in parallel) keeps shard-local
state during training — documented approximation; decode is exact.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig, XLSTMConfig
from repro.models import blocks
from repro.models.runtime import Runtime
from repro.models.spec import PSpec


# ---------------------------------------------------------------------------
# the shared chunked gated linear recurrence
# ---------------------------------------------------------------------------

def chunked_gla(q, k, v, log_decay, chunk: int):
    """Chunked gated linear attention.

    q, k: (B, S, H, N); v: (B, S, H, P); log_decay: (B, S, H), entries <= 0.
    Returns:
      y       (B, S, H, P)  with h0 = 0
      h_fin   (B, H, N, P)  final state
      ld_tot  (B, H)        total log decay over the shard
      la      (B, S, H)     inclusive cumulative log decay (for h0 correction)
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    Lc = min(chunk, S)
    if S % Lc:
        raise ValueError(f"S={S} % chunk={Lc}")
    nc = S // Lc
    qc = q.astype(jnp.float32).reshape(B, nc, Lc, H, N)
    kc = k.astype(jnp.float32).reshape(B, nc, Lc, H, N)
    vc = v.astype(jnp.float32).reshape(B, nc, Lc, H, P)
    ld = log_decay.astype(jnp.float32).reshape(B, nc, Lc, H)
    la = jnp.cumsum(ld, axis=2)                      # inclusive within chunk

    # intra-chunk: y_intra[i] = sum_{j<=i} exp(la_i - la_j) (q_i.k_j) v_j
    scores = jnp.einsum("bclhn,bcmhn->bchlm", qc, kc)    # (B,nc,H,Lc,Lc)
    decay = la[..., :, None, :] - la[..., None, :, :]    # (B,nc,Lc,Lc,H)
    decay = jnp.moveaxis(decay, -1, 2)                   # (B,nc,H,Lc,Lc)
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    w = jnp.where(tri, jnp.exp(jnp.where(tri, decay, 0.0)), 0.0)
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", scores * w, vc)

    # chunk summaries: state_c = sum_j exp(la_L - la_j) k_j v_j^T
    wk = jnp.exp(la[:, :, -1:, :] - la)                  # (B,nc,Lc,H)
    state_c = jnp.einsum("bclhn,bclh,bclhp->bchnp", kc, wk, vc)
    ld_chunk = la[:, :, -1, :]                           # (B,nc,H)

    # inter-chunk scan: h after chunk c
    def step(h, inp):
        s_c, ldc = inp
        h_in = h
        h = h * jnp.exp(ldc)[..., None, None] + s_c
        return h, h_in

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_fin, h_ins = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(ld_chunk, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                    # (B,nc,H,N,P)

    # inter-chunk contribution: y_inter[i] = exp(la_i) q_i . h_in(chunk)
    y_inter = jnp.einsum("bclhn,bclh,bchnp->bclhp", qc, jnp.exp(la), h_ins)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    ld_tot = jnp.sum(log_decay.astype(jnp.float32), axis=1)   # (B,H)
    la_full = la.reshape(B, S, H)
    # make la cumulative across chunks too
    chunk_off = jnp.concatenate(
        [jnp.zeros((B, 1, H), jnp.float32), jnp.cumsum(ld_chunk, axis=1)[:, :-1]],
        axis=1)
    la_full = (la + chunk_off[:, :, None, :]).reshape(B, S, H)
    return y, h_fin, ld_tot, la_full


def cross_shard_correction(rt: Runtime, q, la_full, h_fin, ld_tot):
    """Add the h0 term from preceding SP shards (contiguous sharding).

    q: (B, S, H, N); la_full: (B, S, H); h_fin: (B, H, N, P); ld_tot: (B, H).
    Returns the correction y_corr (B, S, H, P) and this shard's true final
    state (for serving) -- in local mode both are the trivial values.
    """
    if rt.mode == "local":
        return jnp.zeros(q.shape[:3] + (h_fin.shape[-1],), jnp.float32), h_fin
    stacked_h = rt.all_gather_sp_stack(h_fin)        # (Psp, B, H, N, P)
    stacked_ld = rt.all_gather_sp_stack(ld_tot)      # (Psp, B, H)
    psp = stacked_ld.shape[0]
    rank = rt.sp_rank()
    cs = jnp.cumsum(stacked_ld, axis=0)              # inclusive
    # weight for shard p' (< rank): exp(sum_{p''=p'+1..rank-1} ld[p''])
    #   = exp(cs[rank-1] - cs[p'])
    cs_prev = jnp.where(rank > 0, cs[jnp.maximum(rank - 1, 0)], 0.0)
    idx = jnp.arange(psp)
    valid = (idx < rank)[:, None, None]
    # mask BEFORE the exp: entries at/after this shard have positive
    # exponents that overflow to inf (then inf*0 -> NaN in the vjp)
    delta = jnp.where(valid, cs_prev[None] - cs, -jnp.inf)
    w = jnp.exp(delta)                               # (Psp, B, H)
    h0 = jnp.einsum("pbh,pbhnq->bhnq", w, stacked_h)
    y_corr = jnp.einsum("bshn,bsh,bhnq->bshq", q.astype(jnp.float32),
                        jnp.exp(la_full), h0)
    h_true = h0 * jnp.exp(ld_tot)[..., None, None] + h_fin
    return y_corr, h_true


# ---------------------------------------------------------------------------
# Mamba (SSD) mixer
# ---------------------------------------------------------------------------

def mamba_specs(cfg: ModelConfig):
    m = cfg.mamba or MambaConfig()
    d = cfg.d_model
    di = m.expand * d
    hm = di // m.head_dim
    n = m.d_state
    return {
        "in_proj": PSpec((d, 2 * di + 2 * n + hm), ("embed", "mamba_inner")),
        "conv_w": PSpec((m.d_conv, di), ("conv", "mamba_inner"),
                        scale=m.d_conv ** -0.5),
        "A_log": PSpec((hm,), ("state",), init="zeros"),
        "dt_bias": PSpec((hm,), ("state",), init="zeros"),
        "D_skip": PSpec((hm,), ("state",), init="ones"),
        "norm_in": blocks.rmsnorm_specs(d),
        "norm": {"scale": PSpec((di,), ("embed_nosplit",), init="ones")},
        "out_proj": PSpec((di, d), ("mamba_inner", "embed_out")),
    }


def _causal_conv(rt: Runtime, x, w, *, halo_exchange: bool = True):
    """Depthwise causal conv across shard boundaries. x (B,S,C); w (K,C)."""
    K = w.shape[0]
    if halo_exchange:
        halo = rt.ppermute_prev_shard(x[:, -(K - 1):])
    else:
        halo = jnp.zeros_like(x[:, : K - 1])
    pad = jnp.concatenate([halo, x], axis=1)
    S = x.shape[1]
    out = jnp.zeros_like(x, shape=x.shape).astype(jnp.float32)
    for o in range(K):
        out = out + pad[:, o:o + S].astype(jnp.float32) * w[o].astype(jnp.float32)
    return out.astype(x.dtype)


def _from_last_shard(rt: Runtime, x):
    """Broadcast the last SP shard's value to all shards (for decode caches)."""
    if rt.mode == "local":
        return x
    is_last = rt.sp_rank() == rt.sp_size() - 1
    return jax.lax.psum(jnp.where(is_last, x, jnp.zeros_like(x)), rt.sp_axes)


def mamba_block(rt: Runtime, params, x, cfg: ModelConfig,
                return_state: bool = False):
    """Pre-norm Mamba(SSD) mixer with residual. x: (B, S_local, D)."""
    m = cfg.mamba or MambaConfig()
    B, S, D = x.shape
    di = m.expand * D
    hm = di // m.head_dim
    n = m.d_state

    h = blocks.rmsnorm(params["norm_in"], x, cfg.norm_eps)
    proj = rt.dense(params["in_proj"], ("embed", "mamba_inner"))
    u = jnp.einsum("bsd,dx->bsx", h, proj)
    xin, z, Bc, Cc, dt_raw = jnp.split(
        u, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    xin = _causal_conv(rt, xin, params["conv_w"])
    xin = jax.nn.silu(xin.astype(jnp.float32))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,Hm)
    log_decay = -jnp.exp(params["A_log"].astype(jnp.float32)) * dt

    xh = xin.reshape(B, S, hm, m.head_dim)
    v = xh * dt[..., None]
    q = jnp.broadcast_to(Cc.astype(jnp.float32)[:, :, None, :], (B, S, hm, n))
    k = jnp.broadcast_to(Bc.astype(jnp.float32)[:, :, None, :], (B, S, hm, n))

    y, h_fin, ld_tot, la = chunked_gla(q, k, v, log_decay, m.chunk)
    y_corr, h_true = cross_shard_correction(rt, q, la, h_fin, ld_tot)
    y = y + y_corr
    y = y + params["D_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = blocks.rmsnorm(params["norm"], y.astype(x.dtype), cfg.norm_eps)
    out_proj = rt.dense(params["out_proj"], ("mamba_inner", "embed_out"))
    out = x + jnp.einsum("bsx,xd->bsd", y, out_proj)
    if return_state:
        # cache = (conv tail, final SSM state), both from the LAST SP shard
        conv_tail = _from_last_shard(rt, xin.astype(x.dtype)[:, -(m.d_conv - 1):])
        state = _from_last_shard(rt, h_true)
        return out, {"conv": conv_tail, "state": state}
    return out


# ---------------------------------------------------------------------------
# mLSTM mixer (xLSTM matrix memory)
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig):
    d = cfg.d_model
    hq = cfg.num_heads
    dk = d // hq
    return {
        "wq": PSpec((d, hq, dk), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, hq, dk), ("embed", "heads", "head_dim")),
        "wv": PSpec((d, hq, dk), ("embed", "heads", "head_dim")),
        "wi": PSpec((d, hq), ("embed", "heads"), scale=d ** -0.5),
        "wf": PSpec((d, hq), ("embed", "heads"), scale=d ** -0.5),
        "wo": PSpec((hq, dk, d), ("heads", "head_dim", "embed_out")),
        "norm": blocks.rmsnorm_specs(d),
    }


def mlstm_block(rt: Runtime, params, x, cfg: ModelConfig,
                return_state: bool = False):
    xc = cfg.xlstm or XLSTMConfig()
    B, S, D = x.shape
    h = blocks.rmsnorm(params["norm"], x, cfg.norm_eps)
    wq = rt.dense(params["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(params["wk"], ("embed", "heads", "head_dim"))
    wv = rt.dense(params["wv"], ("embed", "heads", "head_dim"))
    wi = rt.dense(params["wi"], ("embed", "heads"))
    wf = rt.dense(params["wf"], ("embed", "heads"))
    wo = rt.dense(params["wo"], ("heads", "head_dim", "embed_out"))

    dk = wq.shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", h, wq) * dk ** -0.5
    k = jnp.einsum("bsd,dhk->bshk", h, wk)
    v = jnp.einsum("bsd,dhk->bshk", h, wv)
    ig = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", h, wi).astype(jnp.float32))
    log_decay = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", h, wf).astype(jnp.float32))

    k = k.astype(jnp.float32) * ig[..., None]      # fold input gate into k
    v_aug = jnp.concatenate(                        # extra channel: normaliser
        [v.astype(jnp.float32), jnp.ones(v.shape[:3] + (1,), jnp.float32)],
        axis=-1)
    y_aug, h_fin, ld_tot, la = chunked_gla(
        q.astype(jnp.float32), k, v_aug, log_decay, xc.chunk)
    y_corr, h_true = cross_shard_correction(rt, q.astype(jnp.float32), la,
                                            h_fin, ld_tot)
    y_aug = y_aug + y_corr
    y, ndot = y_aug[..., :-1], y_aug[..., -1]
    y = y / jnp.maximum(jnp.abs(ndot), 1.0)[..., None]
    out = jnp.einsum("bshk,hkd->bsd", y.astype(x.dtype), wo)
    if return_state:
        return x + out, {"state": _from_last_shard(rt, h_true)}
    return x + out


# ---------------------------------------------------------------------------
# sLSTM mixer (shard-local recurrence; exact at decode time)
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig):
    d = cfg.d_model
    hq = cfg.num_heads
    dh = d // hq
    return {
        "wx": PSpec((d, 4 * d), ("embed", "mamba_inner")),
        "r": PSpec((hq, dh, 4 * dh), ("heads", "head_dim", None),
                   scale=dh ** -0.5),
        "norm": blocks.rmsnorm_specs(d),
        # square (d, d): only the output dim carries the FSDP axis
        "wo": PSpec((d, d), ("embed_nosplit", "embed_out")),
    }


def slstm_block(rt: Runtime, params, x, cfg: ModelConfig,
                return_state: bool = False):
    B, S, D = x.shape
    hq = cfg.num_heads
    dh = D // hq
    h = blocks.rmsnorm(params["norm"], x, cfg.norm_eps)
    wx = rt.dense(params["wx"], ("embed", "mamba_inner"))
    r = params["r"].astype(jnp.float32)
    wo = rt.dense(params["wo"], ("embed_nosplit", "embed_out"))

    gates_x = jnp.einsum("bsd,dg->bsg", h, wx).astype(jnp.float32)
    gates_x = gates_x.reshape(B, S, hq, 4 * dh)

    def step(carry, gx):
        hs, cs = carry                                    # (B, hq, dh)
        gr = jnp.einsum("bhk,hkg->bhg", hs, r)
        z, i, f, o = jnp.split(gx + gr, 4, axis=-1)
        cs = jax.nn.sigmoid(f) * cs + jax.nn.sigmoid(i) * jnp.tanh(z)
        hs = jax.nn.sigmoid(o) * jnp.tanh(cs)
        return (hs, cs), hs

    init = (jnp.zeros((B, hq, dh), jnp.float32),) * 2
    (hs, cs), ys = jax.lax.scan(step, init, jnp.moveaxis(gates_x, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D).astype(x.dtype)
    out = x + jnp.einsum("bsd,de->bse", y, wo)
    if return_state:
        return out, {"h": _from_last_shard(rt, hs),
                     "c": _from_last_shard(rt, cs)}
    return out
