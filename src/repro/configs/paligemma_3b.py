"""paligemma-3b [vlm]: gemma-2b text backbone behind a SigLIP frontend.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216 [arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per the assignment: ``input_specs()``
supplies precomputed patch embeddings that form a bidirectional prefix
(PaliGemma prefix-LM masking); text tokens continue causally. kv=1 (MQA)
means head-sharded SP (Ulysses) is impossible here — StarTrail is not
head-limited, which is exactly the paper's argument.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    prefix_len_frac=0.125,   # image-patch prefix fraction of the sequence
    frontend_stub="patch",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=192, vocab_size=512, param_dtype="float32")
