"""deepseek-7b [dense]: llama-architecture (MHA: kv == heads).

30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400 [arXiv:2401.02954; hf]
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=176, vocab_size=512, param_dtype="float32")
