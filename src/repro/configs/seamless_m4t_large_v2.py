"""seamless-m4t-large-v2 [audio]: encoder-decoder, multimodal.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]

The speech frontend (w2v-BERT feature extractor) is a STUB: ``input_specs()``
supplies precomputed frame embeddings to the encoder. 24 encoder + 24
decoder layers; decoder self-attention is causal StarTrail, encoder
self-attention is full-mask StarTrail, cross-attention uses the (static)
team-gathered encoder K/V.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    num_encoder_layers=24,
    encdec=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend_stub="frames",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=512, param_dtype="float32")
