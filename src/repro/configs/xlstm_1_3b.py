"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks (no attention, no FFN: d_ff=0).

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517; unverified]

No attention => the StarTrail K/V ring is inapplicable (see DESIGN.md
§Arch-applicability). The mLSTM matrix-memory recurrence is parallelised
with the paper's *hierarchical* insight instead: chunked intra-shard scan +
team-gathered cross-shard state combine. sLSTM (1 in 8 blocks) keeps
shard-local state during training (documented approximation); decode is
exact (step recurrent). Sub-quadratic => long_500k runs.
"""

import dataclasses

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
        vocab_size=256, xlstm=XLSTMConfig(slstm_every=2, chunk=8),
        param_dtype="float32")
