"""jamba-1.5-large-398b [hybrid]: Mamba + attention 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf]

Layer pattern: one attention layer per 8 mixer layers (rest Mamba), MoE
replacing the MLP on every other layer. Hybrid + Mamba => sub-quadratic =>
long_500k runs (attention layers keep a windowless KV cache; Mamba layers
carry state). Optimizer state bf16 (398B params; see llama4 note).
"""

import dataclasses

from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  every_n_layers=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=64),
    opt_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, attn_every=4,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      every_n_layers=2),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2, head_dim=16,
                          chunk=8),
        param_dtype="float32", opt_dtype="float32")
