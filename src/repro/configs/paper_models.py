"""The paper's own evaluation models (Table 3): GPT 3B / GPT 7B / DiT 1B.

Used by the benchmark harness to reproduce Figs. 7-10 style experiments.
DiT is modelled as a bidirectional (full-mask) dense transformer backbone,
matching the paper's usage (backbone only, no text/image encoders).
"""

import dataclasses

from repro.configs.base import ModelConfig

# note: the paper's GPT-3B row (12 heads, hidden 4096) is not head-divisible
# (4096/12 = 341.3); we keep 12 heads and use head_dim=256 like common 3B
# configs. Only throughput/memory benchmarks use this model.
GPT_3B = ModelConfig(
    name="gpt-3b", family="dense", num_layers=16, d_model=4096,
    num_heads=12, num_kv_heads=12, d_ff=16384, vocab_size=50304,
    head_dim=256,
)

GPT_7B = ModelConfig(
    name="gpt-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=16384, vocab_size=50304,
)

DIT_1B = ModelConfig(
    name="dit-1b", family="dense", num_layers=24, d_model=1536,
    num_heads=24, num_kv_heads=24, d_ff=6144, vocab_size=8,  # patch tokens
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        GPT_7B, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, param_dtype="float32")
