"""llama4-maverick-400b-a17b [moe]: 128-expert top-1 MoE with shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Per the public Llama-4 architecture, MoE layers interleave with dense
layers (interleave_moe_layer_step = 2), which is also what makes the
"400b total / 17b active" label consistent: 24 MoE layers x 128 experts x
3*5120*8192 ~ 386B routed params + dense/attention ~ 400B total, with
top-1 + shared expert ~ 17B active. Optimizer state is kept in bf16 so a
single 256-chip v5e pod (4 TB HBM) fits; fp32 state needs the 2-pod mesh.
"""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  every_n_layers=2, shared_expert=True),
    opt_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128,
                      every_n_layers=2, shared_expert=True),
        param_dtype="float32", opt_dtype="float32")
