"""minitron-8b [dense]: width/depth-pruned Nemotron-4.

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000 [arXiv:2407.14679; hf]
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=512, param_dtype="float32")
