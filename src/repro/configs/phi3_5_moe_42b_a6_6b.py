"""phi3.5-moe-42b-a6.6b [moe]: 16 experts, top-2, MoE on every layer.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400,
                  every_n_layers=1),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      every_n_layers=1),
        param_dtype="float32")
