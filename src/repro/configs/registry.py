"""Architecture registry: ``--arch <id>`` resolution + shape applicability."""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES: Dict[str, str] = {
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "minitron-8b": "repro.configs.minitron_8b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe_42b_a6_6b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.smoke_config()


def paper_model(name: str) -> ModelConfig:
    from repro.configs import paper_models

    return {"gpt-3b": paper_models.GPT_3B, "gpt-7b": paper_models.GPT_7B,
            "dit-1b": paper_models.DIT_1B}[name]


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(supported, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k":
        subq = cfg.window is not None or cfg.family in ("ssm", "hybrid")
        if not subq:
            return False, (
                "long_500k skipped: pure full-attention arch (no SWA/SSM); "
                "see DESIGN.md §Arch-applicability")
    return True, ""


def cells(archs=None) -> List[Tuple[str, str, bool, str]]:
    """All (arch, shape, supported, reason) assignment cells."""
    out = []
    for a in archs or ASSIGNED_ARCHS:
        cfg = get(a)
        for s in SHAPES.values():
            ok, why = shape_supported(cfg, s)
            out.append((a, s.name, ok, why))
    return out
