"""Config system: model architecture, input shapes, run/parallelism config.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact published sizes) and ``smoke_config()`` (reduced same-family
config for CPU tests). ``registry.get(name)`` resolves both.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_n_layers: int = 1      # MoE replaces the MLP on every n-th layer
    shared_expert: bool = False  # Llama-4 style shared expert alongside routed
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    head_dim: int = 64           # SSD head size
    chunk: int = 64              # intra-chunk SSD block length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8         # 1 sLSTM per this many blocks (rest mLSTM)
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // num_heads
    window: Optional[int] = None         # sliding-window attention (tokens)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_every: int = 1          # hybrid: attention on every n-th mixer layer
    encdec: bool = False
    num_encoder_layers: int = 0
    prefix_len_frac: float = 0.0  # vlm: fraction of sequence that is a
                                  # bidirectional prefix (image patches)
    frontend_stub: Optional[str] = None  # 'patch' (vlm) | 'frames' (audio)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    # optimizer-state dtype: fp32 default; bf16 for the >=398B archs so a
    # single 256-chip v5e pod fits (recorded in EXPERIMENTS.md §Dry-run)
    opt_dtype: str = "float32"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def moe_on_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        n = self.moe.every_n_layers
        # MoE on the last layer of each n-block (Llama-4 interleave style)
        return (i % n) == (n - 1)

    def mixer_on_layer(self, i: int) -> str:
        """'attn' | 'mamba' | 'mlstm' | 'slstm' for decoder layer i."""
        if self.family == "ssm" and self.xlstm is not None:
            return "slstm" if (i % self.xlstm.slstm_every) == (self.xlstm.slstm_every - 1) else "mlstm"
        if self.family == "hybrid":
            # Jamba: attention on one of every `attn_every` layers
            return "attn" if (i % self.attn_every) == (self.attn_every // 2) else "mamba"
        return "attn"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Parallelism + training hyper-config for one run.

    Normally produced by ``repro.plan.ExecutionPlan.run_config()`` — the
    plan layer is the single source of truth for (C, R), scheme and
    microbatch selection; hand-built RunConfigs remain for unit tests.
    """
    c: int = 1                           # StarTrail attention-parallel size
    # 'startrail' | 'ring' (C=1 startrail) | 'ulysses' (all-to-all baseline,
    # dispatched per-layer where head counts allow)
    attention_scheme: str = "startrail"
    # gradient-accumulation microbatches per optimizer step (train only)
    microbatches: int = 1
    seq_scheme: str = "zigzag"
    block_impl: str = "ref"              # ring-step block kernel: 'ref'|'pallas'
    kernel_impl: str = "ref"             # serving decode kernel: 'ref'|'pallas'
    block_skip: bool = False
    multi_pod: bool = False
    remat: str = "attn_out"              # 'none' | 'attn_out' | 'full'
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # cross-pod gradient compression ('none' | 'int8')
    grad_compression: str = "none"
    # logical->mesh sharding rule set
    sharding_rules: str = "default"
    # unroll inner scans so cost_analysis counts every iteration (dry-run)
    unroll_scans: bool = False
    # double-buffered ring scans: issue step s+1's ppermute before step s's
    # block kernel (bit-identical; off = legacy compute-then-permute order)
    pipeline_scan: bool = True
    # split each ring transfer into this many sequence sub-chunks (must
    # divide the team-local sequence length C*N/P)
    comm_chunks: int = 1


def model_flops_per_token(cfg: ModelConfig) -> float:
    """Approx. 6*N_active params-FLOPs per token (for the roofline's
    MODEL_FLOPS = 6*N*D term). Embedding params excluded (standard)."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.head_dim_
    n = 0.0
    for i in range(L):
        mixer = cfg.mixer_on_layer(i)
        if mixer == "attn":
            n += d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)  # qkv
            n += cfg.num_heads * hd * d                           # out
        elif mixer == "mamba":
            m = cfg.mamba or MambaConfig()
            di = m.expand * d
            n += d * 2 * di + di * d + di * (2 * m.d_state + di // m.head_dim)
        elif mixer in ("mlstm", "slstm"):
            x = cfg.xlstm or XLSTMConfig()
            di = 2 * d
            n += d * di * 4 + di * d
        if cfg.moe_on_layer(i):
            n += cfg.moe.top_k * 3 * d * cfg.moe.d_ff_expert
            if cfg.moe.shared_expert:
                n += 3 * d * cfg.moe.d_ff_expert
        elif cfg.d_ff > 0 and mixer in ("attn", "mamba"):
            n += 3 * d * cfg.d_ff
    if cfg.encdec:
        for _ in range(cfg.num_encoder_layers):
            n += d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * hd * d
            n += 3 * d * cfg.d_ff
            # cross attention in decoder counted roughly with encoder here
            n += d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * hd * d
    return 6.0 * n


def total_params(cfg: ModelConfig) -> float:
    """Approximate total parameter count (for memory accounting)."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.head_dim_
    n = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for i in range(L):
        mixer = cfg.mixer_on_layer(i)
        if mixer == "attn":
            n += d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * hd * d
        elif mixer == "mamba":
            m = cfg.mamba or MambaConfig()
            di = m.expand * d
            n += d * 2 * di + di * d + di * (2 * m.d_state + di // m.head_dim)
        elif mixer in ("mlstm", "slstm"):
            di = 2 * d
            n += d * di * 4 + di * d
        if cfg.moe_on_layer(i):
            n += cfg.moe.num_experts * 3 * d * cfg.moe.d_ff_expert
            if cfg.moe.shared_expert:
                n += 3 * d * cfg.moe.d_ff_expert
        elif cfg.d_ff > 0 and mixer in ("attn", "mamba"):
            n += 3 * d * cfg.d_ff
    if cfg.encdec:
        n += cfg.num_encoder_layers * (
            d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
            + cfg.num_heads * hd * d + 3 * d * cfg.d_ff)
        n += cfg.num_layers * (  # cross-attention blocks
            d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * hd * d)
    return float(n)
