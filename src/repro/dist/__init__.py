"""Distributed substrate: sharding rules, meshes, checkpointing, elasticity.

This package is the layer between the pure StarTrail math in
:mod:`repro.core` and everything that runs on real device grids:

  * :mod:`repro.dist.sharding`  — logical-axis -> mesh-axis rule sets and
    ``partition_tree`` (PartitionSpec trees from spec ``axes_tree``\\ s).
  * :mod:`repro.dist.meshes`    — ``refine_mesh`` (factor a flat ``model``
    axis into the concentric ``(sp_grp, sp_ring, sp_team)`` axes with
    ``P = C^2 * R``) and ``local_mesh_for_tests`` (forced-host-device CPU
    meshes).
  * :mod:`repro.dist.checkpoint`— atomic, optionally async tree
    save/restore with a ``latest_step`` scan for fault-tolerant restarts.
  * :mod:`repro.dist.elastic`   — ``plan_mesh`` (degrade gracefully on node
    loss) and ``StragglerDetector`` (windowed slow-step watermark).

The full contract (rule-set names, semantics, on-disk layout) is documented
in ``docs/ARCHITECTURE.md``.
"""

from repro import compat as _compat  # installs jax shims; keep first

from repro.dist import checkpoint, elastic, meshes, sharding

__all__ = ["checkpoint", "elastic", "meshes", "sharding"]
