"""Logical-axis -> mesh-axis sharding rules and PartitionSpec trees.

Parameters declare *logical* axis names in their ``PSpec`` (``"embed"``,
``"ffn"``, ``"vocab"``, ...). A *rule set* maps each logical axis to the
tuple of mesh axes it is stored sharded over; unnamed axes are replicated.
``partition_tree`` turns a spec ``axes_tree`` into a
``jax.sharding.PartitionSpec`` tree under one rule set.

Two kinds of sharded storage coexist (see ``models/runtime.py:dense``):

  * **computation-sharded** axes stay sharded through the matmul and the
    surrounding code supplies the collectives (``"vocab"`` vocab-parallel
    loss, ``"ffn"`` TP with activation gather/reduce-scatter in the
    ``default`` rules, ``"expert_ffn"`` in the MoE block, ``"experts"``
    expert-parallel over ``data``).
  * **FSDP** axes (listed by :func:`fsdp_logical`) are storage-only:
    ``Runtime.dense`` all-gathers them on use and the gather's transpose
    reduce-scatters the gradient — ZeRO-3 semantics.

Rule sets:

  ``default`` — FSDP over ``data`` for embed dims; tensor-parallel MLP
      (``ffn`` stays sharded over the SP axes, activations gathered);
      vocab-parallel embedding/loss over the SP axes; expert-parallel MoE.
  ``fsdp``    — like ``default`` but the MLP ``ffn`` dim is gathered on use
      instead of the activations (ZeRO-3 MLP; no activation collectives).
      The MoE block gathers the expert weights explicitly in this mode.
  ``tp``      — ``fsdp`` plus attention ``heads`` stored sharded over the
      innermost team axis (gathered on use). KV heads stay replicated so
      MQA/GQA archs with few KV heads remain layout-legal.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

# The joint sequence-parallel spec axes, major-to-minor. Sharding one array
# dimension over this tuple linearises the mesh coordinates (g, j, t) as
# rank p = (g*R + j)*C + t — exactly `core.topology.StarTrailTopology.rank`
# and `Runtime.sp_rank()`.
SP_AXES: Tuple[str, str, str] = ("sp_grp", "sp_ring", "sp_team")

Rules = Dict[str, Tuple[str, ...]]

RULES: Dict[str, Rules] = {
    "default": {
        "embed": ("data",),
        "embed_out": ("data",),
        "vocab": SP_AXES,
        "ffn": SP_AXES,
        "experts": ("data",),
        "expert_ffn": SP_AXES,
    },
    "fsdp": {
        "embed": ("data",),
        "embed_out": ("data",),
        "vocab": SP_AXES,
        "ffn": SP_AXES,
        "experts": ("data",),
        "expert_ffn": SP_AXES,
    },
    "tp": {
        "embed": ("data",),
        "embed_out": ("data",),
        "vocab": SP_AXES,
        "ffn": SP_AXES,
        "experts": ("data",),
        "expert_ffn": SP_AXES,
        "heads": ("sp_team",),
    },
}

# Logical axes whose shards are *gathered on use* by ``Runtime.dense`` (the
# gather transpose reduce-scatters the gradient: ZeRO-3). Everything else in
# a rule set stays sharded through the computation.
_FSDP_LOGICAL: Dict[str, FrozenSet[str]] = {
    "default": frozenset({"embed", "embed_out"}),
    "fsdp": frozenset({"embed", "embed_out", "ffn"}),
    "tp": frozenset({"embed", "embed_out", "ffn", "heads"}),
}


def fsdp_logical(rules: str = "default") -> FrozenSet[str]:
    """The gather-on-use logical axes of a rule set (see module docstring)."""
    return _FSDP_LOGICAL[rules]


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple)


def spec_for_axes(axes: Tuple[Optional[str], ...],
                  rules: Union[str, Rules] = "default") -> P:
    """One PartitionSpec from one spec's logical ``axes`` tuple."""
    table = RULES[rules] if isinstance(rules, str) else rules
    entries = []
    used = set()
    for ax in axes:
        mesh_axes = table.get(ax) if ax is not None else None
        if not mesh_axes:
            entries.append(None)
            continue
        dup = used.intersection(mesh_axes)
        if dup:
            raise ValueError(
                f"rule set maps {axes} onto mesh axis {sorted(dup)} twice")
        used.update(mesh_axes)
        entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*entries)


def partition_tree(axes_tree, rules: Union[str, Rules] = "default"):
    """Map a spec ``axes_tree`` (tree of logical-axis tuples, as produced by
    ``models.spec.axes_tree``) to a PartitionSpec tree under ``rules``."""
    return jax.tree.map(lambda axes: spec_for_axes(axes, rules), axes_tree,
                        is_leaf=_is_axes_leaf)
