"""Mesh construction: refine a flat SP degree into the concentric axes.

The production mesh (``launch/mesh.py``) exposes a flat ``model`` axis of P
devices. StarTrail factors that axis into three:

    (sp_grp = C, sp_ring = R, sp_team = C)      with  P = C^2 * R

matching ``core/topology.py``: device (g, j, t) has team ``tau = g*R + j``
and global SP rank ``p = g*R*C + j*C + t`` (major-to-minor ``(g, j, t)``,
i.e. ``PartitionSpec(SP_AXES)`` order).

``placement`` decides which SP axis lands on the physically innermost
(model-axis-adjacent) devices — the scheduler's two options (paper §3.4):

  * ``"team_inner"``  (Collect_intra): the team collectives get the short
    hops; the model axis is split ``(g, j, t)`` with ``t`` fastest-varying.
  * ``"ring_inner"``  (P2P_intra): the ring permutes get the short hops;
    the model axis is split ``(g, t, j)`` with ``j`` fastest-varying, then
    reordered so the mesh axes still read ``(sp_grp, sp_ring, sp_team)``.
"""

from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.dist.sharding import SP_AXES

PLACEMENTS: Tuple[str, str] = ("team_inner", "ring_inner")


def _validate_factorisation(p: int, c: int) -> int:
    """Returns R; raises if (P, C) is not a valid StarTrail factorisation."""
    if c < 1:
        raise ValueError(f"C must be >= 1, got {c}")
    if c * c > p or p % (c * c) != 0:
        raise ValueError(
            f"C={c} invalid for P={p}: need C <= sqrt(P)="
            f"{math.isqrt(p)} and P % C^2 == 0")
    return p // (c * c)


def refine_grid(grid: np.ndarray, c: int, placement: str = "team_inner"
                ) -> np.ndarray:
    """Factor the last (flat SP) dim of ``grid`` into (C, R, C).

    Pure array logic shared by :func:`refine_mesh` and the layout tests:
    output[..., g, j, t] == input[..., rank] with ``rank`` as defined by
    ``core.topology.StarTrailTopology.rank(g, j, t)`` for ``team_inner``.
    """
    p = grid.shape[-1]
    r = _validate_factorisation(p, c)
    lead = grid.shape[:-1]
    if placement == "team_inner":
        return grid.reshape(lead + (c, r, c))
    if placement == "ring_inner":
        # innermost devices traverse the ring: split (g, t, j), present as
        # (g, j, t)
        return np.swapaxes(grid.reshape(lead + (c, c, r)), -1, -2)
    raise ValueError(f"placement must be one of {PLACEMENTS}, got {placement!r}")


def refine_mesh(prod, c: int, *, placement: str = "team_inner"):
    """Refine a production mesh's trailing ``model`` axis into the SP axes.

    ``prod`` is a ``jax.sharding.Mesh`` whose *last* axis is the flat
    sequence-parallel axis (named ``model`` by ``make_production_mesh``);
    leading axes (``pod``, ``data``) are preserved. Returns a new Mesh with
    axes ``(*leading, sp_grp, sp_ring, sp_team)``.
    """
    import jax

    names = tuple(prod.axis_names)
    if names[-1] != "model":
        raise ValueError(
            f"expected the trailing mesh axis to be 'model', got {names}")
    devices = np.asarray(prod.devices)
    grid = refine_grid(devices, c, placement)
    return jax.sharding.Mesh(grid, names[:-1] + SP_AXES)


def local_mesh_for_tests(*, c: int, r: int, data: int = 1):
    """A ``(data, sp_grp, sp_ring, sp_team)`` mesh over forced host devices.

    For CPU runs launched with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` where
    ``N >= data * c^2 * r`` (the train/serve ``--smoke --devices N`` path
    and ``testing/dist_checks.py``).
    """
    import jax

    if r < 1 or c < 1 or data < 1:
        raise ValueError(f"need positive sizes, got c={c} r={r} data={data}")
    need = data * c * c * r
    devs = jax.devices()
    if len(devs) < need:
        raise ValueError(
            f"need {need} devices for (data={data}, c={c}, r={r}) but only "
            f"{len(devs)} available; launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    grid = np.array(devs[:need]).reshape(data, c, r, c)
    return jax.sharding.Mesh(grid, ("data",) + SP_AXES)
