"""Atomic, optionally async checkpointing of parameter/optimizer trees.

On-disk layout (documented in docs/ARCHITECTURE.md):

    <ckpt_dir>/
      step_00000007/            # one completed checkpoint per step
        manifest.json           # {"step": 7, "leaves": [{file, shape, dtype}]}
        leaf_00000.bin          # raw bytes of each tree leaf, flatten order
        leaf_00001.bin
        ...

Writers stage into ``step_XXXXXXXX.tmp`` and ``os.replace`` to the final
name, so a checkpoint directory exists iff it is complete — a crashed
writer's ``.tmp`` is invisible to :func:`latest_step` and overwritten by
the next attempt. Raw bytes + a dtype string in the manifest keep the
format dtype-faithful for ml_dtypes (bfloat16) without relying on ``.npy``
support for extension types.

``save(..., blocking=False)`` snapshots the tree to host memory in the
caller's thread (cheap: device->host copy) and returns a started
``threading.Thread`` doing the disk I/O; ``join()`` it before the next save
to the same directory (see ``train/trainer.py``).
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8,})$")  # 8+: {:08d} grows past 1e8 steps


class _Writer(threading.Thread):
    """Daemon checkpoint writer that re-raises its failure at join() time
    (a silently-dead writer would let training continue checkpoint-less and
    a later restart resume from a stale step)."""

    def __init__(self, fn, name: str):
        super().__init__(name=name, daemon=True)
        self._fn = fn
        self.exc: Optional[BaseException] = None

    def run(self):
        try:
            self._fn()
        except BaseException as e:  # noqa: BLE001 — surfaced at join()
            self.exc = e

    def join(self, timeout=None):
        super().join(timeout)
        if self.exc is not None:
            raise self.exc


def _step_dir(root: Union[str, pathlib.Path], step: int) -> pathlib.Path:
    return pathlib.Path(root) / f"step_{step:08d}"


def save(ckpt_dir: Union[str, pathlib.Path], step: int, tree, *,
         blocking: bool = True) -> Optional[threading.Thread]:
    """Write ``tree`` as checkpoint ``step``. Returns None (blocking) or the
    started writer thread (``blocking=False``)."""
    leaves = jax.tree.leaves(tree)
    if blocking:
        arrays = [np.asarray(leaf) for leaf in leaves]  # device->host
    else:
        # force real copies: np.asarray is zero-copy on CPU backends, and
        # the caller's next train step may donate/free the source buffers
        # while the writer thread is still serializing them
        arrays = [np.array(leaf, copy=True) for leaf in leaves]
    root = pathlib.Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    final = _step_dir(root, step)
    tmp = final.with_name(final.name + ".tmp")

    def _write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: List[dict] = []
        for i, arr in enumerate(arrays):
            fname = f"leaf_{i:05d}.bin"
            (tmp / fname).write_bytes(arr.tobytes())
            manifest.append({"file": fname, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(
            json.dumps({"step": step, "leaves": manifest}, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        _write()
        return None
    t = _Writer(_write, name=f"ckpt-save-{step}")
    t.start()
    return t


def restore(ckpt_dir: Union[str, pathlib.Path], step: int, tree_like,
            shardings=None):
    """Read checkpoint ``step`` into the structure of ``tree_like``.

    ``tree_like`` supplies the pytree structure (and is type/shape
    cross-checked against the manifest). If ``shardings`` (a matching tree
    of ``jax.sharding.Sharding``) is given, each leaf is ``device_put`` with
    its sharding; otherwise leaves come back as committed jax arrays.
    """
    final = _step_dir(ckpt_dir, step)
    manifest = json.loads((final / "manifest.json").read_text())
    ref_leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    entries = manifest["leaves"]
    if len(entries) != len(ref_leaves):
        raise ValueError(
            f"checkpoint {final} has {len(entries)} leaves but the reference "
            f"tree has {len(ref_leaves)}")
    out = []
    for ref, ent in zip(ref_leaves, entries):
        dtype = jnp.dtype(ent["dtype"])
        shape = tuple(ent["shape"])
        if tuple(np.shape(ref)) != shape:
            raise ValueError(
                f"checkpoint leaf {ent['file']} shape {shape} != reference "
                f"{tuple(np.shape(ref))}")
        ref_dtype = getattr(ref, "dtype", None)
        if ref_dtype is not None and jnp.dtype(ref_dtype) != dtype:
            raise ValueError(
                f"checkpoint leaf {ent['file']} dtype {dtype} != reference "
                f"{jnp.dtype(ref_dtype)} (did param_dtype change between "
                f"runs?)")
        data = (final / ent["file"]).read_bytes()
        out.append(np.frombuffer(data, dtype=dtype).reshape(shape))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        return jax.tree.map(jax.device_put, tree, shardings)
    return jax.tree.map(jnp.asarray, tree)


def completed_steps(ckpt_dir: Union[str, pathlib.Path]) -> set:
    """All fully-committed checkpoint steps in ``ckpt_dir``.

    Only ``step_XXXXXXXX`` directories count; stale ``.tmp`` staging dirs
    from crashed writers are ignored.
    """
    root = pathlib.Path(ckpt_dir)
    if not root.is_dir():
        return set()
    steps = set()
    for child in root.iterdir():
        m = _STEP_RE.match(child.name)
        if m and child.is_dir():
            steps.add(int(m.group(1)))
    return steps


def latest_common_step(*ckpt_dirs: Union[str, pathlib.Path]) -> Optional[int]:
    """Highest step completed in *every* given directory (None if there is
    none). Restart logic for multi-tree checkpoints (params + optimizer)
    must use this rather than one tree's ``latest_step``: a crash between
    the two writes leaves the trees one step apart, and the newest step
    present in all trees is the restore point (older step dirs are never
    deleted). The step *sets* are intersected — the trees may have
    diverged by more than one step across restarts with different
    checkpoint cadences."""
    common = None
    for d in ckpt_dirs:
        steps = completed_steps(d)
        common = steps if common is None else common & steps
    return max(common) if common else None


def latest_step(ckpt_dir: Union[str, pathlib.Path]) -> Optional[int]:
    """Highest completed checkpoint step in ``ckpt_dir`` (None if empty)."""
    steps = completed_steps(ckpt_dir)
    return max(steps) if steps else None
