"""Elasticity: degrade the mesh plan on node loss; flag persistent stragglers.

``plan_mesh`` answers "the job asked for a ``model`` axis of M over W
devices — what do we actually run?" after nodes drop out of the pool: keep
the model axis at its target when possible (degrading it to the largest
refinable size that fits when the pool is smaller), absorb the remainder by
shrinking the ``data`` axis, and strand the leftover devices. The model axis must stay a
StarTrail-refinable power (>= ``min_model`` = 4, the smallest C=2 ring), so
a pool too small to host one model replica is a hard error.

``StragglerDetector`` is the training-loop watermark: a step slower than
``threshold`` x the rolling-median of recent steps counts toward a streak;
``patience`` consecutive slow steps raise the flag (one-off hiccups — GC,
checkpoint I/O — never fire it). The trainer surfaces the flag in metrics
so the operator (or a future controller) can replan via ``plan_mesh``.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Callable, Deque, Optional


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A degraded-but-runnable (data, model) split of a device pool."""

    data: int
    model: int
    world: int             # devices in the pool when planned

    @property
    def devices(self) -> int:
        """Devices actually used; ``world - devices`` are stranded."""
        return self.data * self.model

    @property
    def stranded(self) -> int:
        return self.world - self.devices


def plan_mesh(world: int, *, model_axis_target: int,
              min_model: int = 4) -> MeshPlan:
    """Plan a ``(data, model)`` mesh over a possibly-degraded pool.

    Keeps ``model`` at ``model_axis_target`` whenever the pool can host at
    least one replica; otherwise degrades it to the largest C=2-refinable
    size (a multiple of ``min_model`` = 4) that fits. Raises ``ValueError``
    when the pool cannot host ``min_model`` (no StarTrail refinement C>=2
    fits).
    """
    if world < 1:
        raise ValueError(f"world must be positive, got {world}")
    # largest C=2-refinable (multiple of min_model=4, so P % C^2 == 0)
    # model axis that fits both the target and the pool
    model = (min(model_axis_target, world) // min_model) * min_model
    if model < min_model:
        raise ValueError(
            f"pool of {world} devices cannot host a model axis >= "
            f"{min_model} (target {model_axis_target})")
    data = world // model
    return MeshPlan(data=data, model=model, world=world)


class StragglerDetector:
    """Windowed slow-step detector (see module docstring).

    ``clock`` is injectable for tests; defaults to ``time.monotonic``.
    """

    def __init__(self, window: int = 32, threshold: float = 2.0,
                 patience: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        if window < 1 or patience < 1 or threshold <= 1.0:
            raise ValueError(
                f"bad config window={window} patience={patience} "
                f"threshold={threshold}")
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self._clock = clock
        self._durations: Deque[float] = collections.deque(maxlen=window)
        self._t0: Optional[float] = None
        self._streak = 0

    def baseline(self) -> Optional[float]:
        """Rolling median of recent step durations (None until warmed up)."""
        if not self._durations:
            return None
        return statistics.median(self._durations)

    def step_start(self) -> None:
        self._t0 = self._clock()

    def step_end(self) -> bool:
        """Record the step; returns True when a persistent slowdown is on."""
        if self._t0 is None:
            raise RuntimeError("step_end() without step_start()")
        duration = self._clock() - self._t0
        self._t0 = None
        base = self.baseline()
        slow = base is not None and duration > self.threshold * base
        self._streak = self._streak + 1 if slow else 0
        self._durations.append(duration)
        return self._streak >= self.patience
