"""Data pipeline: deterministic synthetic + memory-mapped file sources,
zigzag/contiguous sequence layout, host-side prefetch.

Determinism is a fault-tolerance feature: the sampler is a pure function of
(seed, step), so a restore-from-checkpoint resumes the exact token stream
with no data-state checkpointing, and an elastic re-plan (different DP
width) re-shards the same global batch consistently.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import zigzag as zz


class SyntheticLM:
    """Deterministic synthetic next-token data (self-supervised layout)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 seq_scheme: str = "zigzag", sp_size: int = 1):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.scheme = seq_scheme
        self.positions = zz.make_positions(shape.seq_len, sp_size, seq_scheme)
        self.perm = self.positions.reshape(-1)

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(key=self.seed + step))
        b, s = self.shape.global_batch, self.shape.seq_len
        # markov-ish stream so the loss is learnable (not pure noise)
        base = rng.integers(0, self.cfg.vocab_size, size=(b, s // 8),
                            dtype=np.int64)
        toks = np.repeat(base, 8, axis=1)
        noise = rng.integers(0, self.cfg.vocab_size, size=(b, s))
        flip = rng.random((b, s)) < 0.1
        toks = np.where(flip, noise, toks)
        return toks.astype(np.int32)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._tokens(step)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        batch = {
            "tokens": np.take(toks, self.perm, axis=1),
            "labels": np.take(labels, self.perm, axis=1),
        }
        if self.cfg.frontend_stub is not None:
            rng = np.random.Generator(np.random.Philox(key=99 + step))
            batch["frontend_emb"] = rng.standard_normal(
                (self.shape.global_batch, self.shape.seq_len,
                 self.cfg.d_model), dtype=np.float32)
        return batch


class TokenFile:
    """Memory-mapped packed-token file source (uint16/uint32 .bin)."""

    def __init__(self, path: str, cfg: ModelConfig, shape: ShapeConfig, *,
                 dtype=np.uint16, seq_scheme: str = "zigzag",
                 sp_size: int = 1):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.shape = shape
        self.perm = zz.make_positions(shape.seq_len, sp_size,
                                      seq_scheme).reshape(-1)
        self.tokens_per_batch = shape.global_batch * (shape.seq_len + 1)
        self.num_batches = len(self.data) // self.tokens_per_batch
        if self.num_batches == 0:
            raise ValueError(f"{path}: too small for one batch")

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        i = step % self.num_batches
        flat = np.asarray(
            self.data[i * self.tokens_per_batch:(i + 1) * self.tokens_per_batch],
            dtype=np.int32)
        b, s = self.shape.global_batch, self.shape.seq_len
        chunk = flat.reshape(b, s + 1)
        toks, labels = chunk[:, :-1], chunk[:, 1:]
        return {
            "tokens": np.take(toks, self.perm, axis=1),
            "labels": np.take(labels, self.perm, axis=1),
        }


class Prefetcher:
    """Background-thread prefetch of the next `depth` batches."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.get_batch(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
