"""jax version compatibility shims.

The codebase targets the modern manual-SPMD surface (``jax.shard_map`` with
``check_vma``, ``jax.lax.axis_size``). On older jax (< 0.6) those names do
not exist; this module installs equivalents so the same call sites work on
both:

  * ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
    -> ``jax.experimental.shard_map.shard_map`` with ``check_vma`` mapped to
    the legacy ``check_rep`` flag.
  * ``jax.lax.axis_size(name)`` -> ``jax.lax.psum(1, name)``, which jax
    special-cases to the static mesh axis size inside shard_map.

Importing :mod:`repro.core` or :mod:`repro.dist` installs the shims; they
are no-ops when the running jax already provides the real APIs.
"""

from __future__ import annotations

import jax


def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=True):
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma))


def _axis_size_compat(axis_name):
    # psum of a python constant is special-cased by jax to the (static)
    # axis size, so this returns a plain int at trace time.
    return jax.lax.psum(1, axis_name)


def install() -> None:
    """Install the shims onto the jax namespace (idempotent)."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size_compat


install()
