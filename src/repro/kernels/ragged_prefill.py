"""Pallas TPU ragged flash-prefill kernel: batched per-row positions via
scalar prefetch.

The training kernel (``flash_attention.py``) takes *shared* ``(S,)``
position vectors as array inputs — every row of the batch sees the same
mask. Serving buckets are ragged: each row carries its own cache length,
and the engine encodes validity positionally (``pos_k`` of an unfilled
slot is pushed past the query so the causal mask kills it), which makes
the positions ``(B, S)`` arrays. Those calls used to fall back to the jnp
reference; this kernel retires that fallback.

Following ``kernels/paged_decode.py``, the per-row position arrays ride in
as *scalar-prefetch* operands (``pltpu.PrefetchScalarGridSpec``): they are
available in SMEM before the tile DMAs land, so the kernel slices the
current row's position window with ``pl.ds`` and both builds the per-tile
mask and decides tile liveness (``pl.when`` skip of fully-masked tiles)
without touching VMEM. Per-row *lengths* are the positional encoding of
these arrays — a row with ``len`` valid keys has its remaining ``pos_k``
entries pushed past every query.

Layouts match ``repro.kernels.ref`` with batched positions:
    q (B, Sq, Hq, D); k, v (B, Sk, Hkv, D); pos_q (B, Sq); pos_k (B, Sk)
    o (B, Sq, Hq, D) f32; lse (B, Hq, Sq) f32
GQA is native (K/V index maps divide the query head by G = Hq // Hkv).
Rows whose every key is masked (len = 0) finalise to ``(o=0, lse=-inf)``
— exact under ``core.combine.combine_pair``.

Validated in ``interpret=True`` mode on CPU against ``ref.block_attention``
(tests/test_prefill_kernels.py); compiled path targets TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.combine import NEG_INF
from repro.kernels.flash_attention import (DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q,
                                           _mask_tile, _tile_live,
                                           choose_block)


def _fwd_kernel(pos_q_ref, pos_k_ref,                    # scalar prefetch
                q_ref, k_ref, v_ref,                     # inputs
                o_ref, lse_ref,                          # outputs
                acc_ref, m_ref, l_ref,                   # scratch
                *, causal, window, scale, prefix_len, block_q, block_k, n_k):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # this row's position window, straight from SMEM
    pos_q = pos_q_ref[b, pl.ds(iq * block_q, block_q)]
    pos_k = pos_k_ref[b, pl.ds(ik * block_k, block_k)]

    @pl.when(_tile_live(pos_q, pos_k, causal, window, prefix_len))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = _mask_tile(pos_q, pos_k, causal, window, prefix_len)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None])
        if mask is not None:
            p = p * mask
        alpha = jnp.where(
            m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _finalize():
        m = m_ref[...]
        l = l_ref[...]
        dead = m <= NEG_INF / 2
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = jnp.where(
            dead, NEG_INF, jnp.where(dead, 0.0, m) + jnp.log(l_safe)
        ).astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "prefix_len", "block_q",
                     "block_k", "interpret"),
)
def ragged_prefill_fwd(
    q, k, v, pos_q, pos_k, *, causal=True, window=None, scale=None,
    prefix_len=None, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
    interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """Batched-positions block flash attention -> (o, lse).

    Same semantics as ``ref.block_attention`` with ``(B, Sq)`` / ``(B, Sk)``
    positions (shared ``(S,)`` vectors are broadcast). The position arrays
    are scalar-prefetch operands — per-row masks and tile-skip decisions
    come from SMEM, never from an extra VMEM stream.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    pos_q = jnp.asarray(pos_q, jnp.int32)
    pos_k = jnp.asarray(pos_k, jnp.int32)
    if pos_q.ndim == 1:
        pos_q = jnp.broadcast_to(pos_q[None], (B, Sq))
    if pos_k.ndim == 1:
        pos_k = jnp.broadcast_to(pos_k[None], (B, Sk))
    block_q = choose_block(Sq, block_q)
    block_k = choose_block(Sk, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, scale=scale,
        prefix_len=prefix_len, block_q=block_q, block_k=block_k, n_k=n_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, ik, pq, pk: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, pq, pk: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, pq, pk: (b, ik, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, ik, pq, pk: (b, iq, h, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, iq, ik, pq, pk: (b, h, iq)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
    )
    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))

    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(pos_q, pos_k, q, k, v)
    return o, lse
