"""Pure-jnp reference (oracle) for block flash attention.

These functions are the semantic ground truth for

  * the Pallas TPU kernels in ``flash_attention.py`` (validated in
    interpret mode against this file), and
  * the per-ring-step block computation inside StarTrail attention
    (``block_impl='ref'`` runs these under jit; XLA fuses them well enough
    for the CPU dry-run, while the Pallas path is the TPU target).

Conventions:
  q        : (B, Sq, Hq, D)
  k, v     : (B, Sk, Hkv, D), Hq = G * Hkv (GQA; G = 1 is MHA)
  pos_q/k  : (Sq,) / (Sk,) int32 global token positions (masks are computed
             from *positions*, so zigzag/contiguous layouts are both exact)
  o        : (B, Sq, Hq, D)
  lse      : (B, Hq, Sq)   float32 log-sum-exp of the masked scores

All reductions/accumulations are float32 regardless of input dtype.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.combine import NEG_INF, combine_pair


def make_mask(
    pos_q: jax.Array,
    pos_k: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    prefix_len: Optional[int] = None,
) -> Optional[jax.Array]:
    """(Sq, Sk) boolean mask — or (B, Sq, Sk) when either position array
    carries a leading batch dim (per-sequence cache lengths in the paged
    decode path). None means fully visible.

    prefix_len: prefix-LM (PaliGemma): keys with pos < prefix_len are
    visible to every query (bidirectional prefix), the rest is causal.
    """
    if not causal and window is None:
        return None
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    mask = None
    if causal:
        cm = pk <= pq
        if prefix_len is not None:
            cm |= pk < prefix_len
        mask = cm
    if window is not None:
        wm = (pq - pk) < window
        if not causal:
            wm &= (pk - pq) < window
        if prefix_len is not None:
            wm |= pk < prefix_len
        mask = wm if mask is None else (mask & wm)
    return mask


def block_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos_q: jax.Array,
    pos_k: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prefix_len: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Masked attention of a (Q block x K/V block) pair -> (o, lse).

    o is normalised within the block; (o, lse) pairs over disjoint key
    blocks merge exactly via ``repro.core.combine.combine_pair``.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} must be a multiple of Hkv={Hkv}")
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # scores: (B, Hkv, G, Sq, Sk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    mask = make_mask(pos_q, pos_k, causal=causal, window=window,
                     prefix_len=prefix_len)
    if mask is not None:
        # (Sq, Sk) shared mask, or (B, Sq, Sk) per-sequence (paged decode)
        mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
        s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=-1)  # (B, Hkv, G, Sq)
    dead = m <= NEG_INF / 2
    m_safe = jnp.where(dead, 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    if mask is not None:
        p = p * mask
    l = jnp.sum(p, axis=-1)  # (B, Hkv, G, Sq)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf) / jnp.moveaxis(l_safe, (1, 2, 3), (2, 3, 1))[..., None]
    lse = jnp.where(dead, NEG_INF, m_safe + jnp.log(l_safe))  # (B, Hkv, G, Sq)
    return (
        o.reshape(B, Sq, Hq, D),
        lse.reshape(B, Hq, Sq),
    )


def block_attention_merge(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o_acc: jax.Array,
    lse_acc: jax.Array,
    pos_q: jax.Array,
    pos_k: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prefix_len: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One ring step's block attention merged into a running accumulator.

    The explicit two-step form — ``block_attention`` then
    ``combine_pair`` — kept as the oracle for the fused-epilogue Pallas
    kernel (``flash_attention._fwd_merge_kernel``).
    """
    o_s, lse_s = block_attention(q, k, v, pos_q, pos_k, causal=causal,
                                 window=window, scale=scale,
                                 prefix_len=prefix_len)
    return combine_pair(o_acc, lse_acc, o_s, lse_s)


def block_attention_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    do: jax.Array,
    lse: jax.Array,
    delta: jax.Array,
    pos_q: jax.Array,
    pos_k: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prefix_len: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-attention backward for one (Q block x K/V block) pair.

    Uses the *global* lse (over the full key set) and
    delta_i = sum_d do_i * o_final_i, so each pair's contribution is the
    exact partial derivative of full softmax attention:

        p_ij = exp(s_ij - lse_i)            (true attention probabilities)
        dv_j = sum_i p_ij do_i
        ds_ij = p_ij (do_i . v_j - delta_i)
        dq_i = scale * sum_j ds_ij k_j ;  dk_j = scale * sum_i ds_ij q_i

    Shapes: do (B,Sq,Hq,D); lse, delta (B,Hq,Sq).
    Returns (dq, dk, dv) in float32 with shapes of q, k, v.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = do.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    lsef = lse.astype(jnp.float32).reshape(B, Hkv, G, Sq)
    deltaf = delta.astype(jnp.float32).reshape(B, Hkv, G, Sq)

    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    mask = make_mask(pos_q, pos_k, causal=causal, window=window,
                     prefix_len=prefix_len)
    if mask is not None:
        # mask BEFORE the exp: masked raw scores can exceed lse (which only
        # covers unmasked entries), and exp would overflow to inf -> NaN
        mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
    dead = lsef <= NEG_INF / 2
    lse_safe = jnp.where(dead, 0.0, lsef)
    p = jnp.exp(s - lse_safe[..., None])
    p = jnp.where(dead[..., None], 0.0, p)

    # (B, Hkv, G, Sq, Sk)
    dp = jnp.einsum("bqhgd,bkhd->bhgqk", dof, vf)
    ds = p * (dp - deltaf[..., None]) * scale

    dq = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf).reshape(B, Sq, Hq, D)
    dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf)
    dv = jnp.einsum("bhgqk,bqhgd->bkhd", p, dof)
    return dq, dk, dv


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prefix_len: Optional[int] = None,
) -> jax.Array:
    """Plain full (non-distributed) attention — end-to-end oracle."""
    S = q.shape[1]
    pos = positions if positions is not None else jnp.arange(S, dtype=jnp.int32)
    o, _ = block_attention(
        q, k, v, pos, pos, causal=causal, window=window, scale=scale,
        prefix_len=prefix_len,
    )
    return o.astype(q.dtype)
