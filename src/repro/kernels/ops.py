"""Jit'd public wrappers for the Pallas kernels + backend dispatch.

``flash_attention`` is a differentiable drop-in for
``ref.block_attention(...)[0]`` wired through a custom VJP that calls the
Pallas backward kernels. The StarTrail ring uses the fwd/bwd pair directly
(it manages its own residuals across ring steps).

On CPU the kernels run in interpret mode (Python-level execution of the
kernel body) — correct but slow; production path is TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ref as ref_kernels


def flash_attention_fwd(q, k, v, pos_q, pos_k, *, o_acc=None, lse_acc=None,
                        causal=True, window=None, scale=None,
                        prefix_len=None, block_q=None, block_k=None):
    kw = {}
    if block_q is not None:
        kw["block_q"] = block_q
    if block_k is not None:
        kw["block_k"] = block_k
    return fa.flash_attention_fwd(
        q, k, v, pos_q, pos_k, o_acc, lse_acc, causal=causal, window=window,
        scale=scale, prefix_len=prefix_len, **kw)


def flash_attention_bwd(q, k, v, do, lse, delta, pos_q, pos_k, *, causal=True,
                        window=None, scale=None, prefix_len=None,
                        block_q=None, block_k=None):
    kw = {}
    if block_q is not None:
        kw["block_q"] = block_q
    if block_k is not None:
        kw["block_k"] = block_k
    return fa.flash_attention_bwd(
        q, k, v, do, lse, delta, pos_q, pos_k, causal=causal, window=window,
        scale=scale, prefix_len=prefix_len, **kw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, pos_q, pos_k, causal=True, window=None,
                    scale=None):
    o, _ = flash_attention_fwd(q, k, v, pos_q, pos_k, causal=causal,
                               window=window, scale=scale)
    return o.astype(q.dtype)


def _fa_fwd(q, k, v, pos_q, pos_k, causal, window, scale):
    o, lse = flash_attention_fwd(q, k, v, pos_q, pos_k, causal=causal,
                                 window=window, scale=scale)
    return o.astype(q.dtype), (q, k, v, pos_q, pos_k, o, lse)


def _fa_bwd(causal, window, scale, res, do):
    q, k, v, pos_q, pos_k, o, lse = res
    delta = jnp.einsum(
        "bshd,bshd->bhs", do.astype(jnp.float32), o.astype(jnp.float32))
    dq, dk, dv = flash_attention_bwd(
        q, k, v, do, lse, delta, pos_q, pos_k, causal=causal, window=window,
        scale=scale)
    zero_q = jnp.zeros_like(pos_q)
    zero_k = jnp.zeros_like(pos_k)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zero_q, zero_k)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
