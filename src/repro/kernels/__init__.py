"""Attention kernels: jnp oracle + Pallas TPU implementations behind one
dispatch layer.

  dispatch.py        — THE public surface: block_fwd/block_bwd (ring step),
                       prefill, decode, paged_decode; impl='ref'|'pallas'
                       resolved per backend. Everything outside kernels/
                       calls attention through this module.
  ref.py             — pure-jnp semantic ground truth (oracle for tests)
  flash_attention.py — Pallas flash fwd/bwd block kernels (training)
  paged_decode.py    — Pallas paged-decode kernel (serving; page-table
                       indexed K/V tiles, no dense gather)
  ops.py             — jit'd wrappers + custom-VJP around the Pallas pair
"""
