"""The single attention-kernel dispatch layer.

Every attention call site in ``core/``, ``serve/``, ``engine/`` and
``models/`` goes through this module (grep-enforced by
``tests/test_kernels.py::test_no_direct_kernel_imports``) instead of
importing ``kernels.ref`` / ``kernels.ops`` / ``kernels.flash_attention``
directly. One ``impl`` knob — ``'ref'`` (pure jnp, XLA-fused; CPU default)
or ``'pallas'`` (TPU kernels, interpret-mode on CPU) — selects the backend
for the entry points:

    block_fwd / block_bwd  — one (Q block x K/V block) pair of the ring
                             step (online-softmax partials + flash backward)
    prefill                — full masked attention of batched positions
                             (o only; the serve/encdec dense call sites)
    decode                 — per-shard partial (o, lse) of M=1 queries vs a
                             dense cache slice
    paged_decode           — per-shard partial (o, lse) straight off a
                             page-table-indexed pool (no dense gather);
                             'pallas' runs kernels/paged_decode.py, 'ref'
                             gathers the pages and reuses the jnp oracle
    paged_prefill          — suffix-query block vs the cached-prefix pages
                             (the prefix-cached / chunked prefill partial);
                             'pallas' runs kernels/paged_prefill.py, 'ref'
                             gathers the pages densely

``resolve_impl(None)`` picks the backend default: ``'pallas'`` when
``jax.default_backend()`` is TPU, ``'ref'`` otherwise — the rule
``plan.make_plan`` applies to unset ``block_impl`` / ``kernel_impl`` knobs.

The Pallas *training* block kernels take shared ``(S,)`` position vectors;
calls with *batched* ``(B, S)`` positions (per-sequence cache lengths) run
the scalar-prefetch ragged kernels — ``kernels/ragged_prefill.py`` forward
and the ragged ``flash_attention_bwd`` path backward — so neither
direction falls back to the reference any more. The fallback *accounting*
stays: any future pallas->ref fallback must go through
``_note_fallback`` so it is counted per entry point
(``pallas_fallbacks()``) and logged once, assertable in tests (the counter
ticks at *trace* time — once per jit compilation, not per step).

``block_fwd_merge`` is the ring-scan entry: it folds one block's partials
into the running ``(o_acc, lse_acc)`` accumulator. On 'pallas' the combine
is fused into the flash kernel's epilogue (no separate full-array pass
over the f32 accumulator); on 'ref' it stays the explicit two-step
``block_attention`` + ``combine_pair`` form — the oracle the fused kernel
is validated against.
"""

from __future__ import annotations

import logging
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.core.combine import combine_pair as _combine_pair
from repro.obs import registry as _obs

IMPLS = ("ref", "pallas")

FALLBACK_METRIC = "dispatch_pallas_fallback_total"

_log = logging.getLogger(__name__)
_warned = set()


def _fallback_counter() -> _obs.Counter:
    return _obs.global_registry().counter(
        FALLBACK_METRIC,
        "Trace-time pallas->ref fallbacks by entry point, with provenance "
        "(reason, q shape) and the obs scope active at trace time")


def _note_fallback(entry: str, *, reason: str = "batched_positions",
                   shape=None) -> None:
    """Record a pallas->ref fallback as a labeled counter.

    Ticks at *trace* time — once per jit compilation, not per step. The
    ``scope`` label carries the active ``obs.scope(...)`` (engines trace
    under their own scope), so per-instance attribution is a label filter
    instead of the process-global snapshot-delta arithmetic this replaced.
    """
    if entry not in _warned:
        _warned.add(entry)
        _log.warning(
            "kernels.dispatch.%s: impl='pallas' requested but falling back "
            "to the reference implementation (reason=%s; see "
            "docs/SERVING.md, 'known gaps'). Logged once; occurrences are "
            "counted in the %s metric and pallas_fallbacks().",
            entry, reason, FALLBACK_METRIC)
    _fallback_counter().inc(
        entry=entry, reason=reason,
        shape="x".join(str(d) for d in shape) if shape is not None else "",
        scope=_obs.current_scope())


def pallas_fallbacks(scope: Optional[str] = None) -> Dict[str, int]:
    """Trace-time pallas->ref fallback counts, keyed by entry point.

    ``scope`` filters to counts recorded under one ``obs.scope(...)``
    (e.g. a single engine instance); None sums every scope.
    """
    counter = _obs.global_registry().get(FALLBACK_METRIC)
    if counter is None:
        return {}
    out: Dict[str, int] = {}
    labels = {"scope": scope} if scope is not None else {}
    for key, v in counter.series(**labels).items():
        entry = dict(key).get("entry", "?")
        out[entry] = out.get(entry, 0) + int(v)
    return {k: v for k, v in out.items() if v}


def reset_pallas_fallbacks(scope: Optional[str] = None) -> None:
    counter = _obs.global_registry().get(FALLBACK_METRIC)
    if counter is not None:
        counter.reset(**({"scope": scope} if scope is not None else {}))


def resolve_impl(impl: Optional[str] = None) -> str:
    """'ref' | 'pallas', with None/'auto' resolved from the backend."""
    if impl in (None, "", "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in IMPLS:
        raise ValueError(f"attention impl must be one of {IMPLS} (or None "
                         f"for the backend default), got {impl!r}")
    return impl


def _batched_positions(*pos) -> bool:
    return any(jnp.ndim(p) > 1 for p in pos)


# ---------------------------------------------------------------------------
# ring-step block compute (training hot spot)
# ---------------------------------------------------------------------------

def block_fwd(q, k, v, pos_q, pos_k, *, causal=True, window=None, scale=None,
              prefix_len=None, impl="ref") -> Tuple[jax.Array, jax.Array]:
    """Masked (Q block x K/V block) attention -> (o, lse) partials."""
    if impl == "pallas":
        if not _batched_positions(pos_q, pos_k):
            from repro.kernels import ops as _ops

            return _ops.flash_attention_fwd(
                q, k, v, pos_q, pos_k, causal=causal, window=window,
                scale=scale, prefix_len=prefix_len)
        # batched (B, S) positions: the scalar-prefetch ragged kernel
        from repro.kernels import ragged_prefill as _ragged

        return _ragged.ragged_prefill_fwd(
            q, k, v, pos_q, pos_k, causal=causal, window=window,
            scale=scale, prefix_len=prefix_len)
    return _ref.block_attention(
        q, k, v, pos_q, pos_k, causal=causal, window=window, scale=scale,
        prefix_len=prefix_len)


def block_fwd_merge(q, k, v, o_acc, lse_acc, pos_q, pos_k, *, causal=True,
                    window=None, scale=None, prefix_len=None,
                    impl="ref") -> Tuple[jax.Array, jax.Array]:
    """One ring step: block attention merged into the running accumulator.

    Semantically ``combine_pair(o_acc, lse_acc, *block_fwd(...))``. The
    'pallas' path with shared positions fuses the combine into the flash
    kernel epilogue, saving the extra HBM pass over the f32 accumulator;
    every other path keeps the explicit two-step form (the oracle).
    """
    if impl == "pallas" and not _batched_positions(pos_q, pos_k):
        from repro.kernels import ops as _ops

        return _ops.flash_attention_fwd(
            q, k, v, pos_q, pos_k, o_acc=o_acc, lse_acc=lse_acc,
            causal=causal, window=window, scale=scale,
            prefix_len=prefix_len)
    o_s, lse_s = block_fwd(q, k, v, pos_q, pos_k, causal=causal,
                           window=window, scale=scale,
                           prefix_len=prefix_len, impl=impl)
    return _combine_pair(o_acc, lse_acc, o_s, lse_s)


def block_bwd(q, k, v, do, lse, delta, pos_q, pos_k, *, causal=True,
              window=None, scale=None, prefix_len=None, impl="ref"):
    """Flash backward for one block pair -> (dq, dk, dv) in float32.

    Batched (B, S) positions run the scalar-prefetch ragged backward
    kernels — no pallas->ref fallback on this entry point any more.
    """
    if impl == "pallas":
        from repro.kernels import ops as _ops

        return _ops.flash_attention_bwd(
            q, k, v, do, lse, delta, pos_q, pos_k, causal=causal,
            window=window, scale=scale, prefix_len=prefix_len)
    return _ref.block_attention_bwd(
        q, k, v, do, lse, delta, pos_q, pos_k, causal=causal, window=window,
        scale=scale, prefix_len=prefix_len)


# ---------------------------------------------------------------------------
# prefill (dense full attention; o only, in q's dtype)
# ---------------------------------------------------------------------------

def prefill(q, k, v, pos_q, pos_k, *, causal=True, window=None, scale=None,
            prefix_len=None, impl="ref") -> jax.Array:
    """Full masked attention over a dense K/V set (batched positions ok)."""
    o, _ = block_fwd(q, k, v, pos_q, pos_k, causal=causal, window=window,
                     scale=scale, prefix_len=prefix_len, impl=impl)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode (per-shard partials; the caller lse-combines across SP shards)
# ---------------------------------------------------------------------------

def decode(q, k, v, pos_q, pos_k, *, causal=True, window=None, scale=None,
           impl="ref") -> Tuple[jax.Array, jax.Array]:
    """M-query attention vs a dense cache slice -> partial (o, lse).

    Validity is position-encoded (the repo-wide contract): callers push the
    positions of unfilled cache slots past the query position so the causal
    mask removes them — no separate validity mask enters the kernels.
    """
    return block_fwd(q, k, v, pos_q, pos_k, causal=causal, window=window,
                     scale=scale, impl=impl)


def paged_decode(q, pool_k, pool_v, table, cache_len, rank, *, sp: int,
                 page_size: int, window=None, scale=None,
                 impl="ref") -> Tuple[jax.Array, jax.Array]:
    """One query per row vs this shard's pages -> partial (o, lse).

    q: (B, 1, Hq, D); pool_k/pool_v: (pages_loc, page_size, Hkv, D);
    table: (B, W) local page ids (-1 = unallocated); cache_len: (B,) the
    new token's position; rank: traced scalar SP rank. Page ``w`` covers
    positions ``[(w*sp + rank)*page_size, ...)`` (round-robin layout).

    'pallas' streams page-table-indexed tiles through
    ``kernels/paged_decode.py``; 'ref' gathers the pages into a dense
    (B, W*page_size) view and reuses the jnp oracle — bit-for-bit the
    engine's pre-dispatch behaviour.
    """
    if impl == "pallas":
        from repro.kernels import paged_decode as _paged

        return _paged.paged_decode_attention(
            q, pool_k, pool_v, table, cache_len, rank, sp=sp,
            page_size=page_size, window=window, scale=scale)

    pages_loc = pool_k.shape[0]
    B, W = table.shape
    safe = jnp.clip(table, 0, pages_loc - 1)
    k_r = pool_k[safe].reshape(B, W * page_size, *pool_k.shape[2:])
    v_r = pool_v[safe].reshape(B, W * page_size, *pool_v.shape[2:])
    pos = ((jnp.arange(W, dtype=jnp.int32) * sp + rank) * page_size)[:, None] \
        + jnp.arange(page_size, dtype=jnp.int32)[None]
    pos = pos.reshape(W * page_size)
    valid = jnp.repeat(table >= 0, page_size, axis=1)
    valid &= pos[None] <= cache_len[:, None]
    pos_k = jnp.where(valid, pos[None], (cache_len + 1)[:, None])
    pos_q = cache_len[:, None]
    return decode(q, k_r, v_r, pos_q, pos_k, causal=True, window=window,
                  scale=scale, impl="ref")


def paged_prefill(q, pool_k, pool_v, table, cached_len, rank, *, sp: int,
                  page_size: int, window=None, scale=None,
                  impl="ref") -> Tuple[jax.Array, jax.Array]:
    """Suffix queries vs this shard's cached-prefix pages -> partial (o, lse).

    q: (B, Sq, Hq, D) — row b's query i sits at global position
    ``cached_len[b] + i`` (the prompt suffix, bucket-padded);
    pool_k/pool_v: (pages_loc, page_size, Hkv, D); table: (B, W) local page
    ids (-1 = unallocated); cached_len: (B,) tokens already in the pool;
    rank: traced scalar SP rank. Keys at positions ``< cached_len`` are
    visible (strict — the suffix scores itself through the dense partial),
    page ``w`` covering ``[(w*sp + rank)*page_size, ...)`` (round-robin).

    'pallas' streams page-table-indexed tiles through
    ``kernels/paged_prefill.py``; 'ref' gathers the pages into a dense
    (B, W*page_size) view and masks positionally — bit-for-bit the
    suffix prefill's pre-dispatch behaviour.
    """
    if impl == "pallas":
        from repro.kernels import paged_prefill as _paged_pre

        return _paged_pre.paged_prefill_attention(
            q, pool_k, pool_v, table, cached_len, rank, sp=sp,
            page_size=page_size, window=window, scale=scale)

    pages_loc = pool_k.shape[0]
    B, W = table.shape
    Sq = q.shape[1]
    safe = jnp.clip(table, 0, pages_loc - 1)
    k_r = pool_k[safe].reshape(B, W * page_size, *pool_k.shape[2:])
    v_r = pool_v[safe].reshape(B, W * page_size, *pool_v.shape[2:])
    pos = ((jnp.arange(W, dtype=jnp.int32) * sp + rank) * page_size)[:, None] \
        + jnp.arange(page_size, dtype=jnp.int32)[None]
    pos = pos.reshape(W * page_size)
    valid = jnp.repeat(table >= 0, page_size, axis=1)
    valid &= pos[None] < cached_len[:, None]
    # invalid slots (unallocated, or suffix pages being written this very
    # call) get pushed past every query position -> causally masked
    pos_k = jnp.where(valid, pos[None], (cached_len + Sq)[:, None])
    pos_q = cached_len[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    return block_fwd(q, k_r.astype(q.dtype), v_r.astype(q.dtype), pos_q,
                     pos_k, causal=True, window=window, scale=scale,
                     impl="ref")


# ---------------------------------------------------------------------------
# single-device oracle (examples / tests convenience)
# ---------------------------------------------------------------------------

def mha(q, k, v, *, positions=None, causal=True, window=None, scale=None,
        prefix_len=None) -> jax.Array:
    """Plain full attention — re-exported end-to-end oracle (always ref)."""
    return _ref.mha_reference(q, k, v, positions=positions, causal=causal,
                              window=window, scale=scale,
                              prefix_len=prefix_len)
