"""Pallas TPU flash-attention kernels (the per-ring-step compute hot spot).

TPU-native adaptation of the paper's FlashAttention usage: blocks are tiled
for VMEM with MXU-aligned shapes (multiples of 128 on the matmul dims), the
online-softmax statistics (m, l, acc) live in VMEM scratch that persists
across the innermost (K/V-block) grid dimension, and fully-masked tiles are
skipped with ``pl.when`` using the position metadata (this is what makes
zigzag/causal and sliding-window cheap inside a ring step).

Layouts match ``repro.kernels.ref``:
    q (B, Sq, Hq, D); k, v (B, Sk, Hkv, D); o (B, Sq, Hq, D); lse (B, Hq, Sq)
GQA is native: the K/V block index maps divide the query-head index by
G = Hq // Hkv, so K/V tiles are never materialised per query head.

Validated in ``interpret=True`` mode on CPU against ``ref.py``
(tests/test_kernels.py sweeps shapes/dtypes); compiled path targets TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.combine import NEG_INF

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _mask_tile(pos_q, pos_k, causal, window, prefix_len=None):
    """(bq, bk) bool mask tile from position vectors; None = all visible."""
    if not causal and window is None:
        return None
    pq = pos_q[:, None]
    pk = pos_k[None, :]
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), dtype=jnp.bool_)
    if causal:
        cm = pk <= pq
        if prefix_len is not None:
            cm |= pk < prefix_len
        m &= cm
    if window is not None:
        wm = (pq - pk) < window
        if not causal:
            wm &= (pk - pq) < window
        if prefix_len is not None:
            wm |= pk < prefix_len
        m &= wm
    return m


def _tile_live(pos_q, pos_k, causal, window, prefix_len=None):
    """Scalar: does this tile have any unmasked entry? (for pl.when skip)"""
    live = jnp.bool_(True)
    if causal:
        live &= jnp.min(pos_k) <= jnp.max(pos_q)
    if window is not None:
        live &= (jnp.min(pos_q) - jnp.max(pos_k)) < window
        if not causal:
            live &= (jnp.min(pos_k) - jnp.max(pos_q)) < window
    if prefix_len is not None:
        live |= jnp.min(pos_k) < prefix_len
    return live


def choose_block(s: int, pref: int) -> int:
    """Largest tile size <= pref dividing s (non-power-of-two rows tile
    at their largest aligned divisor instead of raising)."""
    for d in range(min(pref, s), 0, -1):
        if s % d == 0:
            return d
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_accumulate(pos_q, pos_k, q_ref, k_ref, v_ref, acc_ref, m_ref,
                    l_ref, *, causal, window, scale, prefix_len):
    """One K/V tile's online-softmax update of the (acc, m, l) scratch."""
    @pl.when(_tile_live(pos_q, pos_k, causal, window, prefix_len))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = _mask_tile(pos_q, pos_k, causal, window, prefix_len)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None])
        if mask is not None:
            p = p * mask
        alpha = jnp.where(
            m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur


def _block_partial(acc_ref, m_ref, l_ref):
    """(o_blk, lse_blk) f32 of the accumulated tiles; dead rows -> lse=-inf."""
    m = m_ref[...]
    l = l_ref[...]
    dead = m <= NEG_INF / 2
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o_blk = acc_ref[...] / l_safe[:, None]
    lse_blk = jnp.where(
        dead, NEG_INF, jnp.where(dead, 0.0, m) + jnp.log(l_safe))
    return o_blk, lse_blk


def _fwd_kernel(pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref,  # inputs
                o_ref, lse_ref,                              # outputs
                acc_ref, m_ref, l_ref,                       # scratch
                *, causal, window, scale, prefix_len, n_k):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    _fwd_accumulate(pos_q_ref[...], pos_k_ref[...], q_ref, k_ref, v_ref,
                    acc_ref, m_ref, l_ref, causal=causal, window=window,
                    scale=scale, prefix_len=prefix_len)

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_blk, lse_blk = _block_partial(acc_ref, m_ref, l_ref)
        o_ref[0, :, 0, :] = o_blk.astype(o_ref.dtype)
        lse_ref[0, 0, :] = lse_blk.astype(lse_ref.dtype)


def _fwd_merge_kernel(pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref,
                      o_acc_ref, lse_acc_ref,                # running acc in
                      o_ref, lse_ref,                        # merged acc out
                      acc_ref, m_ref, l_ref,                 # scratch
                      *, causal, window, scale, prefix_len, n_k):
    """``_fwd_kernel`` with the ring-step combine fused into the epilogue.

    Instead of writing the block partial and paying a separate full-array
    ``combine_pair`` pass over the f32 accumulator, the finalize reads the
    running ``(o_acc, lse_acc)`` tile and emits the rescaled merge directly
    — the exact op sequence of ``core.combine.combine_pair``, in-register.
    """
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    _fwd_accumulate(pos_q_ref[...], pos_k_ref[...], q_ref, k_ref, v_ref,
                    acc_ref, m_ref, l_ref, causal=causal, window=window,
                    scale=scale, prefix_len=prefix_len)

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_blk, lse_blk = _block_partial(acc_ref, m_ref, l_ref)
        o_prev = o_acc_ref[0, :, 0, :].astype(jnp.float32)
        lse_prev = lse_acc_ref[0, 0, :].astype(jnp.float32)
        # combine_pair(o_prev, lse_prev, o_blk, lse_blk), op for op
        m2 = jnp.maximum(lse_prev, lse_blk)
        both_dead = m2 <= NEG_INF / 2
        m2_safe = jnp.where(both_dead, 0.0, m2)
        w1 = jnp.exp(lse_prev - m2_safe)
        w2 = jnp.exp(lse_blk - m2_safe)
        denom = w1 + w2
        denom_safe = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, :, 0, :] = ((w1[:, None] * o_prev + w2[:, None] * o_blk)
                             / denom_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = jnp.where(
            both_dead, NEG_INF, m2_safe + jnp.log(denom_safe)
        ).astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "prefix_len", "block_q",
                     "block_k", "interpret"),
)
def flash_attention_fwd(
    q, k, v, pos_q, pos_k, o_acc=None, lse_acc=None, *, causal=True,
    window=None, scale=None, prefix_len=None, block_q=DEFAULT_BLOCK_Q,
    block_k=DEFAULT_BLOCK_K, interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """Block flash attention -> (o, lse). Same semantics as ref.block_attention.

    With ``(o_acc, lse_acc)`` — a running partial accumulator of shapes
    ``(B, Sq, Hq, D)`` / ``(B, Hq, Sq)`` — the per-ring-step combine is
    fused into the kernel epilogue: the result is
    ``combine_pair(o_acc, lse_acc, *flash_attention_fwd(...))`` without the
    separate full-array pass over the f32 accumulator.
    """
    if (o_acc is None) != (lse_acc is None):
        raise ValueError("o_acc and lse_acc must be passed together")
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(f"{Sq=} % {block_q=} or {Sk=} % {block_k=} != 0")
    n_q, n_k = Sq // block_q, Sk // block_k
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    grid = (B, Hq, n_q, n_k)
    merge = o_acc is not None
    kernel = functools.partial(
        _fwd_merge_kernel if merge else _fwd_kernel, causal=causal,
        window=window, scale=scale, prefix_len=prefix_len, n_k=n_k)

    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))

    in_specs = [
        pl.BlockSpec((block_q,), lambda b, h, iq, ik: (iq,)),
        pl.BlockSpec((block_k,), lambda b, h, iq, ik: (ik,)),
        pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        pl.BlockSpec((1, block_k, 1, D),
                     lambda b, h, iq, ik: (b, ik, h // G, 0)),
        pl.BlockSpec((1, block_k, 1, D),
                     lambda b, h, iq, ik: (b, ik, h // G, 0)),
    ]
    inputs = [pos_q, pos_k, q, k, v]
    if merge:
        in_specs += [
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ]
        inputs += [o_acc.astype(jnp.float32), lse_acc.astype(jnp.float32)]

    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(*inputs)
    return o, lse


# ---------------------------------------------------------------------------
# backward: dq kernel (accumulate over K/V blocks)
# ---------------------------------------------------------------------------

def _bwd_dq_accumulate(pos_q, pos_k, q_ref, k_ref, v_ref, do_ref, lse_ref,
                       delta_ref, dq_acc, *, causal, window, scale,
                       prefix_len):
    @pl.when(_tile_live(pos_q, pos_k, causal, window, prefix_len))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :].astype(jnp.float32)
        delta = delta_ref[0, 0, :].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask_tile(pos_q, pos_k, causal, window, prefix_len)
        if mask is not None:
            # mask BEFORE exp: masked raw scores can exceed lse -> inf*0=NaN
            s = jnp.where(mask, s, NEG_INF)
        dead = lse <= NEG_INF / 2
        p = jnp.exp(s - jnp.where(dead, 0.0, lse)[:, None])
        p = jnp.where(dead[:, None], 0.0, p)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)


def _bwd_dq_kernel(pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref, do_ref,
                   lse_ref, delta_ref, dq_ref, dq_acc, *, causal, window,
                   scale, prefix_len, n_k):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    _bwd_dq_accumulate(pos_q_ref[...], pos_k_ref[...], q_ref, k_ref, v_ref,
                       do_ref, lse_ref, delta_ref, dq_acc, causal=causal,
                       window=window, scale=scale, prefix_len=prefix_len)

    @pl.when(ik == n_k - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = dq_acc[...].astype(dq_ref.dtype)


def _bwd_dq_ragged_kernel(pos_q_ref, pos_k_ref,          # scalar prefetch
                          q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dq_ref, dq_acc, *, causal, window, scale,
                          prefix_len, block_q, block_k, n_k):
    """``_bwd_dq_kernel`` with per-row (B, S) positions from SMEM
    (the ``ragged_prefill.py`` scalar-prefetch pattern)."""
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    pos_q = pos_q_ref[b, pl.ds(iq * block_q, block_q)]
    pos_k = pos_k_ref[b, pl.ds(ik * block_k, block_k)]
    _bwd_dq_accumulate(pos_q, pos_k, q_ref, k_ref, v_ref, do_ref, lse_ref,
                       delta_ref, dq_acc, causal=causal, window=window,
                       scale=scale, prefix_len=prefix_len)

    @pl.when(ik == n_k - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = dq_acc[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# backward: dk/dv kernel (accumulate over the G * n_q combined dimension)
# ---------------------------------------------------------------------------

def _bwd_dkv_accumulate(pos_q, pos_k, q_ref, k_ref, v_ref, do_ref, lse_ref,
                        delta_ref, dk_acc, dv_acc, *, causal, window, scale,
                        prefix_len):
    @pl.when(_tile_live(pos_q, pos_k, causal, window, prefix_len))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :].astype(jnp.float32)
        delta = delta_ref[0, 0, :].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask_tile(pos_q, pos_k, causal, window, prefix_len)
        if mask is not None:
            # mask BEFORE exp: masked raw scores can exceed lse -> inf*0=NaN
            s = jnp.where(mask, s, NEG_INF)
        dead = lse <= NEG_INF / 2
        p = jnp.exp(s - jnp.where(dead, 0.0, lse)[:, None])
        p = jnp.where(dead[:, None], 0.0, p)
        # dv += p^T do ; ds = p (do v^T - delta) ; dk += ds^T q
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)


def _bwd_dkv_kernel(pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref, do_ref,
                    lse_ref, delta_ref, dk_ref, dv_ref, dk_acc, dv_acc,
                    *, causal, window, scale, prefix_len, n_t):
    it = pl.program_id(3)

    @pl.when(it == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    _bwd_dkv_accumulate(pos_q_ref[...], pos_k_ref[...], q_ref, k_ref, v_ref,
                        do_ref, lse_ref, delta_ref, dk_acc, dv_acc,
                        causal=causal, window=window, scale=scale,
                        prefix_len=prefix_len)

    @pl.when(it == n_t - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_dkv_ragged_kernel(pos_q_ref, pos_k_ref,         # scalar prefetch
                           q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                           dk_ref, dv_ref, dk_acc, dv_acc, *, causal,
                           window, scale, prefix_len, block_q, block_k,
                           n_q, n_t):
    """``_bwd_dkv_kernel`` with per-row (B, S) positions from SMEM."""
    b = pl.program_id(0)
    ik = pl.program_id(2)
    it = pl.program_id(3)

    @pl.when(it == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    pos_q = pos_q_ref[b, pl.ds((it % n_q) * block_q, block_q)]
    pos_k = pos_k_ref[b, pl.ds(ik * block_k, block_k)]
    _bwd_dkv_accumulate(pos_q, pos_k, q_ref, k_ref, v_ref, do_ref, lse_ref,
                        delta_ref, dk_acc, dv_acc, causal=causal,
                        window=window, scale=scale, prefix_len=prefix_len)

    @pl.when(it == n_t - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "prefix_len", "block_q",
                     "block_k", "interpret"),
)
def flash_attention_bwd(
    q, k, v, do, lse, delta, pos_q, pos_k, *, causal=True, window=None,
    scale=None, prefix_len=None, block_q=DEFAULT_BLOCK_Q,
    block_k=DEFAULT_BLOCK_K, interpret=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Flash backward for one (Q x K/V) block pair using the global lse.

    Returns (dq, dk, dv) in float32 (shapes of q, k, v). Semantics match
    ``ref.block_attention_bwd``. Batched ``(B, S)`` positions (per-row
    cache lengths) route to the scalar-prefetch ragged kernels — the same
    SMEM pattern as ``ragged_prefill.py`` — so serving backward paths no
    longer fall back to the reference.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    if jnp.ndim(pos_q) > 1 or jnp.ndim(pos_k) > 1:
        return _flash_attention_bwd_ragged(
            q, k, v, do, lse, delta, pos_q, pos_k, causal=causal,
            window=window, scale=scale, prefix_len=prefix_len,
            block_q=block_q, block_k=block_k, interpret=interpret)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q, n_k = Sq // block_q, Sk // block_k

    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))

    # ---- dq: grid (B, Hq, n_q, n_k), accumulate over ik ----
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, window=window,
                          scale=scale, prefix_len=prefix_len, n_k=n_k),
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((block_q,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((block_k,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
        **params,
    )(pos_q, pos_k, q, k, v, do, lse, delta)

    # ---- dk/dv: grid (B, Hkv, n_k, G * n_q); t = g * n_q + iq ----
    n_t = G * n_q
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, window=window,
                          scale=scale, prefix_len=prefix_len, n_t=n_t),
        grid=(B, Hkv, n_k, n_t),
        in_specs=[
            pl.BlockSpec((block_q,), lambda b, h, ik, t: (t % n_q,)),
            pl.BlockSpec((block_k,), lambda b, h, ik, t: (ik,)),
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, ik, t: (b, t % n_q, h * G + t // n_q, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, t: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, t: (b, ik, h, 0)),
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, ik, t: (b, t % n_q, h * G + t // n_q, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, ik, t: (b, h * G + t // n_q, t % n_q)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, ik, t: (b, h * G + t // n_q, t % n_q)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, t: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, t: (b, ik, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sk, Hkv, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Sk, Hkv, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(pos_q, pos_k, q, k, v, do, lse, delta)

    return dq, dk, dv


def _flash_attention_bwd_ragged(q, k, v, do, lse, delta, pos_q, pos_k, *,
                                causal, window, scale, prefix_len, block_q,
                                block_k, interpret):
    """Backward with per-row (B, S) positions via scalar prefetch.

    Mirrors ``ragged_prefill.ragged_prefill_fwd``: the position arrays ride
    in SMEM ahead of the tile DMAs, each kernel instance slices its row's
    window with ``pl.ds``, and tile liveness/skip comes from those slices.
    Shared ``(S,)`` vectors are broadcast to ``(B, S)``.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    pos_q = jnp.asarray(pos_q, jnp.int32)
    pos_k = jnp.asarray(pos_k, jnp.int32)
    if pos_q.ndim == 1:
        pos_q = jnp.broadcast_to(pos_q[None], (B, Sq))
    if pos_k.ndim == 1:
        pos_k = jnp.broadcast_to(pos_k[None], (B, Sk))
    block_q = choose_block(Sq, block_q)
    block_k = choose_block(Sk, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k

    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))

    q_spec = pl.BlockSpec((1, block_q, 1, D),
                          lambda b, h, iq, ik, pq, pk: (b, iq, h, 0))
    kv_spec = pl.BlockSpec((1, block_k, 1, D),
                           lambda b, h, iq, ik, pq, pk: (b, ik, h // G, 0))
    row_spec = pl.BlockSpec((1, 1, block_q),
                            lambda b, h, iq, ik, pq, pk: (b, h, iq))

    # ---- dq: grid (B, Hq, n_q, n_k), accumulate over ik ----
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_ragged_kernel, causal=causal,
                          window=window, scale=scale, prefix_len=prefix_len,
                          block_q=block_q, block_k=block_k, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hq, n_q, n_k),
            in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
            out_specs=q_spec,
            scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.float32),
        interpret=interpret,
        **params,
    )(pos_q, pos_k, q, k, v, do, lse, delta)

    # ---- dk/dv: grid (B, Hkv, n_k, G * n_q); t = g * n_q + iq ----
    n_t = G * n_q
    qg_spec = pl.BlockSpec(
        (1, block_q, 1, D),
        lambda b, h, ik, t, pq, pk: (b, t % n_q, h * G + t // n_q, 0))
    kvg_spec = pl.BlockSpec((1, block_k, 1, D),
                            lambda b, h, ik, t, pq, pk: (b, ik, h, 0))
    rowg_spec = pl.BlockSpec(
        (1, 1, block_q),
        lambda b, h, ik, t, pq, pk: (b, h * G + t // n_q, t % n_q))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_ragged_kernel, causal=causal,
                          window=window, scale=scale, prefix_len=prefix_len,
                          block_q=block_q, block_k=block_k, n_q=n_q,
                          n_t=n_t),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, n_k, n_t),
            in_specs=[qg_spec, kvg_spec, kvg_spec, qg_spec, rowg_spec,
                      rowg_spec],
            out_specs=[kvg_spec, kvg_spec],
            scratch_shapes=[
                pltpu.VMEM((block_k, D), jnp.float32),
                pltpu.VMEM((block_k, D), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Sk, Hkv, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Sk, Hkv, D), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(pos_q, pos_k, q, k, v, do, lse, delta)

    return dq, dk, dv
