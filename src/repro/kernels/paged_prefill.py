"""Pallas TPU paged-suffix prefill kernel: a block of suffix queries
against this shard's page-table-indexed slice of the paged KV pool.

This is the prefill-side sibling of ``kernels/paged_decode.py``. During a
prefix-cached (or chunked) prefill, the suffix queries at positions
``cached_len ..`` must attend to the *cached prefix* — tokens already
sitting in the SP-sharded page pool. The reference path gathers this
shard's pages into a dense ``(W * page_size)`` view (one full copy of the
prefix through HBM per layer); here the page table rides in as a
*scalar-prefetch* operand and the ``BlockSpec`` index map DMAs each K/V
page tile straight from the pool:

    q              : (B, Sq, Hq, D)     suffix queries (pos cached_len + i)
    pool_k, pool_v : (pages_loc, page_size, Hkv, D)  this shard's pool slice
    table          : (B, W) int32       local page ids, -1 = unallocated
    cached_len     : (B,) int32         tokens already in the pool
    rank           : (1,) int32         this shard's SP rank (traced)

Grid ``(B, Hq, n_q, W)`` with the page dimension innermost; the
online-softmax statistics (m, l, acc) persist in VMEM scratch across the W
steps. Pages that are unallocated (``table < 0``), entirely at or past
``cached_len`` (suffix pages being written this very call), or fully
outside the sliding window are skipped with ``pl.when`` — the skip test
reads only prefetched scalars, so a masked page costs no FLOPs and no
extra mask stream.

A key at position p is visible iff ``p < cached_len`` (strict: the suffix
itself is scored by the dense self-attention partial, not here) and, with
a window, ``pos_q - p < window``. Causality against the suffix queries is
then automatic (``p < cached_len <= pos_q``). Rows with no visible key —
every row when ``cached_len = 0``, bucket padding rows, all rows of a
window that has slid past the prefix — finalise to ``(o=0, lse=-inf)``,
so ``core.startrail.combine_partials_with_lse`` and the pairwise merge
with the suffix partial stay exact.

Returns partial ``(o, lse)`` in float32. Validated in ``interpret=True``
mode against the dense-gather reference (tests/test_prefill_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.combine import NEG_INF
from repro.kernels.ragged_prefill import choose_block

DEFAULT_BLOCK_Q = 128


def _kernel(tbl_ref, cl_ref, rank_ref,                  # scalar prefetch
            q_ref, k_ref, v_ref,                        # inputs
            o_ref, lse_ref,                             # outputs
            acc_ref, m_ref, l_ref,                      # scratch
            *, sp, page_size, window, scale, block_q, n_w):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    w = pl.program_id(3)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cl = cl_ref[b]
    page = tbl_ref[b, w]
    base = (w * sp + rank_ref[0]) * page_size
    live = (page >= 0) & (base < cl)
    if window is not None:
        # the oldest query in this tile sits at cl + iq*block_q; a page
        # whose newest key is already out of its window is dead for the
        # whole tile
        live &= (cl + iq * block_q - (base + page_size - 1)) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # (ps, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, ps)
        pos_k = base + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)                # (1, ps)
        valid = pos_k < cl                               # strict: prefix only
        if window is not None:
            pos_q = cl + iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, 1), 0)              # (bq, 1)
            valid = valid & ((pos_q - pos_k) < window)   # (bq, ps)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None]) * valid
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(w == n_w - 1)
    def _finalize():
        m = m_ref[...]
        l = l_ref[...]
        dead = m <= NEG_INF / 2
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = jnp.where(
            dead, NEG_INF, jnp.where(dead, 0.0, m) + jnp.log(l_safe)
        ).astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sp", "page_size", "window", "scale", "block_q",
                     "interpret"),
)
def paged_prefill_attention(
    q, pool_k, pool_v, table, cached_len, rank, *, sp, page_size,
    window=None, scale=None, block_q=DEFAULT_BLOCK_Q, interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """Per-shard suffix-vs-prefix paged attention -> partial (o, lse).

    q: (B, Sq, Hq, D) suffix queries — row b's query i sits at global
    position ``cached_len[b] + i`` (bucket-padding rows past the real
    suffix simply score the same prefix; the caller's lse-combine with the
    positionally-masked suffix partial keeps them exact). pool_k/pool_v:
    (pages_loc, page_size, Hkv, D); table: (B, W) int32; cached_len: (B,)
    int32; rank: (1,) int32 (traced). Page ``w`` of row ``b`` covers global
    positions ``[(w*sp + rank)*page_size, ... + page_size)`` — the
    round-robin layout of ``engine.paged_cache``.
    """
    B, Sq, Hq, D = q.shape
    pages_loc, ps, Hkv, _ = pool_k.shape
    if ps != page_size:
        raise ValueError(f"pool page size {ps} != page_size {page_size}")
    G = Hq // Hkv
    W = table.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_q = choose_block(Sq, block_q)
    n_q = Sq // block_q
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kernel = functools.partial(
        _kernel, sp=sp, page_size=page_size, window=window, scale=scale,
        block_q=block_q, n_w=W)

    def page_idx(b, h, iq, w, tbl, cl, rk):
        # -1 (unallocated) clips to page 0; the kernel masks it via pl.when
        del iq, cl, rk
        return (jnp.maximum(tbl[b, w], 0), 0, h // G, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hq, n_q, W),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, w, tbl, cl, rk: (b, iq, h, 0)),
            pl.BlockSpec((1, page_size, 1, D), page_idx),
            pl.BlockSpec((1, page_size, 1, D), page_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, w, tbl, cl, rk: (b, iq, h, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, iq, w, tbl, cl, rk: (b, h, iq)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
    )
    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))

    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(table.astype(jnp.int32), cached_len.astype(jnp.int32),
      jnp.asarray(rank, jnp.int32).reshape(1), q, pool_k, pool_v)
    return o, lse
