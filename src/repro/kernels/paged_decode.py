"""Pallas TPU paged-decode flash kernel: one query token per sequence
against this shard's page-table-indexed slice of a paged KV pool.

This is the serving-side analogue of ``flash_attention.py``: instead of
gathering a sequence's pages into a dense per-shard cache (the pure-jnp
reference path — one full copy of the cache through HBM per decode step),
the page table is handed to the kernel as a *scalar-prefetch* operand and
the ``BlockSpec`` index map DMAs each K/V page straight from the pool:

    pool_k, pool_v : (pages_loc, page_size, Hkv, D)   this shard's pool slice
    table          : (B, W) int32                     local page ids, -1 = unallocated
    cache_len      : (B,) int32                       the new token's position
    rank           : (1,) int32                       this shard's SP rank (traced)

Grid ``(B, Hq, W)`` with the page dimension innermost; the online-softmax
statistics (m, l, acc) live in VMEM scratch across the W steps, exactly as
in the training kernel. GQA is native (the K/V index map divides the query
head by G = Hq // Hkv). Pages that are unallocated (``table < 0``), fully
in the causal future, or fully outside the sliding window are skipped with
``pl.when`` — the skip test only reads prefetched scalars, so a skipped
page costs no FLOPs.

Validity is *position-encoded*, matching the repo-wide contract: a key at
position p is visible iff ``p <= cache_len`` (causal; the query sits at
``cache_len``) and, with a window, ``cache_len - p < window``. Rows with no
visible key anywhere (inactive engine slots) finalise to ``(o=0,
lse=-inf)`` so the cross-shard lse-combine drops them exactly.

Returns *partial* ``(o, lse)`` in float32 — block-attention semantics, to
be merged across SP shards by ``core.startrail.combine_decode_partials``.
Validated in ``interpret=True`` mode against ``ref.block_attention`` over
the dense gather of the same pages (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.combine import NEG_INF


def _kernel(tbl_ref, cl_ref, rank_ref,                  # scalar prefetch
            q_ref, k_ref, v_ref,                        # inputs
            o_ref, lse_ref,                             # outputs
            acc_ref, m_ref, l_ref,                      # scratch
            *, sp, page_size, window, scale, n_w):
    b = pl.program_id(0)
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cl = cl_ref[b]
    page = tbl_ref[b, w]
    base = (w * sp + rank_ref[0]) * page_size
    live = (page >= 0) & (base <= cl)
    if window is not None:
        # newest visible position is cl; oldest is cl - window + 1
        live &= (cl - (base + page_size - 1)) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (1, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # (ps, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, ps)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        valid = pos <= cl
        if window is not None:
            valid &= (cl - pos) < window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                              # (1,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None]) * valid
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(w == n_w - 1)
    def _finalize():
        m = m_ref[...]
        l = l_ref[...]
        dead = m <= NEG_INF / 2
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = jnp.where(
            dead, NEG_INF, jnp.where(dead, 0.0, m) + jnp.log(l_safe)
        ).astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sp", "page_size", "window", "scale", "interpret"),
)
def paged_decode_attention(
    q, pool_k, pool_v, table, cache_len, rank, *, sp, page_size,
    window=None, scale=None, interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """Per-shard paged decode attention -> partial (o, lse).

    q: (B, 1, Hq, D); pool_k/pool_v: (pages_loc, page_size, Hkv, D);
    table: (B, W) int32; cache_len: (B,) int32; rank: (1,) int32 (traced —
    ``jax.lax.axis_index`` products are fine). Page ``w`` of row ``b``
    covers global positions ``[(w*sp + rank)*page_size, ... + page_size)``
    — the round-robin layout of ``engine.paged_cache``.
    """
    B, M, Hq, D = q.shape
    if M != 1:
        raise ValueError(f"paged decode takes one query per row, got M={M}")
    pages_loc, ps, Hkv, _ = pool_k.shape
    if ps != page_size:
        raise ValueError(f"pool page size {ps} != page_size {page_size}")
    G = Hq // Hkv
    W = table.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kernel = functools.partial(
        _kernel, sp=sp, page_size=page_size, window=window, scale=scale,
        n_w=W)

    def page_idx(b, h, w, tbl, cl, rk):
        # -1 (unallocated) clips to page 0; the kernel masks it via pl.when
        del cl, rk
        return (jnp.maximum(tbl[b, w], 0), 0, h // G, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hq, W),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, w, tbl, cl, rk: (b, 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, D), page_idx),
            pl.BlockSpec((1, page_size, 1, D), page_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, w, tbl, cl, rk: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, 1),
                         lambda b, h, w, tbl, cl, rk: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, Hq, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(table.astype(jnp.int32), cache_len.astype(jnp.int32),
      jnp.asarray(rank, jnp.int32).reshape(1), q, pool_k, pool_v)
    return o, lse
