"""Gradient compression utilities (cross-pod all-reduce traffic reduction).

int8 quantisation with per-tensor scale, stochastic rounding and an error-
feedback buffer (1-bit-Adam-style). On a real multi-pod deployment the
compressed representation is what crosses the DCI boundary; here the
round-trip (and its error-feedback fidelity) is implemented and tested, and
``train.step`` applies it when ``run_cfg.grad_compression == 'int8'``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, key=None):
    """Per-tensor symmetric int8 quantisation; stochastic rounding if key."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def int8_roundtrip(grads):
    """Simulate the compressed cross-pod reduction (deterministic rounding)."""
    def rt(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s).astype(g.dtype)

    return jax.tree.map(rt, grads)


def error_feedback_compress(grads, residual):
    """Compress grads+residual; return (decompressed, new_residual).

    The residual carries quantisation error into the next step, making the
    compressed optimizer trajectory converge to the uncompressed one.
    """
    def one(g, r):
        t = g.astype(jnp.float32) + r
        q, s = quantize_int8(t)
        d = dequantize_int8(q, s)
        return d.astype(g.dtype), t - d

    out = jax.tree.map(one, grads, residual)
    dec = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return dec, res


def zeros_like_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
