"""AdamW with sharded state (ZeRO-style: moments mirror the param layout).

Pure elementwise given pre-reduced grads, so it runs in GSPMD-land outside
the train-step island with the same PartitionSpecs as the parameters; XLA
keeps every moment shard-local (this *is* ZeRO: no replication anywhere).
State dtype is configurable (fp32 default; bf16 for the >=398B archs so one
v5e pod fits — see configs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1


def init_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(params_abstract, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "mu": jax.tree.map(sds, params_abstract),
        "nu": jax.tree.map(sds, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_partition(param_partition):
    from jax.sharding import PartitionSpec as P

    return {
        "mu": param_partition,
        "nu": param_partition,
        "step": P(),
    }


def schedule(step, cfg: AdamWConfig):
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((s - cfg.warmup_steps) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * cos


def global_norm(grads):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.ones((), jnp.float32)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu32.astype(sdt), nu32.astype(sdt))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
