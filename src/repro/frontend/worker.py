"""Worker replicas behind the engine-API boundary.

Three layers share one implementation of the boundary
(``frontend.protocol``):

  * ``EngineHost`` — wraps one ``repro.engine.Engine`` with the rid-keyed
    add/step/preempt surface. All device state lives here.
  * ``LocalReplica`` — an ``EngineHost`` in the calling process, for
    tests and benchmarks that want orchestrator semantics without
    process overhead (and for ``--workers 0``).
  * ``ProcReplica`` — an ``EngineHost`` in a **spawned child process**
    driven over a ``multiprocessing`` pipe (``worker_main`` is the child
    entry point). The child forces its own XLA host-device count from
    the plan *before* importing jax, so each worker owns exactly its
    replica's devices regardless of the parent's mesh; params are
    re-derived from the same init seed, so replicas hold bit-identical
    weights without shipping them.

``ProcReplica.step_send`` / ``step_recv`` are split so the orchestrator
can fan a step out to every worker and only then collect — the workers'
device steps genuinely overlap (separate processes, separate XLA
clients), which is where the 2-process > 1-process throughput at equal
device count comes from.

This module must stay importable without initialising jax: the child
imports it *before* setting XLA flags would be too late, so nothing at
module top level may touch jax (everything heavyweight is imported
inside functions).
"""

from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

from repro.frontend import protocol
from repro.frontend.protocol import ReplicaDead, StepResult


class EngineHost:
    """One engine behind the rid-keyed boundary surface."""

    def __init__(self, spec: Dict[str, Any]):
        from repro import obs
        from repro.configs import registry as arch_registry
        from repro.engine import Engine, EngineConfig
        from repro.models.factory import build_model
        from repro.plan import ExecutionPlan

        plan = ExecutionPlan.from_dict(spec["plan"])
        cfg = (arch_registry.get_smoke(plan.arch)
               if plan.mesh_kind == "local" else arch_registry.get(plan.arch))
        eng_kw = dict(spec.get("eng") or {})
        if spec.get("prefill_chunk"):
            eng_kw["prefill_chunk"] = spec["prefill_chunk"]
        self.registry = obs.Registry()
        self.tracer = obs.Tracer(enabled=bool(spec.get("trace")))
        model = build_model(cfg)
        import jax

        params = model.init(jax.random.PRNGKey(int(spec.get("init_seed", 0))))
        self.engine = Engine(model, plan, EngineConfig(**eng_kw), params,
                             registry=self.registry, tracer=self.tracer)
        self._reported: set = set()

    # ---- boundary calls --------------------------------------------------
    def add(self, rid: int, req_wire: Dict[str, Any]) -> Optional[Dict]:
        req = protocol.request_from_wire(req_wire)
        rej = self.engine.add_request(req)
        return None if rej is None else protocol.rejection_to_wire(rej)

    def step(self) -> StepResult:
        emitted = [(protocol.rid_for(uid), tok)
                   for uid, tok in self.engine.step()]
        sched = self.engine.scheduler
        finished = [protocol.rid_for(uid) for uid in sched.finished
                    if uid not in self._reported]
        self._reported.update(protocol.uid_for(r) for r in finished)
        outstanding = sum(r.prompt_len + r.max_new_tokens
                          for r in sched.queue)
        outstanding += sum(
            s.req.prompt_len + s.req.max_new_tokens - len(s.out)
            for s in sched.active())
        return protocol.pack_step(
            emitted, finished,
            free_slots=sum(1 for s in sched.slots if s is None),
            queued=len(sched.queue), active=len(sched.active()),
            outstanding_tokens=outstanding)

    def preempt(self, rid: int) -> Optional[Dict[str, Any]]:
        resume = self.engine.preempt(protocol.uid_for(rid))
        return None if resume is None else protocol.request_to_wire(resume)

    def idle(self) -> bool:
        return self.engine.idle()

    def flush(self) -> None:
        self.engine.connector.flush()

    def metrics_text(self) -> str:
        return self.registry.render_prometheus()

    def trace_events(self) -> List[Dict[str, Any]]:
        return self.tracer.events()


def worker_main(conn, spec: Dict[str, Any]) -> None:
    """Child-process entry point: build the engine, serve the pipe.

    The XLA host-device count is forced from the plan **before** any jax
    import — the child inherits only a bare interpreter (spawn context),
    so this is the first and only backend configuration it sees."""
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               f"{int(spec['n_devices'])}")
    try:
        host = EngineHost(spec)
    except Exception as e:              # surface build failures, don't hang
        conn.send(("error", f"{type(e).__name__}: {e}"))
        conn.close()
        return
    conn.send(("ready", os.getpid()))
    while True:
        try:
            msg = conn.recv()
        except EOFError:
            break
        op, args = msg[0], msg[1:]
        try:
            if op == "add":
                conn.send(("rej", host.add(*args)))
            elif op == "step":
                conn.send(("step", host.step()))
            elif op == "preempt":
                conn.send(("req", host.preempt(*args)))
            elif op == "flush":
                host.flush()
                conn.send(("ok", None))
            elif op == "idle":
                conn.send(("bool", host.idle()))
            elif op == "metrics":
                conn.send(("text", host.metrics_text()))
            elif op == "trace":
                conn.send(("events", host.trace_events()))
            elif op == "shutdown":
                conn.send(("ok", None))
                break
            else:
                conn.send(("error", f"unknown op {op!r}"))
        except Exception as e:          # keep serving after a bad request
            conn.send(("error", f"{type(e).__name__}: {e}"))
    conn.close()


class LocalReplica:
    """The boundary surface over an in-process ``EngineHost``."""

    def __init__(self, index: int, spec: Dict[str, Any]):
        self.index = index
        self.host = EngineHost(spec)
        self.alive = True
        self.last: Optional[StepResult] = None
        self._pending = False

    def add(self, rid: int, req_wire: Dict[str, Any]) -> Optional[Dict]:
        return self.host.add(rid, req_wire)

    def step_send(self) -> None:
        self._pending = True

    def step_recv(self) -> StepResult:
        assert self._pending, "step_recv without step_send"
        self._pending = False
        self.last = self.host.step()
        return self.last

    def preempt(self, rid: int) -> Optional[Dict[str, Any]]:
        return self.host.preempt(rid)

    def idle(self) -> bool:
        return self.host.idle()

    def flush(self) -> None:
        self.host.flush()

    def metrics_text(self) -> str:
        return self.host.metrics_text()

    def trace_events(self) -> List[Dict[str, Any]]:
        return self.host.trace_events()

    def shutdown(self) -> None:
        self.alive = False

    def kill(self) -> None:
        self.alive = False


class ProcReplica:
    """The boundary surface over a spawned worker process."""

    def __init__(self, index: int, spec: Dict[str, Any], *,
                 start_timeout_s: float = 300.0):
        import multiprocessing as mp

        ctx = mp.get_context("spawn")
        self.index = index
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=worker_main, args=(child, spec),
                                daemon=True)
        self.proc.start()
        child.close()
        self.alive = True
        self.last: Optional[StepResult] = None
        self._pending = False
        if not self.conn.poll(start_timeout_s):
            self.kill()
            raise ReplicaDead(index, "worker did not come up")
        try:
            tag, payload = self.conn.recv()
        except (EOFError, OSError) as e:
            self.kill()
            raise ReplicaDead(index, f"worker died during startup: {e}")
        if tag != "ready":
            self.kill()
            raise ReplicaDead(index, str(payload))
        self.pid = payload

    # ---- plumbing --------------------------------------------------------
    def _send(self, *msg) -> None:
        if not self.alive:
            raise ReplicaDead(self.index, "already dead")
        try:
            self.conn.send(msg)
        except (BrokenPipeError, OSError) as e:
            self.alive = False
            raise ReplicaDead(self.index, str(e))

    def _recv(self, expect: str):
        try:
            tag, payload = self.conn.recv()
        except (EOFError, OSError) as e:
            self.alive = False
            raise ReplicaDead(self.index, str(e))
        if tag == "error":
            raise RuntimeError(f"replica {self.index}: {payload}")
        if tag != expect:
            raise RuntimeError(
                f"replica {self.index}: expected {expect!r}, got {tag!r}")
        return payload

    def _rpc(self, expect: str, *msg):
        self._send(*msg)
        return self._recv(expect)

    # ---- boundary calls --------------------------------------------------
    def add(self, rid: int, req_wire: Dict[str, Any]) -> Optional[Dict]:
        return self._rpc("rej", "add", rid, req_wire)

    def step_send(self) -> None:
        self._send("step")
        self._pending = True

    def step_recv(self) -> StepResult:
        assert self._pending, "step_recv without step_send"
        self._pending = False
        self.last = self._recv("step")
        return self.last

    def preempt(self, rid: int) -> Optional[Dict[str, Any]]:
        return self._rpc("req", "preempt", rid)

    def idle(self) -> bool:
        return self._rpc("bool", "idle")

    def flush(self) -> None:
        self._rpc("ok", "flush")

    def metrics_text(self) -> str:
        return self._rpc("text", "metrics")

    def trace_events(self) -> List[Dict[str, Any]]:
        return self._rpc("events", "trace")

    def shutdown(self, timeout_s: float = 30.0) -> None:
        if self.alive:
            try:
                self._rpc("ok", "shutdown")
            except (ReplicaDead, RuntimeError):
                pass
            self.alive = False
        self.proc.join(timeout_s)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(5.0)

    def kill(self) -> None:
        """Hard-kill the worker process (replica-death testing). The
        client side stays nominally alive: the next RPC hits the broken
        pipe and raises ReplicaDead, exactly as a real crash surfaces."""
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(10.0)
