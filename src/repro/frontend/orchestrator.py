"""The serving orchestrator: routing, admission, streaming, preemption
and failover over a fleet of worker replicas.

This is the process that talks to users (via ``frontend.server``) and
*never* touches a device: it drives replicas — ``LocalReplica`` or
``ProcReplica``, the boundary is identical — through the engine API and
owns every piece of cross-replica policy:

  * **routing** — the gateway's ``Router`` over replica load (the
    orchestrator's own outstanding-token bookkeeping; no scheduler walk
    crosses the pipe) with liveness: a dead worker leaves the eligible
    set instantly.
  * **admission** — priority classes (``frontend.slo.PriorityClass``)
    with per-class outstanding-token budgets and an SLO-priced TTFT
    check; failures are typed ``Rejection``s the HTTP layer maps to
    429/503.
  * **preemption** — when an interactive request is stuck queued behind
    a full replica, the lowest-priority preemptible stream on that
    replica is spilled (``Engine.preempt``: valid KV blocks into the
    prefix cache) and its resume request re-queued *behind* the waiting
    work — re-admitted at lower priority, continuing bit-identically.
  * **failover** — a replica death (EOF mid-step) re-admits its live
    streams on the survivors from orchestrator-side state: resume
    prompt = original prompt + tokens streamed so far, so the continued
    stream is exactly what the dead worker would have produced.
  * **observability** — per-class TTFT histograms and frontend counters
    in its own registry; ``metrics_text()`` merges every worker's
    ``/metrics`` scrape under ``worker=<i>`` labels
    (``obs.merge_prometheus_text``), and ``shutdown`` folds worker trace
    events into the orchestrator's tracer (``Tracer.extend``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

from repro import obs
from repro.engine import Rejection, Request
from repro.frontend import protocol
from repro.frontend.protocol import ReplicaDead
from repro.frontend.slo import PriorityClass, SLOAdmission, default_classes
from repro.gateway.router import Router


@dataclasses.dataclass
class _Stream:
    rid: int
    req: Request                   # original request (resume source)
    cls: PriorityClass
    replica: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    cursor: int = 0
    done: bool = False
    submitted_t: float = 0.0
    first_token_t: Optional[float] = None
    preemptions: int = 0
    resumed: int = 0               # tokens emitted before the last resume

    @property
    def remaining(self) -> int:
        return self.req.max_new_tokens - len(self.tokens)


class _ReplicaView:
    """What the router sees of a replica: load only (the tries live in
    other processes; prefix-aware routing would cost an RPC per probe)."""

    def __init__(self, orch: "Orchestrator", index: int):
        self._orch, self._index = orch, index

    def outstanding_tokens(self) -> int:
        return self._orch._outstanding(self._index)


class Orchestrator:
    def __init__(self, replicas, *, classes: Optional[
            Dict[str, PriorityClass]] = None,
            slo: Optional[SLOAdmission] = None, preempt: bool = False,
            registry: Optional[obs.Registry] = None,
            tracer: Optional[obs.Tracer] = None,
            max_steps: int = 100_000):
        self.replicas = list(replicas)
        self.classes = classes if classes is not None else default_classes()
        self.slo = slo
        self.preempt_enabled = preempt
        self.registry = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.max_steps = max_steps
        self.router = Router(
            [_ReplicaView(self, i) for i in range(len(self.replicas))],
            prefix_aware=False)
        self.streams: Dict[int, _Stream] = {}
        self.draining = False
        self._rid = 0
        self._lock = threading.RLock()
        self._worker_metrics: Dict[int, str] = {}    # last scrape per worker
        self.registry.histogram(
            "frontend_ttft_seconds",
            "Submit -> first streamed token, by priority class",
            buckets=obs.TTFT_BUCKETS)
        self.registry.counter(
            "frontend_rejections_total", "Admission rejections by reason")
        self.registry.counter(
            "frontend_preemptions_total", "Priority preemptions by class")
        self.registry.counter(
            "frontend_failovers_total",
            "Streams re-admitted after a replica death")
        self.registry.counter(
            "frontend_tokens_streamed_total", "Tokens streamed by class")
        self.registry.gauge(
            "frontend_live_replicas", "Workers currently routable").set(
            len(self.replicas))

    # ---- bookkeeping -----------------------------------------------------
    def _outstanding(self, i: int) -> int:
        return sum(len(s.req.tokens) + s.remaining
                   for s in self.streams.values()
                   if s.replica == i and not s.done)

    def _class_outstanding(self, name: str) -> int:
        return sum(s.remaining for s in self.streams.values()
                   if s.cls.name == name and not s.done)

    def live(self) -> List[int]:
        return [i for i, r in enumerate(self.replicas) if r.alive]

    def _reject(self, rej: Rejection) -> Rejection:
        self.registry.get("frontend_rejections_total").inc(reason=rej.reason)
        return rej

    # ---- admission -------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int, *,
               cls: str = "interactive", temperature: float = 0.0,
               top_k: int = 0, top_p: float = 1.0, seed: int = 0,
               session: Optional[str] = None) -> Union[int, Rejection]:
        """Admit one request; returns its rid, or a typed Rejection."""
        with self._lock:
            if self.draining:
                return self._reject(Rejection(
                    "draining", "orchestrator is draining"))
            pc = self.classes.get(cls)
            if pc is None:
                return self._reject(Rejection(
                    "unknown_class",
                    f"unknown priority class {cls!r}; have "
                    f"{sorted(self.classes)}"))
            if not self.router.live_eligible():
                return self._reject(Rejection(
                    "no_live_replica", "every worker replica is dead",
                    retry_after_steps=1))
            if pc.budget_tokens:
                out = self._class_outstanding(cls)
                if out + max_new_tokens > pc.budget_tokens:
                    return self._reject(Rejection(
                        "class_budget_exhausted",
                        f"class {cls!r} holds {out} outstanding tokens of a "
                        f"{pc.budget_tokens}-token budget",
                        retry_after_steps=max(
                            out + max_new_tokens - pc.budget_tokens, 1)))
            i = self.router.route(
                _RouteProbe(prompt, max_new_tokens), session)
            if self.slo is not None and pc.slo_ttft_ms:
                rej = self.slo.check(
                    prompt_len=len(prompt), slo_ttft_ms=pc.slo_ttft_ms,
                    queued_tokens=self._outstanding(i))
                if rej is not None:
                    self.router.routed[i] -= 1
                    return self._reject(rej)
            rid = self._rid
            self._rid += 1
            req = Request(uid=protocol.uid_for(rid), tokens=list(prompt),
                          max_new_tokens=max_new_tokens,
                          temperature=temperature, top_k=top_k, top_p=top_p,
                          seed=seed, priority=cls)
            try:
                rej_wire = self.replicas[i].add(
                    rid, protocol.request_to_wire(req))
            except ReplicaDead:
                self._on_death(i)
                self._readmit_orphans()
                return self._reject(Rejection(
                    "no_live_replica", f"replica {i} died during admission",
                    retry_after_steps=1))
            if rej_wire is not None:
                return self._reject(protocol.rejection_from_wire(rej_wire))
            self.streams[rid] = _Stream(rid=rid, req=req, cls=pc, replica=i,
                                        submitted_t=time.monotonic())
            return rid

    # ---- the drive loop --------------------------------------------------
    def _preempt_tick(self) -> None:
        """One preemption decision per replica per step: if a
        higher-priority stream is stuck *queued* (no first token) on a
        replica with no free slot, spill the worst lower-priority
        preemptible stream there and re-queue its resume behind the
        waiting work."""
        for i, rep in enumerate(self.replicas):
            if not rep.alive or rep.last is None or rep.last.free_slots:
                continue
            here = [s for s in self.streams.values()
                    if s.replica == i and not s.done]
            waiting = [s for s in here if s.first_token_t is None]
            if not waiting:
                continue
            best_rank = min(s.cls.rank for s in waiting)
            victims = [s for s in here
                       if s.first_token_t is not None and s.cls.preemptible
                       and s.cls.rank > best_rank and s.remaining > 0]
            if not victims:
                continue
            victim = max(victims, key=lambda s: (s.cls.rank, s.remaining,
                                                 s.rid))
            try:
                resume_wire = rep.preempt(victim.rid)
                if resume_wire is None:
                    continue
                rej = rep.add(victim.rid, resume_wire)
            except ReplicaDead:
                self._on_death(i)
                continue
            if rej is not None:
                # cannot re-queue (should not happen: the resume request
                # shrank); leave a loud trail rather than lose the stream
                raise RuntimeError(
                    f"preempted rid {victim.rid} rejected on re-admit: "
                    f"{rej}")
            victim.preemptions += 1
            victim.resumed = len(victim.tokens)
            self.registry.get("frontend_preemptions_total").inc(
                cls=victim.cls.name)

    def _on_death(self, i: int) -> None:
        """Replica ``i`` is gone: stop routing to it. Its orphaned
        streams are re-admitted by :meth:`_readmit_orphans` — deferred,
        because re-admitting inline would interleave an ``add`` RPC with
        a step reply still in flight on a survivor's pipe."""
        self.router.mark_dead(i)
        self.replicas[i].alive = False
        self.registry.get("frontend_live_replicas").set(len(self.live()))

    def _readmit_orphans(self) -> None:
        """Re-admit every live stream stranded on a dead replica, on the
        least-loaded survivor, from orchestrator-side state: resume
        prompt = original prompt + tokens streamed so far. Only called
        when no step RPC is pending on any survivor."""
        orphans = [s for s in self.streams.values()
                   if not s.done and not self.replicas[s.replica].alive]
        for s in orphans:
            resume = s.req if not s.tokens else dataclasses.replace(
                s.req, tokens=list(s.req.tokens) + s.tokens,
                max_new_tokens=s.remaining)
            while True:
                live = self.router.live_eligible()
                if not live:
                    raise RuntimeError(
                        "all replicas dead with streams in flight")
                j = min(live, key=lambda k: (self._outstanding(k), k))
                try:
                    rej = self.replicas[j].add(
                        s.rid, protocol.request_to_wire(resume))
                except ReplicaDead:
                    self._on_death(j)
                    continue
                if rej is not None:
                    raise RuntimeError(
                        f"failover re-admit of rid {s.rid} rejected: {rej}")
                s.replica = j
                s.resumed = len(s.tokens)
                self.registry.get("frontend_failovers_total").inc()
                break

    def step(self) -> List[Tuple[int, int]]:
        """One orchestrator tick: preemption policy, then one engine step
        on every busy replica — fanned out first (``step_send``), then
        collected (``step_recv``), so worker processes genuinely overlap.

        Returns this tick's (rid, token) emissions."""
        with self._lock:
            # notice externally-killed replicas the router still trusts
            for i, rep in enumerate(self.replicas):
                if not rep.alive and i not in self.router.dead:
                    self._on_death(i)
            if self.preempt_enabled:
                self._preempt_tick()
            busy = [i for i in self.live() if self._outstanding(i) > 0]
            for i in busy:
                try:
                    self.replicas[i].step_send()
                except ReplicaDead:
                    self._on_death(i)
            emitted: List[Tuple[int, int]] = []
            now = time.monotonic()
            for i in busy:
                rep = self.replicas[i]
                if not rep.alive:
                    continue
                try:
                    res = rep.step_recv()
                except ReplicaDead:
                    self._on_death(i)
                    continue
                for rid, tok in res.emitted:
                    s = self.streams.get(rid)
                    if s is None or s.replica != i:
                        continue          # late echo from a failed-over rid
                    s.tokens.append(tok)
                    emitted.append((rid, tok))
                    self.registry.get("frontend_tokens_streamed_total").inc(
                        cls=s.cls.name)
                    if s.first_token_t is None:
                        s.first_token_t = now
                        self.registry.get("frontend_ttft_seconds").observe(
                            now - s.submitted_t, cls=s.cls.name)
                for rid in res.finished:
                    s = self.streams.get(rid)
                    if s is not None and s.replica == i:
                        s.done = True
            self._readmit_orphans()    # every pending step reply is drained
            return emitted

    def take(self, rid: int) -> List[int]:
        """Drain tokens streamed for ``rid`` since the last take."""
        with self._lock:
            s = self.streams[rid]
            out = s.tokens[s.cursor:]
            s.cursor += len(out)
            return out

    def stream_done(self, rid: int) -> bool:
        with self._lock:
            return self.streams[rid].done

    def idle(self) -> bool:
        with self._lock:
            return all(s.done for s in self.streams.values())

    def run(self, max_steps: Optional[int] = None
            ) -> Dict[int, List[int]]:
        """Drive until every submitted stream finishes; returns
        {rid -> full token stream}."""
        limit = max_steps or self.max_steps
        n = 0
        while not self.idle():
            self.step()
            n += 1
            if n > limit:
                raise RuntimeError(
                    f"orchestrator did not drain in {limit} steps")
        return {rid: list(s.tokens) for rid, s in self.streams.items()}

    # ---- drain / shutdown ------------------------------------------------
    def shutdown(self, drain: bool = True,
                 max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        """Stop admission, optionally finish in-flight streams, flush
        staged host-tier spills, fold worker traces/metrics into the
        orchestrator's, and join every worker process."""
        with self._lock:
            self.draining = True
        if drain and not self.idle():
            self.run(max_steps)
        with self._lock:
            for i in self.live():
                rep = self.replicas[i]
                try:
                    rep.flush()
                    self._worker_metrics[i] = rep.metrics_text()
                    self.tracer.extend(rep.trace_events())
                except (ReplicaDead, RuntimeError):
                    self._on_death(i)
            for rep in self.replicas:
                rep.shutdown()
            return {rid: list(s.tokens)
                    for rid, s in self.streams.items()}

    # ---- metrics ---------------------------------------------------------
    def metrics_text(self) -> str:
        """One Prometheus exposition for the whole deployment: the
        orchestrator's own registry plus every worker's scrape merged
        under ``worker=<i>`` labels. Dead/shut-down workers contribute
        their last successful scrape."""
        with self._lock:
            for i in self.live():
                try:
                    self._worker_metrics[i] = \
                        self.replicas[i].metrics_text()
                except (ReplicaDead, RuntimeError):
                    self._on_death(i)
            merged = obs.Registry()
            obs.merge_prometheus_text(
                merged, self.registry.render_prometheus())
            for i, text in sorted(self._worker_metrics.items()):
                obs.merge_prometheus_text(merged, text, worker=str(i))
            return merged.render_prometheus()

    def ttft_quantile(self, q: float, cls: Optional[str] = None) -> float:
        h = self.registry.get("frontend_ttft_seconds")
        return h.quantile(q, cls=cls) if cls else h.quantile(q)


class _RouteProbe:
    """Duck-typed request for Router.route (load-only routing)."""

    def __init__(self, tokens: List[int], max_new_tokens: int):
        self.tokens = tokens
        self.prompt_len = len(tokens)
        self.max_new_tokens = max_new_tokens
