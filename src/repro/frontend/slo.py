"""Priority classes and SLO-priced admission.

A :class:`PriorityClass` is the orchestrator's unit of policy: its
``rank`` orders preemption (lower rank = higher priority, preempts
higher ranks), ``budget_tokens`` caps the class's outstanding decode
budget (the cheap backpressure: a runaway batch queue cannot starve
interactive admission), and ``slo_ttft_ms`` arms the priced admission
check.

:class:`SLOAdmission` prices a request's expected TTFT **analytically**
from the same cost model the planner uses (``plan.cost.serve_slo_cost``
= this prompt's prefill + the work queued ahead of it at the replica's
decode rate). A request whose priced TTFT cannot meet its class SLO is
rejected *at admission* with a 429-shaped :class:`Rejection` carrying a
``retry_after_steps`` hint — refusing work we would miss the SLO on is
cheaper for everyone than admitting it and missing. ``calibration``
scales the analytical seconds to the measured machine (the cost model
prices FLOPs/bytes on an ideal roofline; a CPU smoke mesh is orders of
magnitude off, so deployments calibrate once from a measured decode
step).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.engine import Rejection


@dataclasses.dataclass(frozen=True)
class PriorityClass:
    name: str
    rank: int                      # 0 = highest priority
    slo_ttft_ms: float = 0.0       # 0 = no admission-time TTFT pricing
    budget_tokens: int = 0         # outstanding-token cap; 0 = unlimited
    preemptible: bool = False      # may be spilled for lower-rank work


def default_classes() -> Dict[str, PriorityClass]:
    return {
        "interactive": PriorityClass("interactive", rank=0),
        "batch": PriorityClass("batch", rank=1, preemptible=True),
    }


def parse_classes(spec: str, slo_ttft_ms: float = 0.0,
                  budget_tokens: int = 0) -> Dict[str, PriorityClass]:
    """``--priority-classes`` parser: comma-separated class names, listed
    highest-priority first. The first class carries the ``--slo-ttft-ms``
    target (interactive traffic is what has a TTFT SLO) and optional
    budget; every class after the first is preemptible."""
    names = [n.strip() for n in spec.split(",") if n.strip()]
    if not names:
        raise ValueError("--priority-classes needs at least one class name")
    out: Dict[str, PriorityClass] = {}
    for rank, name in enumerate(names):
        out[name] = PriorityClass(
            name, rank=rank,
            slo_ttft_ms=slo_ttft_ms if rank == 0 else 0.0,
            budget_tokens=budget_tokens if rank == 0 else 0,
            preemptible=rank > 0)
    return out


class SLOAdmission:
    """Analytical TTFT pricing at admission, from the planner cost model."""

    def __init__(self, cfg, *, sp: int, page_size: int, decode_batch: int,
                 kernel: str = "ref", calibration: float = 1.0):
        self.cfg = cfg
        self.sp = sp
        self.page_size = page_size
        self.decode_batch = decode_batch
        self.kernel = kernel
        self.calibration = calibration

    def price(self, *, prompt_len: int, queued_tokens: int
              ) -> Dict[str, float]:
        from repro.plan import cost as plan_cost

        d = plan_cost.serve_slo_cost(
            self.cfg, prompt_len=prompt_len, queued_tokens=queued_tokens,
            sp=self.sp, page_size=self.page_size,
            decode_batch=self.decode_batch, kernel=self.kernel)
        return {k: (v * self.calibration if k.endswith("_s") else v)
                for k, v in d.items()}

    def check(self, *, prompt_len: int, slo_ttft_ms: float,
              queued_tokens: int) -> Optional[Rejection]:
        """None when the priced TTFT meets the SLO, else the 429."""
        if slo_ttft_ms <= 0:
            return None
        d = self.price(prompt_len=prompt_len, queued_tokens=queued_tokens)
        if d["ttft_s"] * 1000.0 <= slo_ttft_ms:
            return None
        # the queue drains at ~decode_batch tokens per step: estimate how
        # many steps until the queued share of the estimate has drained
        # enough for the prompt's own prefill to fit the SLO
        slack_s = max(d["ttft_s"] - slo_ttft_ms / 1000.0, 0.0)
        steps = max(int(math.ceil(slack_s / max(d["decode_step_s"], 1e-9))),
                    1)
        return Rejection(
            "slo_ttft_unattainable",
            f"priced TTFT {d['ttft_s'] * 1000:.1f}ms > SLO "
            f"{slo_ttft_ms:.0f}ms with {queued_tokens} tokens queued ahead",
            retry_after_steps=steps)
