"""Async HTTP/SSE front door over the orchestrator — stdlib only.

One asyncio server (hand-rolled HTTP/1.1: the container must not grow
an aiohttp dependency for four routes) plus one *stepper thread* that
owns the orchestrator's drive loop. The event loop never blocks on a
device step: handlers submit under the orchestrator lock and then await
an ``asyncio.Queue`` that the stepper feeds through
``loop.call_soon_threadsafe`` — per-request token streaming with
engine steps running concurrently in the worker processes.

Routes:

  ``POST /generate``  body ``{"prompt": [ids...], "max_new_tokens": n,
                      "class": "interactive", "temperature": t,
                      "top_k": k, "top_p": p, "seed": s,
                      "session": "..."}`` →
                      ``text/event-stream``: one ``data: {"rid", "token"}``
                      event per token, then ``data: {"done": true,
                      "tokens": [...]}``. Typed admission failures map to
                      429 (retryable: budget/SLO, with ``Retry-After``) /
                      503 (draining, no live replica) / 400 (request can
                      never be served), JSON body carrying the
                      ``Rejection`` fields.
  ``GET /metrics``    merged Prometheus exposition (orchestrator +
                      every worker under ``worker=<i>`` labels).
  ``GET /plan``       the per-replica worker spec (plan dict, engine
                      knobs, init seed) + worker count — clients rebuild
                      a bit-exact in-process reference engine from it.
  ``GET /healthz``    ``{"ok": true, "live_replicas": n}``.
"""

from __future__ import annotations

import asyncio
import json
import threading
import time
from typing import Any, Dict, Optional

from repro.engine import Rejection
from repro.frontend.orchestrator import Orchestrator

_DONE = object()

#: Rejection reasons that are server-state, not client-error (503).
_UNAVAILABLE = {"draining", "no_live_replica"}


def status_for(rej: Rejection) -> int:
    if rej.reason in _UNAVAILABLE:
        return 503
    return 429 if rej.retryable else 400


class FrontendServer:
    def __init__(self, orch: Orchestrator, *, host: str = "127.0.0.1",
                 port: int = 8080, worker_spec: Optional[Dict] = None,
                 workers: int = 0, step_interval_s: float = 0.0,
                 step_time_hint_s: float = 0.5):
        self.orch = orch
        self.host = host
        self.port = port
        self.worker_spec = worker_spec or {}
        self.workers = workers
        self.step_interval_s = step_interval_s
        # Retry-After = retry_after_steps * this (measured once running)
        self.step_time_hint_s = step_time_hint_s
        self._queues: Dict[int, asyncio.Queue] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop = threading.Event()
        self._stepper: Optional[threading.Thread] = None
        self._server: Optional[asyncio.AbstractServer] = None

    # ---- the drive loop (own thread; never on the event loop) -----------
    def _step_loop(self) -> None:
        while not self._stop.is_set():
            if self.orch.idle():
                time.sleep(0.005)
                continue
            t0 = time.monotonic()
            emitted = self.orch.step()
            dt = time.monotonic() - t0
            if dt > 0:
                # smooth measured step time into the Retry-After hint
                self.step_time_hint_s = \
                    0.8 * self.step_time_hint_s + 0.2 * dt
            done = [rid for rid in list(self._queues)
                    if self.orch.stream_done(rid)]
            if (emitted or done) and self._loop is not None:
                self._loop.call_soon_threadsafe(
                    self._deliver, list(emitted), done)
            if self.step_interval_s:
                time.sleep(self.step_interval_s)

    def _deliver(self, emitted, done) -> None:
        for rid, tok in emitted:
            q = self._queues.get(rid)
            if q is not None:
                q.put_nowait(tok)
        for rid in done:
            q = self._queues.get(rid)
            if q is not None:
                q.put_nowait(_DONE)

    # ---- HTTP plumbing ---------------------------------------------------
    @staticmethod
    async def _read_request(reader) -> Optional[Dict[str, Any]]:
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _ = line.decode("latin-1").split(" ", 2)
        except ValueError:
            return None
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        body = b""
        n = int(headers.get("content-length", 0) or 0)
        if n:
            body = await reader.readexactly(n)
        return {"method": method, "path": path.split("?", 1)[0],
                "headers": headers, "body": body}

    @staticmethod
    def _response(status: int, body: bytes, content_type: str,
                  extra_headers: Dict[str, str] = {}) -> bytes:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  429: "Too Many Requests",
                  503: "Service Unavailable"}.get(status, "OK")
        head = [f"HTTP/1.1 {status} {reason}",
                f"Content-Type: {content_type}",
                f"Content-Length: {len(body)}",
                "Connection: close"]
        head += [f"{k}: {v}" for k, v in extra_headers.items()]
        return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1") + body

    def _json(self, status: int, obj: Dict,
              extra_headers: Dict[str, str] = {}) -> bytes:
        return self._response(status, json.dumps(obj).encode(),
                              "application/json", extra_headers)

    async def _handle(self, reader, writer) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            if req["method"] == "POST" and req["path"] == "/generate":
                await self._generate(req, writer)
            elif req["method"] == "GET" and req["path"] == "/metrics":
                text = await asyncio.to_thread(self.orch.metrics_text)
                writer.write(self._response(
                    200, text.encode(), "text/plain; version=0.0.4"))
            elif req["method"] == "GET" and req["path"] == "/plan":
                writer.write(self._json(200, {
                    **self.worker_spec, "workers": self.workers}))
            elif req["method"] == "GET" and req["path"] == "/healthz":
                writer.write(self._json(200, {
                    "ok": bool(self.orch.live()),
                    "live_replicas": len(self.orch.live()),
                    "draining": self.orch.draining}))
            else:
                writer.write(self._json(404, {"error": "not_found"}))
            await writer.drain()
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _generate(self, req: Dict[str, Any], writer) -> None:
        try:
            body = json.loads(req["body"] or b"{}")
            prompt = [int(t) for t in body["prompt"]]
            max_new = int(body.get("max_new_tokens", 16))
        except (KeyError, TypeError, ValueError) as e:
            writer.write(self._json(400, {"error": "bad_request",
                                          "detail": str(e)}))
            return
        out = self.orch.submit(
            prompt, max_new, cls=body.get("class", "interactive"),
            temperature=float(body.get("temperature", 0.0)),
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            seed=int(body.get("seed", 0)),
            session=body.get("session"))
        if isinstance(out, Rejection):
            status = status_for(out)
            headers = {}
            if out.retry_after_steps is not None:
                headers["Retry-After"] = str(max(int(
                    out.retry_after_steps * self.step_time_hint_s), 1))
            writer.write(self._json(status, {
                "error": out.reason, "detail": out.detail,
                "retry_after_steps": out.retry_after_steps}, headers))
            return
        rid = out
        q: asyncio.Queue = asyncio.Queue()
        self._queues[rid] = q
        head = ["HTTP/1.1 200 OK", "Content-Type: text/event-stream",
                "Cache-Control: no-cache", "Connection: close"]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode("latin-1"))
        await writer.drain()
        tokens = []
        try:
            while True:
                item = await q.get()
                if item is _DONE:
                    break
                tokens.append(int(item))
                writer.write(
                    f"data: {json.dumps({'rid': rid, 'token': item})}"
                    "\n\n".encode())
                await writer.drain()
            writer.write(
                f"data: {json.dumps({'done': True, 'rid': rid, 'tokens': tokens})}"
                "\n\n".encode())
            await writer.drain()
        finally:
            self._queues.pop(rid, None)

    # ---- lifecycle -------------------------------------------------------
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stepper = threading.Thread(target=self._step_loop,
                                         name="frontend-stepper",
                                         daemon=True)
        self._stepper.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        if self.port == 0:
            self.port = self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        if self._server is None:
            await self.start()
        async with self._server:
            await self._server.serve_forever()

    def shutdown(self, drain: bool = True) -> None:
        """Stop the stepper, drain the orchestrator, close the listener.
        Callable from any thread (the SIGTERM handler)."""
        self._stop.set()
        if self._stepper is not None:
            self._stepper.join(30.0)
        self.orch.shutdown(drain=drain)
        if self._loop is not None and self._server is not None:
            self._loop.call_soon_threadsafe(self._server.close)


def run_server(orch: Orchestrator, *, host: str = "127.0.0.1",
               port: int = 8080, worker_spec: Optional[Dict] = None,
               workers: int = 0,
               install_signal_handlers: bool = True) -> None:
    """Blocking entry point used by ``launch.serve --http``: serve until
    SIGTERM/SIGINT, then drain gracefully (finish in-flight streams,
    flush host-tier spills, join workers) and return."""
    import signal

    srv = FrontendServer(orch, host=host, port=port,
                         worker_spec=worker_spec, workers=workers)

    async def _main():
        await srv.start()
        print(f"[frontend] serving on http://{srv.host}:{srv.port} "
              f"({workers} worker processes, "
              f"{len(orch.replicas)} replicas)", flush=True)
        stopping = asyncio.Event()
        if install_signal_handlers:
            loop = asyncio.get_running_loop()
            for sig in (signal.SIGTERM, signal.SIGINT):
                loop.add_signal_handler(sig, stopping.set)
        await stopping.wait()
        print("[frontend] SIGTERM: draining...", flush=True)
        await asyncio.to_thread(srv.shutdown, True)
        print("[frontend] drained; workers joined", flush=True)

    asyncio.run(_main())
