"""Stdlib SSE client for the frontend server, plus a bit-exactness
verifier.

Used three ways:

  * as a library (``generate`` / ``generate_many``) by tests and
    ``benchmarks/serving_load.py``;
  * as the CI ``http-smoke`` driver::

        python -m repro.frontend.client --port 8080 \\
            --requests 8 --concurrency 4 --verify

    which fires concurrent streaming requests and, with ``--verify``,
    rebuilds a bit-exact **in-process** reference (same per-replica
    plan, fetched from ``GET /plan``) and asserts every streamed token
    sequence matches the in-process gateway-path baseline exactly;
  * ad hoc, mirroring the curl example in docs/RUNNING.md.

Everything is stdlib (``http.client`` + threads): the client must run
in the CI container with no extra deps.
"""

from __future__ import annotations

import argparse
import http.client
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple


class HTTPError(RuntimeError):
    def __init__(self, status: int, body: Dict[str, Any]):
        super().__init__(f"HTTP {status}: {body}")
        self.status = status
        self.body = body


def _sse_events(resp) -> List[Dict[str, Any]]:
    """Parse a complete SSE response body into its data payloads."""
    events = []
    buf = b""
    while True:
        chunk = resp.read(4096)
        if not chunk:
            break
        buf += chunk
    for block in buf.decode().split("\n\n"):
        for line in block.splitlines():
            if line.startswith("data: "):
                events.append(json.loads(line[len("data: "):]))
    return events


def generate(host: str, port: int, prompt: List[int],
             max_new_tokens: int, *, cls: str = "interactive",
             temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
             seed: int = 0, session: Optional[str] = None,
             timeout_s: float = 600.0) -> Dict[str, Any]:
    """One streaming request. Returns ``{"rid", "tokens", "events",
    "ttft_s", "total_s"}``; raises :class:`HTTPError` on 4xx/5xx with
    the structured rejection body attached."""
    body: Dict[str, Any] = {"prompt": prompt,
                            "max_new_tokens": max_new_tokens, "class": cls,
                            "temperature": temperature, "top_k": top_k,
                            "top_p": top_p, "seed": seed}
    if session is not None:
        body["session"] = session
    conn = http.client.HTTPConnection(host, port, timeout=timeout_s)
    try:
        t0 = time.monotonic()
        conn.request("POST", "/generate", json.dumps(body),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status != 200:
            raise HTTPError(resp.status,
                            json.loads(resp.read().decode() or "{}"))
        events = _sse_events(resp)
        total_s = time.monotonic() - t0
    finally:
        conn.close()
    token_events = [e for e in events if "token" in e]
    done = [e for e in events if e.get("done")]
    if not done:
        raise RuntimeError("stream ended without a done event")
    return {"rid": done[0]["rid"], "tokens": done[0]["tokens"],
            "events": events, "n_streamed": len(token_events),
            "ttft_s": total_s if token_events else float("inf"),
            "total_s": total_s}


def get_json(host: str, port: int, path: str) -> Dict[str, Any]:
    conn = http.client.HTTPConnection(host, port, timeout=60.0)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return json.loads(resp.read().decode())
    finally:
        conn.close()


def get_text(host: str, port: int, path: str) -> str:
    conn = http.client.HTTPConnection(host, port, timeout=60.0)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.read().decode()
    finally:
        conn.close()


def generate_many(host: str, port: int,
                  requests: List[Dict[str, Any]],
                  concurrency: int = 4) -> List[Dict[str, Any]]:
    """Fire ``requests`` (kwargs for :func:`generate`) with at most
    ``concurrency`` concurrent SSE streams; results in request order.
    A rejected request's slot holds its :class:`HTTPError`."""
    results: List[Any] = [None] * len(requests)
    sem = threading.Semaphore(concurrency)

    def worker(idx: int, kw: Dict[str, Any]) -> None:
        with sem:
            try:
                results[idx] = generate(host, port, **kw)
            except (HTTPError, RuntimeError, OSError) as e:
                results[idx] = e

    threads = [threading.Thread(target=worker, args=(i, kw))
               for i, kw in enumerate(requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def smoke_requests(n: int, *, prompt_len: int = 12,
                   max_new: int = 8) -> List[Dict[str, Any]]:
    """Deterministic request mix shared by the client and its in-process
    verifier: greedy and sampled, varying prompts/lengths/seeds."""
    reqs = []
    for i in range(n):
        prompt = [(7 * i + j) % 251 + 1 for j in range(prompt_len + i % 3)]
        reqs.append(dict(prompt=prompt, max_new_tokens=max_new + i % 4,
                         temperature=0.0 if i % 2 == 0 else 0.8,
                         top_k=0 if i % 2 == 0 else 40, seed=17 + i))
    return reqs


def verify_against_inprocess(host: str, port: int,
                             results: List[Dict[str, Any]],
                             requests: List[Dict[str, Any]]) -> None:
    """Rebuild the server's per-replica engine in this process (plan
    from ``GET /plan``) and assert every streamed token sequence is
    bit-identical to the in-process gateway-path baseline."""
    from repro.frontend.orchestrator import Orchestrator
    from repro.frontend.worker import LocalReplica

    spec = get_json(host, port, "/plan")
    spec.pop("workers", None)
    ref = Orchestrator([LocalReplica(0, spec)])
    rids = []
    for kw in requests:
        kw = dict(kw)
        prompt = kw.pop("prompt")
        max_new = kw.pop("max_new_tokens")
        rid = ref.submit(prompt, max_new, **kw)
        assert isinstance(rid, int), f"reference rejected: {rid}"
        rids.append(rid)
    got = ref.run()
    ref.shutdown(drain=False)
    for kw, res, rid in zip(requests, results, rids):
        assert not isinstance(res, Exception), f"HTTP request failed: {res}"
        want = got[rid]
        if res["tokens"] != want:
            raise AssertionError(
                f"stream mismatch for prompt {kw['prompt'][:4]}...: "
                f"http={res['tokens']} inprocess={want}")
    print(f"[client] verify: {len(results)} streams bit-identical "
          "to in-process baseline")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--verify", action="store_true",
                    help="bit-compare streams against an in-process "
                         "rebuild of the server's engine")
    ap.add_argument("--metrics-out", default="",
                    help="write a /metrics scrape to this file")
    args = ap.parse_args(argv)

    health = get_json(args.host, args.port, "/healthz")
    print(f"[client] healthz: {health}")
    reqs = smoke_requests(args.requests, max_new=args.max_new)
    t0 = time.monotonic()
    results = generate_many(args.host, args.port, reqs,
                            concurrency=args.concurrency)
    dt = time.monotonic() - t0
    failures = [r for r in results if isinstance(r, Exception)]
    toks = sum(len(r["tokens"]) for r in results
               if not isinstance(r, Exception))
    print(f"[client] {len(results) - len(failures)}/{len(results)} streams "
          f"ok, {toks} tokens in {dt:.2f}s ({toks / max(dt, 1e-9):.1f} "
          "tok/s aggregate)")
    for r in failures:
        print(f"[client]   failure: {r}")
    if args.metrics_out:
        text = get_text(args.host, args.port, "/metrics")
        with open(args.metrics_out, "w") as f:
            f.write(text)
        print(f"[client] wrote /metrics scrape to {args.metrics_out}")
    if failures:
        return 1
    if args.verify:
        verify_against_inprocess(args.host, args.port, results, reqs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
