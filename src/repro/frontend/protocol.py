"""The engine-API boundary between the orchestrator and worker replicas.

JetStream splits serving into an *orchestrator* (routing, admission,
streaming) and *engines* (device-holding workers) behind a deliberately
small API; this module is that boundary for ``repro``: plain-data
messages a ``multiprocessing`` pipe can carry, plus the packed step
result. Four calls cross the pipe in the hot path:

  ``add(rid, request)``   -> None | rejection dict
  ``step()``              -> packed StepResult (one host array)
  ``preempt(rid)``        -> resume-request dict | None
  ``flush()``             -> commit staged host-tier spills

and a cold-path tail (``metrics`` / ``trace`` / ``shutdown``) for
observability and drain. Step results mirror JetStream's
``ResultTokens``: every (request, token) emitted that tick rides in a
single ``(k, 2) int32`` host array — one pickle of one numpy buffer per
step, never one message per token — with slot bookkeeping scalars
alongside so the orchestrator can route and preempt without extra RPCs.

Requests cross the boundary as ``dataclasses.asdict`` dicts of
``repro.engine.Request`` keyed by an orchestrator-assigned integer
``rid`` (the uid is derived as ``r<rid>``), so the packed array needs no
string table.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def uid_for(rid: int) -> str:
    return f"r{rid}"


def rid_for(uid: str) -> int:
    return int(uid[1:])


def request_to_wire(req) -> Dict[str, Any]:
    return dataclasses.asdict(req)


def request_from_wire(d: Dict[str, Any]):
    from repro.engine import Request

    return Request(**d)


def rejection_to_wire(rej) -> Dict[str, Any]:
    return dataclasses.asdict(rej)


def rejection_from_wire(d: Dict[str, Any]):
    from repro.engine import Rejection

    return Rejection(**d)


@dataclasses.dataclass
class StepResult:
    """One worker step's emissions + scheduler occupancy snapshot."""

    tokens: np.ndarray            # (k, 2) int32 — [rid, token] per emission
    finished: List[int]           # rids that completed this step
    free_slots: int               # open decode slots after this step
    queued: int                   # requests still waiting for a slot
    active: int                   # slots holding live requests
    outstanding_tokens: int       # queued + remaining decode budget

    @property
    def emitted(self) -> List[Tuple[int, int]]:
        return [(int(r), int(t)) for r, t in self.tokens]


def pack_step(emitted: List[Tuple[int, int]], finished: List[int], *,
              free_slots: int, queued: int, active: int,
              outstanding_tokens: int) -> StepResult:
    arr = np.asarray(emitted, np.int32).reshape(-1, 2) if emitted \
        else np.zeros((0, 2), np.int32)
    return StepResult(tokens=arr, finished=list(finished),
                      free_slots=int(free_slots), queued=int(queued),
                      active=int(active),
                      outstanding_tokens=int(outstanding_tokens))


def make_worker_spec(*, plan, eng=None, arch: Optional[str] = None,
                     init_seed: int = 0, trace: bool = False,
                     prefill_chunk: int = 0) -> Dict[str, Any]:
    """Everything a worker process needs to build its engine, as one
    picklable dict. The plan rides as its ``to_dict`` form; params are
    *not* shipped — every worker re-derives them from
    ``model.init(PRNGKey(init_seed))``, which is deterministic, so the
    replicas hold bit-identical weights without a multi-GB pickle."""
    spec: Dict[str, Any] = {
        "plan": plan.to_dict(),
        "init_seed": int(init_seed),
        "trace": bool(trace),
        "prefill_chunk": int(prefill_chunk),
        "n_devices": int(plan.n_devices),
    }
    if arch is not None:
        spec["arch"] = arch
    if eng is not None:
        spec["eng"] = dataclasses.asdict(eng)
    return spec


class ReplicaDead(RuntimeError):
    """The worker process behind a replica client is gone (EOF/broken
    pipe mid-RPC). The orchestrator catches this, marks the replica dead
    in the router, and re-admits its in-flight requests elsewhere."""

    def __init__(self, index: int, detail: str = ""):
        super().__init__(f"replica {index} died{': ' if detail else ''}"
                         f"{detail}")
        self.index = index
