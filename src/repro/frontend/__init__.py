"""repro.frontend — process-separated serving front end.

JetStream-style orchestrator/engine split: an :class:`Orchestrator`
drives worker replicas (in-process ``LocalReplica`` or spawned
``ProcReplica``) through the small engine-API boundary in
:mod:`repro.frontend.protocol`, with async HTTP/SSE streaming on top
(:mod:`repro.frontend.server`).

Attribute access is lazy: spawned worker children import
``repro.frontend.worker`` during unpickling *before* they get to set
XLA flags, so nothing here may pull in jax (or the orchestrator, whose
import chain reaches the engine) eagerly.
"""

_EXPORTS = {
    "Orchestrator": "repro.frontend.orchestrator",
    "EngineHost": "repro.frontend.worker",
    "LocalReplica": "repro.frontend.worker",
    "ProcReplica": "repro.frontend.worker",
    "worker_main": "repro.frontend.worker",
    "StepResult": "repro.frontend.protocol",
    "ReplicaDead": "repro.frontend.protocol",
    "make_worker_spec": "repro.frontend.protocol",
    "PriorityClass": "repro.frontend.slo",
    "SLOAdmission": "repro.frontend.slo",
    "default_classes": "repro.frontend.slo",
    "parse_classes": "repro.frontend.slo",
    "FrontendServer": "repro.frontend.server",
    "run_server": "repro.frontend.server",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
