"""Block-paged KV cache with an SP-sharded page pool.

Layout
------
Each attention sub-layer owns a pool of fixed-size pages

    k, v : (n_periods, P_sp * pages_per_shard, page_size, Hkv, hd)

sharded on the *page* dimension over the concentric SP axes
``(sp_grp, sp_ring, sp_team)`` — the same axes (and the same linear rank
order, ``rank = (g*R + r)*C + t``) that ``serve.kv_cache.cache_partition_for``
uses for the contiguous decode cache. Inside the decode island every shard
therefore holds a ``(n_periods, pages_per_shard, page_size, Hkv, hd)`` slice.

A sequence's logical KV blocks (block ``b`` covers token positions
``[b*page_size, (b+1)*page_size)``) are distributed **round-robin** over the
SP shards: block ``b`` lives on shard ``b % P_sp`` as that shard's ``b //
P_sp``-th block of the sequence. The page table is a replicated

    table : (max_slots, P_sp, W) int32     # local page id, -1 = unallocated

so each shard reads its own row (``dynamic_index`` at the traced rank) and
touches only ``ceil(blocks / P_sp)`` pages per sequence — per-device decode
compute and memory stay flat in the SP degree, exactly the Ring-Attention
degenerate configuration of ``core.startrail.decode_attention`` (partial
attention per shard + global lse-combine ``psum``).

Validity is encoded through *positions*, as everywhere else in this repo:
unallocated/unfilled slots get ``pos = cache_len + 1`` so the causal mask
kills them — no extra mask plumbing through the attention kernels.

Device-side helpers in this module are pure functions meant to run inside a
``shard_map`` island; host-side page accounting lives in
``repro.engine.scheduler`` on top of this module's :class:`PagePool` —
the ref-counted free list that lets several sequences share immutable
pages (the copy-on-write substrate of ``repro.gateway``'s prefix cache).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import SP_AXES
from repro.models import transformer
from repro.models.runtime import Runtime


class PagePool:
    """Host-side, ref-counted page free lists (one per SP shard).

    Every physical page carries a reference count: 1 for each live sequence
    whose page table points at it, plus 1 when the gateway's prefix cache
    retains it. Pages return to the free list only when the count reaches
    zero, so a shared prefix page outlives any single request and an
    over-release (double free) is a loud error instead of silent cache
    corruption. Shared pages are **immutable by construction** — decode
    appends land in blocks past the shared full-prompt prefix — so
    copy-on-write never has to copy; the ref counts are the entire
    write-safety story (see docs/SERVING.md, "COW semantics").
    """

    def __init__(self, sp: int, pages_per_shard: int):
        self.sp = sp
        self.pages_per_shard = pages_per_shard
        self.free: List[List[int]] = [
            list(range(pages_per_shard - 1, -1, -1)) for _ in range(sp)]
        self.refs = np.zeros((sp, pages_per_shard), np.int32)

    def available(self, shard: int) -> int:
        return len(self.free[shard])

    def alloc(self, shard: int) -> int:
        """Pop a free page on ``shard`` with refcount 1."""
        if not self.free[shard]:
            raise RuntimeError(
                f"page pool exhausted on shard {shard} "
                f"({self.pages_per_shard} pages)")
        page = self.free[shard].pop()
        assert self.refs[shard, page] == 0, "free-list page had live refs"
        self.refs[shard, page] = 1
        return page

    def incref(self, shard: int, page: int) -> None:
        if self.refs[shard, page] <= 0:
            raise ValueError(
                f"incref of free page ({shard}, {page}) — stale reference")
        self.refs[shard, page] += 1

    def decref(self, shard: int, page: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        if self.refs[shard, page] <= 0:
            raise ValueError(
                f"double free of page ({shard}, {page}): refcount already 0")
        self.refs[shard, page] -= 1
        if self.refs[shard, page] == 0:
            self.free[shard].append(page)
            return True
        return False

    def pages_in_use(self) -> int:
        return self.sp * self.pages_per_shard - sum(
            len(f) for f in self.free)

    def pages_total(self) -> int:
        return self.sp * self.pages_per_shard


@dataclasses.dataclass(frozen=True)
class PagedTables:
    """Traced page-table view threaded through the decode step.

    table: (B, P_sp, W) int32, replicated — local page ids per (slot, shard,
      local block); -1 marks unallocated entries.
    page_size: static tokens per page.
    """

    table: jax.Array
    page_size: int


def supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Engine v1 serves decoder-only stacks whose mixers are all attention
    (paged KV is meaningless for recurrent per-slot states; those archs
    keep the contiguous serving path)."""
    if cfg.encdec:
        return False, "encoder-decoder archs use the contiguous serve path"
    if cfg.frontend_stub is not None:
        return False, "frontend (VLM/audio) archs use the contiguous serve path"
    for mixer, _ in transformer.layer_pattern(cfg):
        if mixer != "attn":
            return False, (f"mixer {mixer!r} keeps per-slot recurrent state; "
                           "paged engine v1 covers attention mixers only")
    return True, ""


def pool_spec(cfg: ModelConfig, pages_global: int, page_size: int):
    """Abstract pool tree {'stack': {subN: {'k','v'}}} (period-stacked)."""
    dtype = jnp.dtype(cfg.param_dtype)
    pat = transformer.layer_pattern(cfg)
    n_periods = cfg.num_layers // len(pat)
    hd = cfg.head_dim_
    leaf = jax.ShapeDtypeStruct(
        (n_periods, pages_global, page_size, cfg.num_kv_heads, hd), dtype)
    return {"stack": {f"sub{i}": {"k": leaf, "v": leaf}
                      for i in range(len(pat))}}


def pool_partition(cfg: ModelConfig):
    """PartitionSpec tree matching pool_spec: pages sharded over SP."""
    pat = transformer.layer_pattern(cfg)
    spec = P(None, SP_AXES, None, None, None)
    return {"stack": {f"sub{i}": {"k": spec, "v": spec}
                      for i in range(len(pat))}}


def init_pools(cfg: ModelConfig, mesh, pages_global: int, page_size: int):
    """Concrete zeroed pools, placed with the SP-sharded layout."""
    spec = pool_spec(cfg, pages_global, page_size)
    part = pool_partition(cfg)
    return jax.tree.map(
        lambda s, p: jax.device_put(jnp.zeros(s.shape, s.dtype),
                                    NamedSharding(mesh, p)),
        spec, part)


# ---------------------------------------------------------------------------
# device-side read/write (call inside shard_map; pools are local slices)
# ---------------------------------------------------------------------------

def read_pages(rt: Runtime, pools, idx):
    """Gather whole pages by *global* page id, replicated to every device.

    pools: the full pool tree's local slices, leaves
      (n_periods, pages_loc, page_size, Hkv, hd).
    idx: (B,) int32 global page ids (``shard * pages_loc + local_page``);
      -1 pads the fixed transfer bucket (padding reads as zeros).

    Each shard contributes the pages it owns (zeros elsewhere); a psum
    over the SP axes rebuilds the full batch on every device, so the
    caller can pull the result to the host from any one of them. This is
    the device->host leg of the KV connector's spill and of the
    prefill->decode handoff (`engine.kv_connector`).
    """
    rank = rt.sp_rank()

    def leaf(pool):
        pages_loc = pool.shape[1]
        local = idx - rank * pages_loc
        ok = (idx >= 0) & (local >= 0) & (local < pages_loc)
        vals = jnp.take(pool, jnp.where(ok, local, 0), axis=1)
        vals = jnp.where(ok[None, :, None, None, None], vals,
                         jnp.zeros_like(vals))
        return rt.psum_model(vals)

    return jax.tree.map(leaf, pools)


def write_pages(rt: Runtime, pools, idx, data):
    """Scatter whole pages by global page id (inverse of ``read_pages``).

    data: a tree like ``pools`` with leaves (n_periods, B, page_size, Hkv,
    hd), replicated. Every shard writes only the batch entries whose page
    it owns; idx -1 (bucket padding) and out-of-range ids drop. This is
    the host->device leg of the connector's reload and of the decode-side
    handoff injection.
    """
    rank = rt.sp_rank()

    def leaf(pool, d):
        pages_loc = pool.shape[1]
        local = idx - rank * pages_loc
        ok = (idx >= 0) & (local >= 0) & (local < pages_loc)
        tgt = jnp.where(ok, local, pages_loc)               # OOB -> drop
        return pool.at[:, tgt].set(d.astype(pool.dtype), mode="drop")

    return jax.tree.map(leaf, pools, data)


def write_token(rt: Runtime, cache: Dict[str, jax.Array], k_new, v_new,
                paged: PagedTables, cache_len, active):
    """Append one token per slot into its owning shard's page.

    cache: {'k','v'} local pool slices (pages_loc, page_size, Hkv, hd).
    k_new/v_new: (B, 1, Hkv, hd) — post-RoPE K and V of the new token.
    cache_len: (B,) int32 — the new token's global position.
    active: (B,) bool or None — inactive slots write nothing.

    Returns (new_cache, tbl) where tbl (B, W) is this shard's slice of the
    page table — the operand both decode-kernel paths consume (the Pallas
    paged kernel indexes the pool with it directly; the ref path gathers a
    dense view via ``kernels.dispatch.paged_decode(..., impl='ref')``).
    """
    pool_k, pool_v = cache["k"], cache["v"]
    pages_loc, ps = pool_k.shape[0], paged.page_size
    rank = rt.sp_rank()
    sp = rt.sp_size()
    tbl = jax.lax.dynamic_index_in_dim(paged.table, rank, axis=1,
                                       keepdims=False)        # (B, W)
    B, W = tbl.shape

    g = cache_len // ps                                       # global block
    j = g // sp                                               # local block
    page = jnp.take_along_axis(tbl, jnp.clip(j, 0, W - 1)[:, None],
                               axis=1)[:, 0]
    ok = ((g % sp) == rank) & (j < W) & (page >= 0)
    if active is not None:
        ok &= active
    page = jnp.where(ok, page, pages_loc)                     # OOB -> drop
    off = cache_len % ps
    pool_k = pool_k.at[page, off].set(
        k_new[:, 0].astype(pool_k.dtype), mode="drop")
    pool_v = pool_v.at[page, off].set(
        v_new[:, 0].astype(pool_v.dtype), mode="drop")
    return {"k": pool_k, "v": pool_v}, tbl


def insert_prompt(rt: Runtime, pools_sub: Dict[str, jax.Array],
                  k_stack, v_stack, table_row, prompt_len, page_size: int):
    """Scatter a prefilled sequence's K/V into this shard's pool pages.

    pools_sub: {'k','v'} local slices (n_periods, pages_loc, ps, Hkv, hd).
    k_stack/v_stack: (n_periods, 1, S_loc, Hkv, hd) — the prefill cache of
      one sequence, SP-sharded contiguously (post-RoPE, as written by
      ``serve.step.lm_prefill``).
    table_row: (P_sp, W) int32 — the target slot's page-table row.
    prompt_len: traced scalar int32 — tokens beyond it are padding; their
      blocks are never written (and padding *within* a prompt's last block
      is written but unreadable: its positions exceed every cache_len until
      decode overwrites them).

    The prompt arrives sequence-sharded but pages are owned round-robin, so
    one tiled all_gather over the SP axes (O(L) — same order as the prefill
    itself) re-materialises the full prompt before each shard scatters the
    blocks it owns.
    """
    rank = rt.sp_rank()
    sp = rt.sp_size()
    ps = page_size
    kg = rt.all_gather_model(k_stack, axis=2)[:, 0]     # (n_per, L, Hkv, hd)
    vg = rt.all_gather_model(v_stack, axis=2)[:, 0]
    n_per, L = kg.shape[0], kg.shape[1]
    G = L // ps
    kb = kg.reshape(n_per, G, ps, *kg.shape[2:])
    vb = vg.reshape(n_per, G, ps, *vg.shape[2:])

    tbl = jax.lax.dynamic_index_in_dim(table_row, rank, axis=0,
                                       keepdims=False)  # (W,)
    W = tbl.shape[0]
    pages_loc = pools_sub["k"].shape[1]
    gidx = jnp.arange(G, dtype=jnp.int32)
    j = gidx // sp
    page = tbl[jnp.clip(j, 0, W - 1)]
    mine = ((gidx % sp) == rank) & (gidx * ps < prompt_len) \
        & (j < W) & (page >= 0)
    page = jnp.where(mine, page, pages_loc)             # OOB -> drop
    pool_k = pools_sub["k"].at[:, page].set(
        kb.astype(pools_sub["k"].dtype), mode="drop")
    pool_v = pools_sub["v"].at[:, page].set(
        vb.astype(pools_sub["v"].dtype), mode="drop")
    return {"k": pool_k, "v": pool_v}
