"""repro.engine — continuous-batching serving engine with a paged,
SP-sharded KV cache (see docs/SERVING.md).

Public surface:
  Request                — one serving request (prompt, budget, sampling)
  Engine / EngineConfig  — add_request / step / collect / run driver;
                           constructed from a kind='decode' ExecutionPlan
                           (mesh, arrangement, decode_batch/page_size and
                           the paged-decode kernel_impl all come from it)
  build_engine           — convenience constructor: resolves a serve plan
                           (plan.make_serve_plan) over the local mesh
  paged_cache            — SP-sharded page-pool layout + island helpers;
                           PagePool, the ref-counted free list that makes
                           pages shareable (repro.gateway's prefix cache)
  sampling               — vocab-parallel greedy/temperature/top-k/top-p
  scheduler              — FIFO continuous-batching slot/page bookkeeping
                           (prefix-cache-aware admission when a
                           repro.gateway.PrefixCache is attached)
"""

from repro import compat as _compat  # noqa: F401  (jax shims)
from repro.engine.engine import (Engine, EngineConfig, EngineMetrics,
                                 build_engine)
from repro.engine.paged_cache import PagePool
from repro.engine.scheduler import (Rejection, Request, Scheduler, SlotState,
                                    bucket_pow2)

__all__ = [
    "Engine", "EngineConfig", "EngineMetrics", "build_engine", "PagePool",
    "Rejection", "Request", "Scheduler", "SlotState", "bucket_pow2",
]
