"""The serving engine: continuous batching over the paged, SP-sharded KV
cache, compiled once per length bucket.

The engine owns three kinds of state:

  * **device** — the page pools (``paged_cache.init_pools``) and the model
    params, both living in the refined ``(data, sp_grp, sp_ring, sp_team)``
    mesh's shardings — the mesh, the (C, R) refinement and the paged-decode
    ``kernel_impl`` all come from one ``ExecutionPlan`` serve plan
    (``plan.make_serve_plan`` / ``launch.serve --plan``);
  * **host** — the ``Scheduler`` (slots, page free lists, page table,
    FIFO queue);
  * **compiled** — two jit caches: prefill keyed by the padded prompt
    length bucket, decode keyed by the per-shard page-table width bucket
    ``W`` (powers of two). Per-sequence ``cache_len`` is a *traced operand*
    of the decode step, so generation never recompiles: a decode fn only
    recompiles when the longest active sequence crosses a power-of-two
    block-count boundary. ``metrics.decode_compiles`` counts exactly these
    cache misses — the "compiles at most once per bucket" guarantee is
    testable.

``step()`` is one driver iteration in the JetStream style: admit queued
requests into free slots (each admission = one prefill + paged insert +
first sampled token), then run a single decode step for every active slot,
then evict finished requests. Outputs are **bit-identical to serving each
request alone** (for batch-decoupled archs — MoE capacity couples tokens
across the batch): attention/MLP/sampling are all row-independent, page
content is per-slot, and sampling noise is keyed by (request seed, token
position), never by slot or step index.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.dist.sharding import SP_AXES
from repro.engine import kv_connector, paged_cache, sampling as sampling_lib
from repro.engine.scheduler import (Rejection, Request, Scheduler, SlotState,
                                    bucket_pow2)
from repro.models import transformer
from repro.models.factory import Model

_ENGINE_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4          # decode batch width (slots)
    page_size: int = 8          # tokens per KV page
    pages_per_shard: int = 128  # pool capacity per SP shard
    max_len: int = 512          # max prompt_len + max_new_tokens
    max_top_k: int = 64         # static top-k candidate bound
    max_steps: int = 100_000    # runaway guard for run()
    prefill_chunk: int = 0      # 0 = monolithic prefill; > 0 = split long
    #                             prompts into chunks of ~this many tokens
    #                             (rounded up to a compile bucket), one
    #                             chunk per driver step, interleaved with
    #                             decode so a long prompt never stalls the
    #                             decoding batch
    host_tier_bytes: int = 0    # pinned-host KV tier capacity; 0 = off
    #                             (the plan's host_tier_bytes, when set,
    #                             is authoritative — like the rest of the
    #                             serving face)
    transfer_bucket: int = 4    # pages per host-link transfer launch (one
    #                             fixed shape -> the read/write islands
    #                             compile exactly once)


class EngineMetrics:
    """Registry-backed engine metrics.

    Keeps the attribute interface the driver and every existing consumer
    use (``m.steps += 1``, ``to_dict()``, ``reset(keep_compiles=True)``)
    while each field lives as a labeled series in an ``obs.Registry`` —
    one scrape of the registry sees every engine (gateway replicas share
    a registry, distinguished by a ``replica`` label). Counter-kind
    fields render as ``engine_*_total`` counters, level-kind fields as
    gauges; the TTFT / inter-token latency histograms ride in the same
    registry (observed by the engine driver, not through this class).
    """

    # attribute -> (metric name, kind, python type)
    _SPECS = {
        "steps": ("engine_steps_total", "counter", int),
        "decode_steps": ("engine_decode_steps_total", "counter", int),
        "prefills": ("engine_prefills_total", "counter", int),
        "finished": ("engine_requests_finished_total", "counter", int),
        "tokens_out": ("engine_tokens_out_total", "counter", int),
        # device prefill launches (>= prefills when chunking is on)
        "prefill_chunks": ("engine_prefill_chunks_total", "counter", int),
        "prefill_compiles": ("engine_prefill_compiles_total", "counter",
                             int),
        "decode_compiles": ("engine_decode_compiles_total", "counter", int),
        # host-link page transfer islands (read/write, one shape each)
        "transfer_compiles": ("engine_transfer_compiles_total", "counter",
                              int),
        "occupancy_sum": ("engine_occupancy_sum", "gauge", float),
        "peak_pages": ("engine_peak_pages", "gauge", int),
        "pages_total": ("engine_pages_total", "gauge", int),
        "wall_s": ("engine_wall_seconds", "gauge", float),
        # prefix-cache accounting (zero when the cache is off)
        "prefill_tokens_computed": ("engine_prefill_tokens_computed_total",
                                    "counter", int),
        "prefill_tokens_cached": ("engine_prefill_tokens_cached_total",
                                  "counter", int),
        # of the cached tokens, those reloaded from the pinned-host tier
        "prefill_tokens_host": ("engine_prefill_tokens_host_total",
                                "counter", int),
        "prefix_evictions": ("engine_prefix_evictions", "gauge", int),
        # disaggregated prefill->decode handoffs (out: prefill-role side,
        # in: decode-role side)
        "handoffs_out": ("engine_handoffs_out_total", "counter", int),
        "handoffs_in": ("engine_handoffs_in_total", "counter", int),
        # priority preemptions (spill + re-admit; frontend-driven)
        "preemptions": ("engine_preemptions_total", "counter", int),
    }
    _HISTOGRAMS = ("serve_ttft_seconds", "serve_intertoken_seconds")

    def __init__(self, registry: Optional[obs.Registry] = None,
                 labels: Optional[Dict[str, str]] = None, **initial):
        reg = registry if registry is not None else obs.Registry()
        object.__setattr__(self, "registry", reg)
        object.__setattr__(self, "labels", dict(labels or {}))
        for name, (metric, kind, _) in self._SPECS.items():
            if kind == "counter":
                reg.counter(metric)
            else:
                reg.gauge(metric)
        reg.histogram("serve_ttft_seconds",
                      "Request admission -> first emitted token",
                      buckets=obs.TTFT_BUCKETS)
        reg.histogram("serve_intertoken_seconds",
                      "Gap between consecutive emitted tokens of a request",
                      buckets=obs.INTERTOKEN_BUCKETS)
        for name, v in initial.items():
            setattr(self, name, v)

    def __getattr__(self, name):
        spec = self._SPECS.get(name)
        if spec is None:
            raise AttributeError(name)
        metric, _, typ = spec
        return typ(self.registry.get(metric).value(**self.labels))

    def __setattr__(self, name, value) -> None:
        spec = self._SPECS.get(name)
        if spec is None:
            raise AttributeError(f"EngineMetrics has no field {name!r}")
        metric, _, typ = spec
        self.registry.get(metric).set(typ(value), **self.labels)

    def reset(self, keep_compiles: bool = True) -> None:
        pc, dc, tc = (self.prefill_compiles, self.decode_compiles,
                      self.transfer_compiles)
        for name in self._SPECS:
            setattr(self, name, 0)
        for name in self._HISTOGRAMS:
            self.registry.get(name).reset(**self.labels)
        if keep_compiles:
            self.prefill_compiles, self.decode_compiles = pc, dc
            self.transfer_compiles = tc

    def to_dict(self) -> Dict[str, float]:
        d = {name: getattr(self, name) for name in self._SPECS}
        d["occupancy"] = (self.occupancy_sum / self.decode_steps
                          if self.decode_steps else 0.0)
        d["page_utilization"] = (self.peak_pages / self.pages_total
                                 if self.pages_total else 0.0)
        d["tokens_per_s"] = (self.tokens_out / self.wall_s
                             if self.wall_s > 0 else 0.0)
        prompt = self.prefill_tokens_computed + self.prefill_tokens_cached
        d["prefix_hit_rate"] = (self.prefill_tokens_cached / prompt
                                if prompt else 0.0)
        return d

    # latency histograms (driver-facing)
    def observe_ttft(self, seconds: float) -> None:
        self.registry.get("serve_ttft_seconds").observe(
            seconds, **self.labels)

    def observe_intertoken(self, seconds: float) -> None:
        self.registry.get("serve_intertoken_seconds").observe(
            seconds, **self.labels)

    def latency_quantiles(self) -> Dict[str, float]:
        """p50/p95/p99 TTFT and inter-token gap from the fixed buckets."""
        out = {}
        for short, metric in (("ttft", "serve_ttft_seconds"),
                              ("intertoken", "serve_intertoken_seconds")):
            h = self.registry.get(metric)
            for q in (0.5, 0.95, 0.99):
                out[f"{short}_p{int(q * 100)}_s"] = \
                    h.quantile(q, **self.labels)
            out[f"{short}_count"] = h.count(**self.labels)
        return out


class Engine:
    """Continuous-batching serving engine (add_request / step / collect).

    Construction is plan-driven: the ``ExecutionPlan`` (a ``kind='decode'``
    plan with the serving face filled in — see ``plan.make_serve_plan``) is
    the single source of the mesh refinement, the attention scheme, the
    decode slot count / page size, and the paged-decode ``kernel_impl``.
    """

    def __init__(self, model: Model, plan,
                 eng: EngineConfig = EngineConfig(), params=None, mesh=None,
                 registry: Optional[obs.Registry] = None,
                 labels: Optional[Dict[str, str]] = None,
                 tracer: Optional[obs.Tracer] = None):
        import jax
        import jax.numpy as jnp
        import dataclasses as dc

        from repro.train import step as train_step

        cfg = model.cfg
        ok, why = paged_cache.supported(cfg)
        if not ok:
            raise NotImplementedError(f"repro.engine: {cfg.name}: {why}")
        if not plan.decode_batch or not plan.page_size:
            raise ValueError(
                "engine plans need the serving face (decode_batch/page_size "
                "> 0) — build them with plan.make_serve_plan or --plan a "
                "persisted serve plan")
        # the plan is authoritative for the serving shape; EngineConfig
        # keeps only the pool-capacity and sampling/driver knobs
        eng = dc.replace(
            eng, max_slots=plan.decode_batch, page_size=plan.page_size,
            max_len=plan.seq_len,
            host_tier_bytes=int(getattr(plan, "host_tier_bytes", 0)
                                or eng.host_tier_bytes))
        run_cfg = plan.run_config()
        mesh = mesh if mesh is not None else plan.build_mesh()
        self.model, self.mesh, self.run_cfg, self.eng = model, mesh, run_cfg, eng
        self.plan = plan
        self.cfg = cfg
        self.sp = 1
        for a in SP_AXES:
            self.sp *= mesh.shape[a]
        if self.sp != plan.sp_size:
            raise ValueError(f"mesh SP degree {self.sp} != plan "
                             f"sp_size {plan.sp_size}")
        shape = plan.shape_config()
        rt = train_step.make_runtime(model, run_cfg, shape, mode="spmd")
        rt = dc.replace(rt, batch_axes=(),
                        st_cfg=dc.replace(rt.st_cfg, seq_scheme="contiguous"))
        self.rt = rt
        self.kernel_impl = plan.kernel_impl
        self.params = model.init(jax.random.PRNGKey(0)) if params is None \
            else params
        self._param_specs = model.partition(run_cfg.sharding_rules)
        self._pool_part = paged_cache.pool_partition(cfg)
        self._sc = sampling_lib.SamplingConfig(max_top_k=eng.max_top_k)
        self._prefill_base = math.lcm(self.sp, eng.page_size)
        # chunked prefill: the chunk is itself a compile bucket (a multiple
        # of lcm(sp, page_size) so every chunk boundary is page-aligned on
        # every shard and intermediate chunks need no padding)
        self._chunk = 0
        if eng.prefill_chunk > 0:
            self._chunk = bucket_pow2(
                max(eng.prefill_chunk, self._prefill_base),
                self._prefill_base)
            if any(mlp == "moe"
                   for _, mlp in transformer.layer_pattern(cfg)):
                # same coupling that forbids prefix caching: a chunk's
                # tokens compete for expert capacity without the rest of
                # the prompt, so chunked != monolithic for MoE stacks
                raise NotImplementedError(
                    f"repro.engine: {cfg.name}: chunked prefill is unsound "
                    "for MoE stacks (expert capacity couples a chunk's "
                    "tokens to the rest of the prompt)")
        self._prefilling: List[SlotState] = []
        self.last_step_prefills: List[Tuple[int, int]] = []
        # every step runs under this obs scope, so trace-time events in the
        # process-global registry (dispatch's pallas->ref fallbacks) carry
        # a scope label attributing them to this engine instance
        self.obs_scope = f"engine{next(_ENGINE_IDS)}"
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self._arrival: Dict[str, float] = {}     # uid -> enqueue time
        self._last_emit: Dict[str, float] = {}   # uid -> last token time
        self._req_spans: Dict[str, Optional[str]] = {}
        # all pool (re)initialisation goes through one jitted zeroing fn so
        # every pool entering a step fn is a jit output — device_put arrays
        # carry a differently-typed sharding and would retrace the first
        # call after each reset()
        self._zero_pools = jax.jit(jax.shard_map(
            lambda pools: jax.tree.map(jnp.zeros_like, pools),
            mesh=mesh, in_specs=(self._pool_part,),
            out_specs=self._pool_part, check_vma=False),
            donate_argnums=(0,))
        self.pools = self._zero_pools(paged_cache.init_pools(
            cfg, mesh, self.sp * eng.pages_per_shard, eng.page_size))
        self.prefix_caching = bool(getattr(plan, "prefix_cache", False))
        if self.prefix_caching and any(
                mlp == "moe" for _, mlp in transformer.layer_pattern(cfg)):
            # MoE expert capacity couples tokens *within* a sequence: a
            # prefix token's hidden state depends on the suffix competing
            # for expert slots, so cached prefix KV is not reusable.
            raise NotImplementedError(
                f"repro.engine: {cfg.name}: prefix caching is unsound for "
                "MoE stacks (capacity couples prefix KV to the suffix)")
        if eng.host_tier_bytes > 0 and not self.prefix_caching:
            raise ValueError(
                "host_tier_bytes > 0 needs prefix_cache=True: the host "
                "tier is fed by PrefixCache.evict and hit through the "
                "same chain hashes")
        self._prefill_fns: Dict[int, object] = {}
        self._suffix_fns: Dict[int, object] = {}
        self._decode_fns: Dict[int, object] = {}
        self._base_keys: Dict[int, np.ndarray] = {}
        self.metrics = EngineMetrics(
            registry, labels, pages_total=self.sp * eng.pages_per_shard)
        self.registry = self.metrics.registry
        # host-link transfer islands + the connector (spill/reload/handoff)
        self._read_pages_fn = None
        self._write_pages_fn = None
        self._cost_memo: Dict[int, float] = {}
        self._spill_memo: Dict[int, bool] = {}
        page_bytes = sum(
            int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
            for leaf in jax.tree.leaves(
                paged_cache.pool_spec(cfg, 1, eng.page_size)))
        self.connector = kv_connector.KVConnector(
            read_fn=self._read_kv, write_fn=self._write_kv,
            bucket=eng.transfer_bucket, page_size=eng.page_size,
            pages_per_shard=eng.pages_per_shard, page_bytes=page_bytes,
            capacity_bytes=eng.host_tier_bytes,
            spill_fn=self._spill_worthwhile,
            registry=self.registry, labels=labels)
        self._handoff_ready: List[SlotState] = []
        self.scheduler = self._new_scheduler()

    def _new_scheduler(self) -> Scheduler:
        sched = Scheduler(
            max_slots=self.eng.max_slots, page_size=self.eng.page_size,
            sp=self.sp, pages_per_shard=self.eng.pages_per_shard,
            max_len=self.eng.max_len)
        sched.connector = self.connector
        if self.prefix_caching:
            from repro.gateway.prefix_cache import PrefixCache

            sched.prefix_cache = PrefixCache(
                sched.pool, page_size=self.eng.page_size, sp=self.sp,
                cost_fn=self._recompute_cost,
                connector=(self.connector if self.connector.enabled
                           else None))
        return sched

    # ---- host-link transfers (spill / reload / handoff) -----------------
    def _io_fns(self):
        """The page gather/scatter islands, built lazily and compiled
        exactly once — the transfer bucket is a single fixed shape, so a
        second trace here is operand-provenance drift, not a new bucket."""
        if self._read_pages_fn is None:
            import jax
            from jax.sharding import PartitionSpec as P

            rt = self.rt
            self._read_pages_fn = jax.jit(jax.shard_map(
                lambda pools, idx: paged_cache.read_pages(rt, pools, idx),
                mesh=self.mesh, in_specs=(self._pool_part, P()),
                out_specs=P(), check_vma=False))
            self._write_pages_fn = jax.jit(jax.shard_map(
                lambda pools, idx, data: paged_cache.write_pages(
                    rt, pools, idx, data),
                mesh=self.mesh, in_specs=(self._pool_part, P(), P()),
                out_specs=self._pool_part, check_vma=False),
                donate_argnums=(0,))
            self.metrics.transfer_compiles += 2
        return self._read_pages_fn, self._write_pages_fn

    def _read_kv(self, idx: np.ndarray):
        read, _ = self._io_fns()
        return read(self.pools, idx)

    def _write_kv(self, idx: np.ndarray, data) -> None:
        _, write = self._io_fns()
        self.pools = write(self.pools, idx, data)

    def transfer_xla_compiles(self) -> int:
        """XLA trace count of the transfer islands (2 once used; more
        means silent retracing — same contract as ``xla_compiles``)."""
        n = 0
        for fn in (self._read_pages_fn, self._write_pages_fn):
            if fn is not None:
                size = getattr(fn, "_cache_size", None)
                n += size() if callable(size) else 1
        return n

    def _recompute_cost(self, chain_tokens: int) -> float:
        """Eviction ranking: seconds to re-prefill a chain cold."""
        c = self._cost_memo.get(chain_tokens)
        if c is None:
            from repro.plan import cost as plan_cost

            c = plan_cost.prefill_step_cost(
                self.cfg, prompt_len=chain_tokens, sp=self.sp,
                page_size=self.eng.page_size)["total_s"]
            self._cost_memo[chain_tokens] = c
        return c

    def _spill_worthwhile(self, chain_tokens: int) -> bool:
        """Under host-tier pressure: does the transfer round-trip beat
        recomputing this chain (plan.cost.spill_decision)?"""
        v = self._spill_memo.get(chain_tokens)
        if v is None:
            from repro.plan import cost as plan_cost

            v = bool(plan_cost.spill_decision(
                self.cfg, chain_tokens=chain_tokens, sp=self.sp,
                page_size=self.eng.page_size)["spill"])
            self._spill_memo[chain_tokens] = v
        return v

    @property
    def prefix_cache(self):
        return self.scheduler.prefix_cache

    # ---- request lifecycle ---------------------------------------------
    def add_request(self, req: Request) -> Optional[Rejection]:
        """Queue ``req``. Returns ``None`` on success or a typed
        :class:`Rejection` (never raises for unserveable requests — the
        HTTP layer maps ``reason`` to a status code)."""
        rej = self.scheduler.validate(req)
        if rej is not None:
            return rej
        self.scheduler.queue.append(req)
        self._arrival[req.uid] = time.monotonic()
        self._req_spans[req.uid] = self.tracer.async_begin(
            "request", uid=req.uid, prompt_len=req.prompt_len,
            max_new=req.max_new_tokens)
        return None

    def preempt(self, uid: str) -> Optional[Request]:
        """Evict ``uid`` from the engine, preserving its progress, and
        return the *resume request* to re-admit later (here or on another
        replica). ``None`` if the uid is not queued or active here.

        The resume request's prompt is ``tokens + out`` with the remaining
        token budget: re-admission prefills it like any other prompt, and
        because sampling is keyed by (seed, absolute position) — prefill
        folds at ``prompt_len``, decode at ``cache_len + 1`` — the resumed
        stream continues bit-identically to the uninterrupted one. With a
        prefix cache attached, the preempted slot's complete valid KV
        blocks are registered in the trie first, so the resume prefill is
        mostly (often entirely) a cache hit instead of a recompute; under
        memory pressure those blocks are spillable to the host tier like
        any cached chain.
        """
        for i, r in enumerate(self.scheduler.queue):
            if r.uid == uid:                 # still queued: nothing started
                del self.scheduler.queue[i]
                self._arrival.pop(uid, None)
                self.tracer.async_end(
                    "request", self._req_spans.pop(uid, None), preempted=True)
                self.metrics.preemptions += 1
                return r
        st = next((s for s in self.scheduler.active() if s.req.uid == uid),
                  None)
        if st is None or st.req.handoff:
            # handoff slots pin exported KV — preempting one mid-export
            # would tear the transfer; the gateway owns their lifecycle
            return None
        req = st.req
        seq = list(req.tokens) + [int(t) for t in st.out]
        # KV valid through max(cache_len, prefill_pos): decode keeps
        # cache_len, a mid-chunk prefill only prefill_pos. pending_reload
        # blocks hold garbage until _advance_prefill lands them, so a slot
        # that never ran a chunk registers nothing new (its device-hit
        # prefix is already in the trie).
        valid = max(st.cache_len, st.prefill_pos)
        pc = self.prefix_cache
        if pc is not None and not st.pending_reload:
            full = valid // self.eng.page_size
            if full > 0:
                pc.insert(pc.hashes(seq)[:full], st.pages[:full])
        if st in self._prefilling:
            self._prefilling.remove(st)
        remaining = req.max_new_tokens - len(st.out)
        self.scheduler.finish(st.slot, self.metrics.steps)
        self.scheduler.finished.pop(uid, None)    # not finished: preempted
        self.metrics.preemptions += 1
        self._arrival.pop(uid, None)
        self._last_emit.pop(uid, None)
        self.tracer.async_end("request", self._req_spans.pop(uid, None),
                              preempted=True, tokens=len(st.out))
        if not st.out:
            return req
        return dataclasses.replace(req, tokens=seq,
                                   max_new_tokens=remaining)

    def _finish_request(self, st: SlotState) -> None:
        """Bookkeeping common to every finish site (prefill or decode)."""
        self.scheduler.finish(st.slot, self.metrics.steps)
        self.metrics.finished += 1
        uid = st.req.uid
        self._arrival.pop(uid, None)
        self._last_emit.pop(uid, None)
        self.tracer.async_end("request", self._req_spans.pop(uid, None),
                              tokens=len(st.out))

    def collect(self) -> Dict[str, List[int]]:
        """uid -> generated tokens, for every finished request."""
        return {uid: list(st.out)
                for uid, st in self.scheduler.finished.items()}

    def reset(self) -> None:
        """Drop all requests and cache contents (including the prefix
        cache and the host tier — the pools are zeroed); keep compiled
        fns."""
        self.pools = self._zero_pools(self.pools)
        self.connector.reset()
        self.scheduler = self._new_scheduler()
        self._prefilling = []
        self._handoff_ready = []
        self.last_step_prefills = []
        self._arrival.clear()
        self._last_emit.clear()
        self._req_spans.clear()
        self.metrics.reset(keep_compiles=True)
        self.metrics.pages_total = self.scheduler.pages_total()

    def pallas_fallbacks(self) -> Dict[str, int]:
        """Trace-time pallas->ref fallback counts attributable to *this*
        engine: the dispatch layer's labeled registry counters, filtered
        by this engine's ``obs.scope`` (every ``step()`` runs under it) —
        a fresh engine has a fresh scope, so it never inherits fallbacks
        earlier engines or tests traced."""
        from repro.kernels import dispatch as _dispatch

        return _dispatch.pallas_fallbacks(scope=self.obs_scope)

    # ---- compiled-step caches ------------------------------------------
    def _prefill_bucket(self, prompt_len: int) -> int:
        return bucket_pow2(prompt_len, self._prefill_base)

    def _prefill_fn(self, bucket_len: int, sampled: bool):
        """One jit per (padded prompt length, any-sampling). All-greedy
        requests skip the top-k/top-p/gumbel kernel entirely; the sampled
        variant's greedy branch produces the identical token for T<=0 rows,
        so the split never changes outputs."""
        import jax
        import dataclasses as dc
        from jax.sharding import PartitionSpec as P

        from repro.serve import step as serve_step

        fn = self._prefill_fns.get((bucket_len, sampled))
        if fn is not None:
            return fn
        cfg, eng, sc = self.cfg, self.eng, self._sc
        rt = dc.replace(self.rt, st_cfg=dc.replace(self.rt.st_cfg,
                                                   seq_len=bucket_len))
        pat = transformer.layer_pattern(cfg)

        def island(params, tokens, prompt_len, pools, table_row,
                   temp, top_k, top_p, key):
            last, cache = serve_step.lm_prefill(
                rt, params, {"tokens": tokens}, cfg,
                prompt_len=prompt_len, return_hidden=True)
            subs = {}
            for i in range(len(pat)):
                subs[f"sub{i}"] = paged_cache.insert_prompt(
                    rt, pools["stack"][f"sub{i}"],
                    cache["stack"][f"sub{i}"]["k"],
                    cache["stack"][f"sub{i}"]["v"],
                    table_row, prompt_len[0], eng.page_size)
            head = params.get("lm_head", params["embed"])
            if sampled:
                k1 = jax.random.fold_in(key, prompt_len[0])
                tok = sampling_lib.sample(
                    rt, head, last, cfg, temperature=temp, top_k=top_k,
                    top_p=top_p, keys=k1[None], sc=sc)
            else:
                tok = sampling_lib.greedy(rt, head, last, cfg)
            return tok, {"stack": subs}

        fn = jax.jit(jax.shard_map(
            island, mesh=self.mesh,
            in_specs=(self._param_specs, P(None, SP_AXES), P(),
                      self._pool_part, P(), P(), P(), P(), P()),
            out_specs=(P(), self._pool_part), check_vma=False),
            donate_argnums=(3,))
        self._prefill_fns[(bucket_len, sampled)] = fn
        self.metrics.prefill_compiles += 1
        return fn

    def _suffix_fn(self, bucket_len: int, sampled: bool):
        """One jit per (padded *suffix* length, any-sampling): the
        prefix-cached prefill. The page-table row keeps its full static
        width (one prefill per request — no width bucketing needed)."""
        import jax
        import dataclasses as dc
        from jax.sharding import PartitionSpec as P

        from repro.serve import step as serve_step

        fn = self._suffix_fns.get((bucket_len, sampled))
        if fn is not None:
            return fn
        cfg, eng, sc = self.cfg, self.eng, self._sc
        rt = dc.replace(self.rt, st_cfg=dc.replace(self.rt.st_cfg,
                                                   seq_len=bucket_len))

        def island(params, tokens, prompt_len, cached_len, pools, table_row,
                   temp, top_k, top_p, key):
            last, new_pools = serve_step.lm_prefill_paged(
                rt, params, {"tokens": tokens}, cfg,
                prompt_len=prompt_len, cached_len=cached_len, pools=pools,
                table_row=table_row, page_size=eng.page_size)
            head = params.get("lm_head", params["embed"])
            if sampled:
                k1 = jax.random.fold_in(key, prompt_len[0])
                tok = sampling_lib.sample(
                    rt, head, last, cfg, temperature=temp, top_k=top_k,
                    top_p=top_p, keys=k1[None], sc=sc)
            else:
                tok = sampling_lib.greedy(rt, head, last, cfg)
            return tok, new_pools

        fn = jax.jit(jax.shard_map(
            island, mesh=self.mesh,
            in_specs=(self._param_specs, P(None, SP_AXES), P(), P(),
                      self._pool_part, P(), P(), P(), P(), P()),
            out_specs=(P(), self._pool_part), check_vma=False),
            donate_argnums=(4,))
        self._suffix_fns[(bucket_len, sampled)] = fn
        self.metrics.prefill_compiles += 1
        return fn

    def _decode_fn(self, width: int, sampled: bool):
        """One jit per (table-width bucket, any-active-request-samples)."""
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.serve import step as serve_step

        fn = self._decode_fns.get((width, sampled))
        if fn is not None:
            return fn
        cfg, eng, rt, sc = self.cfg, self.eng, self.rt, self._sc

        def island(params, pools, tokens, cache_len, table,
                   temp, top_k, top_p, keys, active):
            paged = paged_cache.PagedTables(table=table,
                                            page_size=eng.page_size)
            sampling = {"temperature": temp, "top_k": top_k, "top_p": top_p,
                        "keys": keys, "sc": sc} if sampled else None
            return serve_step.lm_decode_step(
                rt, params, pools, tokens, cfg, cache_len, paged=paged,
                active=active, sampling=sampling)

        fn = jax.jit(jax.shard_map(
            island, mesh=self.mesh,
            in_specs=(self._param_specs, self._pool_part, P(), P(), P(),
                      P(), P(), P(), P(), P()),
            out_specs=(P(), self._pool_part), check_vma=False),
            donate_argnums=(1,))
        self._decode_fns[(width, sampled)] = fn
        self.metrics.decode_compiles += 1
        return fn

    def xla_compiles(self) -> Tuple[int, int]:
        """(prefill, decode) XLA-level trace counts summed over the bucket
        fns. Unlike the bucket-miss counters this catches *silent*
        retracing (dtype/weak-type drift in the host-assembled operands):
        every bucket fn should hold exactly one cache entry."""
        def total(fns):
            n = 0
            for fn in fns.values():
                size = getattr(fn, "_cache_size", None)
                n += size() if callable(size) else 1
            return n
        return (total(self._prefill_fns) + total(self._suffix_fns),
                total(self._decode_fns))

    def _base_key(self, seed: int) -> np.ndarray:
        key = self._base_keys.get(seed)
        if key is None:
            import jax

            key = np.asarray(jax.random.PRNGKey(seed))
            self._base_keys[seed] = key
        return key

    # ---- driver ---------------------------------------------------------
    def _advance_prefill(self, st: SlotState):
        """Run one prefill chunk for ``st`` (the whole remaining prompt
        when chunking is off). Returns the first sampled token when the
        prompt completes, else None.

        A leading chunk (``prefill_pos == 0``) runs the dense full-forward
        prefill; every later chunk is a *suffix* prefill with
        ``cached_len = prefill_pos`` — the pages earlier chunks (or the
        prefix cache) populated are read in place, so one jit bucket
        serves prefix hits and chunk continuations alike. Only the final
        chunk's token is kept; its sampling fold (request seed, position
        ``prompt_len``) is the same as the monolithic path's, so chunking
        never changes the emitted stream.
        """
        req = st.req
        m = self.metrics
        if st.pending_reload:
            # host-tier hits: land their KV in the freshly-allocated pool
            # pages before any forward reads them
            with self.tracer.span("engine/host_reload", cat="engine",
                                  uid=req.uid, blocks=len(st.pending_reload)):
                self.connector.reload(st.pending_reload)
            m.prefill_tokens_host += st.host_len
            st.pending_reload = []
        start = st.prefill_pos
        end = req.prompt_len if not self._chunk \
            else min(start + self._chunk, req.prompt_len)
        final = end == req.prompt_len
        sampled = final and req.temperature > 0.0
        sampling_args = (
            np.asarray([req.temperature], np.float32),
            np.asarray([req.top_k], np.int32),
            np.asarray([req.top_p], np.float32),
            self._base_key(req.seed))
        if start:
            suffix = end - start
            bucket = self._prefill_bucket(suffix)
            fn = self._suffix_fn(bucket, sampled)
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :suffix] = req.tokens[start:end]
            tok, self.pools = fn(
                self.params, tokens, np.asarray([end], np.int32),
                np.asarray([start], np.int32), self.pools,
                self.scheduler.table[st.slot].copy(), *sampling_args)
        else:
            bucket = self._prefill_bucket(end)
            fn = self._prefill_fn(bucket, sampled)
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :end] = req.tokens[:end]
            tok, self.pools = fn(
                self.params, tokens, np.asarray([end], np.int32),
                self.pools, self.scheduler.table[st.slot].copy(),
                *sampling_args)
        st.prefill_pos = end
        m.prefill_tokens_computed += end - start
        m.prefill_chunks += 1
        self.last_step_prefills.append((start, end))
        return int(np.asarray(tok)[0, 0]) if final else None

    def _complete_prefill(self, st: SlotState, tok: int, emitted) -> None:
        m = self.metrics
        self._prefilling.remove(st)
        self.scheduler.register_prefix(st)
        m.prefill_tokens_cached += st.cached_len
        st.cache_len = st.req.prompt_len
        st.out.append(tok)
        st.first_token_step = m.steps
        emitted.append((st.req.uid, tok))
        m.prefills += 1
        m.tokens_out += 1
        now = time.monotonic()
        arrived = self._arrival.get(st.req.uid)
        if arrived is not None:
            m.observe_ttft(now - arrived)
        self._last_emit[st.req.uid] = now
        if st.done:
            if st.req.handoff:
                # keep the slot (and its pages' refs) live until the
                # gateway exports the prompt KV to a decode replica —
                # finishing here could recycle the pages mid-export
                self._handoff_ready.append(st)
                self.metrics.handoffs_out += 1
            else:
                self._finish_request(st)

    # ---- disaggregated prefill -> decode handoff ------------------------
    def take_handoffs(self) -> List[SlotState]:
        """Slots whose handoff prefill finished this step (prompt KV still
        pinned). The caller must ``export_kv`` then ``release_handoff``
        each one."""
        out, self._handoff_ready = self._handoff_ready, []
        return out

    def export_kv(self, st: SlotState) -> List:
        """Read the slot's prompt-KV pages to host, block order. The
        partial tail block rides along — positions past ``prompt_len``
        hold garbage the position-encoded validity never reads."""
        nb_kv = math.ceil(st.req.prompt_len / self.eng.page_size)
        return self.connector.export(st.pages[:nb_kv])

    def release_handoff(self, st: SlotState) -> None:
        """Drop the handoff slot after its KV has been exported."""
        self._finish_request(st)

    def add_prefilled(self, req: Request, first_token: int,
                      blocks: List) -> None:
        """Decode-role entry point: queue a request whose prompt KV and
        first token came from a prefill replica. No TTFT is observed here
        — the first token was emitted by the prefill engine."""
        self.scheduler.enqueue_prefilled(req, first_token, blocks)

    def step(self) -> List[Tuple[str, int]]:
        """One driver iteration: admit, advance prefills (one chunk each),
        one decode step for every decoding slot.

        Returns the (uid, token) pairs emitted this step.
        """
        with obs.scope(self.obs_scope), \
                self.tracer.span("engine/step", cat="engine",
                                 scope=self.obs_scope,
                                 step=self.metrics.steps):
            return self._step_inner()

    def _step_inner(self) -> List[Tuple[str, int]]:
        t0 = time.monotonic()
        emitted: List[Tuple[str, int]] = []
        m = self.metrics
        tracer = self.tracer
        self.last_step_prefills = []

        # commit spills staged by the previous step's evictions: host-tier
        # entries become hittable only once their d2h copy has landed (a
        # torn spill is never observable as a hit)
        self.connector.flush()

        # disaggregated handoff inbox: requests with prompt KV prefilled
        # on another replica enter here — inject the exported pages and
        # the already-sampled first token, skipping prefill entirely
        for st, tok, blocks in self.scheduler.admit_prefilled(m.steps):
            with tracer.span("engine/handoff_inject", cat="engine",
                             uid=st.req.uid, blocks=len(blocks)):
                nb_kv = math.ceil(st.req.prompt_len / self.eng.page_size)
                self.connector.inject(st.pages[:nb_kv], blocks)
            st.cache_len = st.req.prompt_len
            st.prefill_pos = st.req.prompt_len
            st.out.append(tok)
            st.first_token_step = m.steps
            m.handoffs_in += 1
            self._last_emit[st.req.uid] = time.monotonic()
            if st.done:                      # degenerate 1-token budget
                self._finish_request(st)

        # in-flight chunked prefills admitted on earlier steps: one chunk
        # each, *before* this step's admissions (FIFO progress)
        for st in list(self._prefilling):
            with tracer.span("engine/prefill_chunk", cat="engine",
                             uid=st.req.uid, start=st.prefill_pos):
                tok = self._advance_prefill(st)
            if tok is not None:
                self._complete_prefill(st, tok, emitted)

        while True:
            # one at a time: each admission registers its prompt blocks
            # before the next is matched, so same-step bursts sharing a
            # prefix hit the cache (a *chunked* long prompt registers only
            # when its last chunk lands, steps later)
            batch = self.scheduler.admit(m.steps, limit=1)
            if not batch:
                break
            st = batch[0]
            self._prefilling.append(st)
            with tracer.span("engine/prefill", cat="engine",
                             uid=st.req.uid, prompt_len=st.req.prompt_len,
                             cached_len=st.cached_len):
                tok = self._advance_prefill(st)
            if tok is not None:
                self._complete_prefill(st, tok, emitted)
        if self.scheduler.prefix_cache is not None:
            m.prefix_evictions = self.scheduler.prefix_cache.evicted_pages

        # decode: slots whose prefill has completed (mid-chunk slots hold
        # pages but have no token stream yet; done-but-unreleased handoff
        # slots only await export and must not keep generating)
        active = [st for st in self.scheduler.active()
                  if st.cache_len > 0 and not st.done]
        if active:
            width = self.scheduler.decode_width()
            sampled = any(st.req.temperature > 0.0 for st in active)
            with tracer.span("engine/decode", cat="engine",
                             width=width, active=len(active)):
                fn = self._decode_fn(width, sampled)
                B = self.eng.max_slots
                tokens = np.zeros((B, 1), np.int32)
                cache_len = np.zeros((B,), np.int32)
                temp = np.zeros((B,), np.float32)
                top_k = np.zeros((B,), np.int32)
                top_p = np.ones((B,), np.float32)
                keys = np.zeros((B, 2), np.uint32)
                act = np.zeros((B,), bool)
                for st in active:
                    i = st.slot
                    tokens[i, 0] = st.out[-1]
                    cache_len[i] = st.cache_len
                    temp[i] = st.req.temperature
                    top_k[i] = st.req.top_k
                    top_p[i] = st.req.top_p
                    keys[i] = self._base_key(st.req.seed)
                    act[i] = True
                table = np.ascontiguousarray(
                    self.scheduler.table[:, :, :width])
                tok, self.pools = fn(self.params, self.pools, tokens,
                                     cache_len, table, temp, top_k, top_p,
                                     keys, act)
                tok = np.asarray(tok)
            now = time.monotonic()
            for st in active:
                t = int(tok[st.slot, 0])
                st.out.append(t)
                st.cache_len += 1
                emitted.append((st.req.uid, t))
                m.tokens_out += 1
                uid = st.req.uid
                last = self._last_emit.get(uid)
                if last is not None:
                    m.observe_intertoken(now - last)
                self._last_emit[uid] = now
                if st.done:
                    self._finish_request(st)
            m.decode_steps += 1
            m.occupancy_sum += len(active) / self.eng.max_slots

        m.peak_pages = max(m.peak_pages, self.scheduler.pages_in_use())
        m.steps += 1
        m.wall_s += time.monotonic() - t0
        return emitted

    def idle(self) -> bool:
        return not self.scheduler.queue and not self.scheduler.prefilled \
            and not self.scheduler.active()

    def run(self, max_steps: Optional[int] = None) -> Dict[str, List[int]]:
        """Drive until every queued/running request finishes."""
        limit = max_steps or self.eng.max_steps
        n = 0
        while not self.idle():
            emitted = self.step()
            if not emitted and not self.scheduler.active():
                # queue non-empty but nothing admitted and nothing decoding:
                # the head request cannot make progress (enqueue validation
                # makes this unreachable, but fail loud rather than spin)
                raise RuntimeError(
                    f"engine stalled with {len(self.scheduler.queue)} queued "
                    "requests and no admissible slot/pages")
            n += 1
            if n > limit:
                raise RuntimeError(f"engine did not drain in {limit} steps")
        return self.collect()


def build_engine(arch: str, *, smoke: bool = True, c: Optional[int] = 1,
                 data: int = 1, eng: EngineConfig = EngineConfig(),
                 params=None, init_seed: int = 0,
                 kernel: Optional[str] = None, plan=None,
                 registry: Optional[obs.Registry] = None,
                 tracer: Optional[obs.Tracer] = None) -> Engine:
    """Convenience constructor: resolve a serve plan, build the engine.

    With ``plan=None`` a ``kind='decode'`` ExecutionPlan is made from the
    knobs over every available device (``make_serve_plan`` — same
    refinement rule as the train launcher; pass ``c=None`` to let the cost
    model pick the factorisation). ``kernel`` selects the paged-decode
    kernel (None = backend default: pallas on TPU, ref on CPU).
    """
    import jax

    from repro.configs import registry as arch_registry
    from repro.models.factory import build_model
    from repro.plan import make_serve_plan

    cfg = arch_registry.get_smoke(arch) if smoke else arch_registry.get(arch)
    model = build_model(cfg)
    if plan is None:
        plan = make_serve_plan(
            cfg, arch=arch, n_devices=len(jax.devices()), data=data, c=c,
            decode_batch=eng.max_slots, page_size=eng.page_size,
            max_len=eng.max_len, mesh_kind="local", kernel_impl=kernel)
    if params is None:
        params = model.init(jax.random.PRNGKey(init_seed))
    return Engine(model, plan, eng, params, registry=registry, tracer=tracer)
