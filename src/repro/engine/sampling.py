"""Vocab-parallel sampling: greedy / temperature / top-k / top-p without
ever gathering the full logits.

The vocabulary is sharded over the SP axes (Megatron-style, same layout as
``blocks.lm_head_logits_and_loss``), so every primitive here works on the
local ``(B, V/P)`` logits slice plus O(1)-sized collectives:

  * **greedy / argmax** — local top-1, then a lexicographic global combine:
    ``pmax`` of the values, ``pmin`` of the winning shard rank, ``psum`` of
    the winner's token id. Ties break toward the lowest shard and, within a
    shard, toward the lowest local index — i.e. deterministically toward the
    *smallest global token id* among tied maxima.
  * **temperature** — pure local scaling (sampling itself is gumbel-max:
    ``argmax(logits/T + gumbel)`` is an exact categorical sample, and argmax
    distributes over shards exactly like greedy).
  * **top-k** — each shard contributes its local top-``K_MAX`` values
    (``K_MAX`` a static bound, default 64); the k-th largest of the gathered
    ``P * K_MAX`` candidates is the global threshold. Only ``K_MAX`` scalars
    per shard are communicated, never the logits.
  * **top-p** — the nucleus is found as a *probability threshold*: global
    softmax normalisation via the flash-style ``pmax``/``psum`` pair, then a
    fixed-iteration bisection on the threshold ``t`` with the monotone mass
    function ``mass(t) = psum(sum(probs[probs >= t]))``. Keeps the smallest
    set of highest-probability tokens whose mass reaches ``p`` (ties at the
    threshold are all kept, matching conventional implementations).

Per-sequence parameters are traced ``(B,)`` arrays so one compiled decode
step serves a continuously-batched mix of greedy and stochastic requests.
Gumbel noise is keyed per ``(request, position)`` (the engine folds the
token position into the key) plus the shard rank, which makes every
request's sample stream independent of batch composition — the property
behind the engine's "batched == solo" bit-exactness guarantee.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.runtime import Runtime

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Static bounds of the sampling kernels (part of the compile key)."""

    max_top_k: int = 64      # static candidate count gathered per shard
    nucleus_iters: int = 30  # bisection steps for the top-p threshold


def shard_logits(rt: Runtime, head_params, x, cfg: ModelConfig):
    """This shard's vocab-slice logits for the newest position.

    x: (B, 1, D) replicated over SP. Returns (logits (B, V_local) float32
    with padded vocab rows at NEG, lo = first global token id of the slice).
    """
    table = rt.dense(head_params["table"], ("vocab", "embed"))
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))[:, 0]
    v_local = table.shape[0]
    lo = rt.sp_rank() * v_local
    logits = jnp.where((lo + jnp.arange(v_local)) < cfg.vocab_size,
                       logits, NEG)
    return logits, lo


def lowest_shard_argmax(rt: Runtime, vals, lo):
    """Global argmax of shard-sliced (B, V_local) values -> (B,) token ids.

    Deterministic tie-break: the lowest shard wins (pmin over the winning
    ranks), and jnp.argmax picks the lowest local index — so ties resolve
    to the smallest global token id.
    """
    loc_max = jnp.max(vals, axis=-1)
    loc_arg = jnp.argmax(vals, axis=-1).astype(jnp.int32)
    if rt.mode == "local":
        return loc_arg
    axes = rt.sp_axes
    rank = rt.sp_rank()
    g_max = jax.lax.pmax(loc_max, axes)
    win = loc_max >= g_max
    win_rank = jax.lax.pmin(
        jnp.where(win, rank, jnp.int32(2 ** 30)), axes)
    mine = win & (rank == win_rank)
    return jax.lax.psum(jnp.where(mine, loc_arg + lo, 0), axes)


def greedy(rt: Runtime, head_params, x, cfg: ModelConfig):
    """Greedy next token, vocab-parallel. x: (B, 1, D) -> (B, 1) int32."""
    logits, lo = shard_logits(rt, head_params, x, cfg)
    return lowest_shard_argmax(rt, logits, lo)[:, None]


def _psum(rt: Runtime, x):
    return x if rt.mode == "local" else jax.lax.psum(x, rt.sp_axes)


def _pmax(rt: Runtime, x):
    return x if rt.mode == "local" else jax.lax.pmax(x, rt.sp_axes)


def sample(rt: Runtime, head_params, x, cfg: ModelConfig, *,
           temperature, top_k, top_p, keys,
           sc: SamplingConfig = SamplingConfig()):
    """Sample next tokens with per-sequence parameters. Returns (B, 1) int32.

    temperature: (B,) float32 — rows with temperature <= 0 decode greedily.
    top_k: (B,) int32 — 0 disables; effective values are capped at
      ``sc.max_top_k * P_sp`` (the static candidate pool).
    top_p: (B,) float32 — 1.0 disables.
    keys: (B, 2) uint32 PRNG keys already folded with the token *position*;
      the shard rank is folded in here so noise is shard-local.
    """
    logits, lo = shard_logits(rt, head_params, x, cfg)
    B, v_local = logits.shape
    greedy_tok = lowest_shard_argmax(rt, logits, lo)

    t = jnp.maximum(temperature, 1e-6)[:, None]
    z = logits / t

    # ---- top-k: global threshold from each shard's local top-K_MAX ----
    kk = min(sc.max_top_k, v_local)
    loc_top = jax.lax.top_k(z, kk)[0]                       # (B, kk)
    all_top = rt.all_gather_sp_stack(loc_top)               # (P, B, kk)
    all_top = jnp.moveaxis(all_top, 0, 1).reshape(B, -1)    # (B, P*kk)
    all_top = -jnp.sort(-all_top, axis=-1)
    idx = jnp.clip(top_k - 1, 0, all_top.shape[-1] - 1)
    thr_k = jnp.take_along_axis(all_top, idx[:, None], axis=-1)
    z = jnp.where((top_k[:, None] > 0) & (z < thr_k), NEG, z)

    # ---- global softmax over the surviving tokens ----
    m = _pmax(rt, jnp.max(z, axis=-1))                      # (B,)
    ez = jnp.exp(z - m[:, None])
    se = _psum(rt, jnp.sum(ez, axis=-1))                    # (B,)
    probs = ez / se[:, None]

    # ---- top-p: bisect the largest threshold with mass(t) >= p ----
    lo_t = jnp.zeros_like(top_p)
    hi_t = jnp.ones_like(top_p)
    for _ in range(sc.nucleus_iters):
        mid = 0.5 * (lo_t + hi_t)
        mass = _psum(rt, jnp.sum(
            jnp.where(probs >= mid[:, None], probs, 0.0), axis=-1))
        ge = mass >= top_p
        lo_t = jnp.where(ge, mid, lo_t)
        hi_t = jnp.where(ge, hi_t, mid)
    z = jnp.where((top_p[:, None] < 1.0) & (probs < lo_t[:, None]), NEG, z)

    # ---- gumbel-max: argmax(z + g) is an exact categorical sample ----
    rank = rt.sp_rank()

    def noise_row(key):
        return jax.random.gumbel(jax.random.fold_in(key, rank),
                                 (v_local,), jnp.float32)

    g = jax.vmap(noise_row)(keys)
    pert = jnp.where(z <= NEG / 2, NEG, z + g)
    samp_tok = lowest_shard_argmax(rt, pert, lo)

    tok = jnp.where(temperature <= 0.0, greedy_tok, samp_tok)
    return tok[:, None]
