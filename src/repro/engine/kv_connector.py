"""Tiered KV store: a pinned-host tier behind the HBM page pool, bridged
by a KV connector (the Mooncake/vLLM-connector construction, on this
repo's SP-sharded paged pool).

Why
---
The prefix cache (`gateway.prefix_cache`) lives entirely in the device
page pool, so its capacity — and the hit rate it can sustain — is capped
by HBM. At ring-attention context lengths the KV footprint, not FLOPs, is
the binding constraint, and the leaf-first eviction throws away KV that
was *expensive* to compute. The host tier turns that eviction into a
demotion: a refcount-1 node `PrefixCache.evict` would drop instead spills
its page to pinned host memory (chain hash preserved), and a later trie
hit on the same chain reloads it into freshly-acquired pool pages instead
of re-prefilling.

Tiers and lifecycle
-------------------
::

      device HBM page pool          pinned host arrays
    ┌──────────────────────┐      ┌─────────────────────┐
    │ PagePool + PrefixCache│ spill│  staging (in-flight │
    │ (SP-sharded pages,    │─────▶│  device copies)     │
    │  refcounted, COW)     │      │    │ flush/commit   │
    │                       │◀─────│    ▼                │
    │ fresh pages at admit  │reload│  HostTier store     │
    └──────────────────────┘      │  (hash -> KV, LRU)  │
                                   └─────────────────────┘

* **spill** — at eviction the victim page is read out of the pool by a
  jitted gather (`paged_cache.read_pages`, one fixed-size transfer bucket
  so it compiles exactly once) and parked in a per-shard **staging**
  list as a device array: the dispatch is asynchronous, and the copy has
  captured the page's value in program order, so the pool page can be
  reused immediately.
* **flush/commit** — at the top of the next engine step the staged
  arrays are materialised to host numpy and inserted into the
  :class:`HostTier` store. Only *committed* entries are hittable
  (`has()`), so a torn or in-flight spill can never satisfy a lookup.
* **reload** — admission probes the tier with the same chain hashes the
  device trie uses; hits extend ``cached_len`` past the device match, and
  the scheduler records pending reloads into the *fresh* pages it just
  allocated (host hits are cheap-but-not-free: they still consume pool
  pages and admission feasibility counts them like any uncached block).
  The engine writes them back with `paged_cache.write_pages` before the
  suffix prefill runs.

The same read/write islands carry the **prefill -> decode handoff** of a
disaggregated gateway (`export` / `inject`): finished prefill KV goes
device -> host -> device between replicas on the smoke path.

Pricing
-------
`plan.cost.spill_decision` compares the round-trip transfer bytes against
the chain's recompute FLOPs. The connector spills unconditionally while
the tier has free capacity (an idle host tier costs nothing to fill);
under capacity pressure the decision gates admission, so chains cheaper
to recompute than to round-trip never displace valuable ones. The same
cost curve orders `PrefixCache.evict`'s victims (cheapest-recompute
first).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

# Host transfers span ~page-bucket DMAs (sub-ms) to multi-MB chain
# reloads; pinned so latency quantiles are comparable across runs.
TRANSFER_BUCKETS = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 0.01, 0.03,
                    0.1, 0.3, 1.0, 3.0)


@dataclasses.dataclass
class _HostPage:
    """One committed page in the host tier (immutable once stored)."""

    key: int                 # chain hash (position-qualified, trie-equal)
    chain_tokens: int        # tokens of the chain ending at this block
    data: object             # pool-shaped tree, leaves (n_per, ps, Hkv, hd)


@dataclasses.dataclass
class _Staged:
    """An in-flight spill: device arrays whose d2h copy may still be
    running. Invisible to ``has()`` until committed by ``flush()``."""

    key: int
    chain_tokens: int
    data: object             # device tree, leaves (n_per, ps, Hkv, hd)
    t0: float                # dispatch time (for the d2h latency sample)


class HostTier:
    """Pinned-host page store keyed by chain hash, byte-capacity LRU.

    Holds only *committed* numpy pages; capacity is enforced in whole
    pages (``capacity_bytes // page_bytes``). Eviction is LRU over
    committed entries — reloads touch, so chains in active rotation
    survive.
    """

    def __init__(self, *, capacity_bytes: int, page_bytes: int):
        if page_bytes <= 0:
            raise ValueError(f"page_bytes must be positive, got {page_bytes}")
        self.page_bytes = page_bytes
        self.capacity_pages = max(int(capacity_bytes) // page_bytes, 0)
        self._store: "collections.OrderedDict[int, _HostPage]" = \
            collections.OrderedDict()
        self.evicted_pages = 0

    def __len__(self) -> int:
        return len(self._store)

    @property
    def bytes_resident(self) -> int:
        return len(self._store) * self.page_bytes

    def has(self, key: int) -> bool:
        """Pure membership probe — no LRU touch (blocked admissions must
        stay side-effect free)."""
        return key in self._store

    def get(self, key: int) -> _HostPage:
        entry = self._store[key]
        self._store.move_to_end(key)
        return entry

    def touch(self, key: int) -> None:
        if key in self._store:
            self._store.move_to_end(key)

    def put(self, entry: _HostPage) -> int:
        """Insert (or LRU-touch) a committed page; evicts LRU entries
        past capacity. Returns the number of host pages evicted."""
        if entry.key in self._store:
            self._store.move_to_end(entry.key)
            return 0
        self._store[entry.key] = entry
        dropped = 0
        while len(self._store) > self.capacity_pages:
            self._store.popitem(last=False)
            dropped += 1
        self.evicted_pages += dropped
        return dropped

    def drop_all(self) -> None:
        self._store.clear()


class KVConnector:
    """Bridge between one engine's page pool and its host tier.

    The engine supplies the two jitted transfer islands:

    * ``read_fn(idx)``  — (bucket,) int32 global page ids (-1 pad) ->
      pool-shaped tree, leaves (n_per, bucket, ps, Hkv, hd), replicated.
    * ``write_fn(idx, data)`` — scatter the same shape back into the
      pools (the engine donates and swaps its pool arrays inside).

    Global page id = ``shard * pages_per_shard + local_page`` — the same
    linearisation as the SP shard order, so one integer round-trips
    through the host tier and lands on the owning shard.
    """

    def __init__(self, *, read_fn: Callable, write_fn: Callable,
                 bucket: int, page_size: int, pages_per_shard: int,
                 page_bytes: int, capacity_bytes: int,
                 spill_fn: Optional[Callable[[int], bool]] = None,
                 registry: Optional[obs.Registry] = None,
                 labels: Optional[Dict[str, str]] = None):
        self.read_fn = read_fn
        self.write_fn = write_fn
        self.bucket = bucket
        self.page_size = page_size
        self.pages_per_shard = pages_per_shard
        self.page_bytes = page_bytes
        self.tier = HostTier(capacity_bytes=capacity_bytes,
                             page_bytes=page_bytes)
        # spill_fn(chain_tokens) -> True when the transfer round-trip beats
        # recompute (plan.cost.spill_decision); consulted only under
        # capacity pressure — free host capacity always admits.
        self.spill_fn = spill_fn
        self._staging: Dict[int, List[_Staged]] = {}     # per source shard
        self._staged_keys: set = set()
        self.registry = registry if registry is not None else obs.Registry()
        self.labels = dict(labels or {})
        r = self.registry
        self._pages = r.counter(
            "kv_transfer_pages_total",
            "KV pages moved over the host link, by op "
            "(spill/reload/handoff_out/handoff_in)")
        self._bytes = r.counter(
            "kv_transfer_bytes_total", "KV bytes moved over the host link")
        self._lat = r.histogram(
            "kv_transfer_seconds",
            "Host-observed transfer latency (dispatch -> commit for "
            "spills; host assembly + dispatch for reloads)",
            buckets=TRANSFER_BUCKETS)
        self._skipped = r.counter(
            "host_tier_spill_skipped_total",
            "Spills refused by the cost model under capacity pressure")
        self._host_evict = r.counter(
            "host_tier_evicted_pages_total", "Host-tier LRU evictions")
        self._hit_tok = r.counter(
            "host_tier_hit_tokens_total",
            "Prompt tokens served from the host tier")
        self._lookup_tok = r.counter(
            "host_tier_lookup_tokens_total",
            "Prompt tokens probed against the host tier (past the "
            "device-trie match)")
        self._g_pages = r.gauge("host_tier_pages",
                                "Committed pages resident in the host tier")
        self._g_bytes = r.gauge("host_tier_bytes",
                                "Committed bytes resident in the host tier")
        self._g_hit = r.gauge("host_tier_hit_rate",
                              "host hit tokens / host lookup tokens")

    # ---- helpers --------------------------------------------------------
    def global_id(self, page: Tuple[int, int]) -> int:
        shard, local = page
        return shard * self.pages_per_shard + local

    def _count(self, op: str, pages: int, seconds: float) -> None:
        self._pages.inc(pages, op=op, **self.labels)
        self._bytes.inc(pages * self.page_bytes, op=op, **self.labels)
        self._lat.observe(seconds, op=op, **self.labels)

    @property
    def enabled(self) -> bool:
        return self.tier.capacity_pages > 0

    # ---- spill (device -> staging -> host) ------------------------------
    def spill(self, *, key: int, page: Tuple[int, int],
              chain_tokens: int) -> bool:
        """Stage an evicted page for the host tier. Called by
        ``PrefixCache.evict`` *before* the pool reference drops: the read
        is dispatched here, so the page value is captured in program
        order even though the page may be reallocated within the same
        admission. Returns True when a copy was staged."""
        if not self.enabled:
            return False
        if self.tier.has(key):
            self.tier.touch(key)                 # dedupe: already resident
            return False
        if key in self._staged_keys:
            return False
        occupied = len(self.tier) + len(self._staged_keys)
        if occupied >= self.tier.capacity_pages and self.spill_fn is not None \
                and not self.spill_fn(chain_tokens):
            self._skipped.inc(1, **self.labels)
            return False
        import jax

        idx = np.full((self.bucket,), -1, np.int32)
        idx[0] = self.global_id(page)
        out = self.read_fn(idx)
        data = jax.tree.map(lambda v: v[:, 0], out)
        self._staging.setdefault(page[0], []).append(
            _Staged(key=key, chain_tokens=chain_tokens, data=data,
                    t0=time.perf_counter()))
        self._staged_keys.add(key)
        return True

    def flush(self) -> int:
        """Commit every staged spill: block on the d2h copies, move the
        pages into the host store, and only then make them hittable.
        Called once per engine step — a crash or reset mid-flight loses
        staged pages, never corrupts committed ones."""
        import jax

        committed = 0
        for shard in sorted(self._staging):
            for entry in self._staging[shard]:
                data = jax.tree.map(np.asarray, entry.data)   # blocks on d2h
                self._count("spill", 1, time.perf_counter() - entry.t0)
                dropped = self.tier.put(_HostPage(
                    key=entry.key, chain_tokens=entry.chain_tokens,
                    data=data))
                if dropped:
                    self._host_evict.inc(dropped, **self.labels)
                committed += 1
        self._staging.clear()
        self._staged_keys.clear()
        self._update_gauges()
        return committed

    # ---- lookup / reload (host -> device) -------------------------------
    def has(self, key: int) -> bool:
        """Committed-only membership (staged in-flight spills are not
        hittable). Pure — safe on blocked admissions."""
        return self.tier.has(key)

    def note_probe(self, lookup_blocks: int, hit_blocks: int) -> None:
        """Hit-rate accounting, called once per *successful* admission
        (blocked admissions leave no trace)."""
        self._lookup_tok.inc(lookup_blocks * self.page_size, **self.labels)
        self._hit_tok.inc(hit_blocks * self.page_size, **self.labels)
        self._update_gauges()

    def reload(self, items: Sequence[Tuple[int, Tuple[int, int]]]) -> None:
        """Write committed host pages into freshly-allocated pool pages.

        items: (chain hash, (shard, local page)) per block, in block
        order. The entries stay resident in the tier (LRU-touched): other
        arrivals of the same chain may need them again after the fresh
        copies are themselves evicted.
        """
        if not items:
            return
        import jax

        for lo in range(0, len(items), self.bucket):
            batch = items[lo:lo + self.bucket]
            t0 = time.perf_counter()
            entries = []
            for key, page in batch:
                if not self.tier.has(key):
                    raise RuntimeError(
                        f"host-tier reload of missing chain hash {key:#x} "
                        "(evicted between admission and reload?)")
                entries.append(self.tier.get(key))
            idx = np.full((self.bucket,), -1, np.int32)
            for j, (_, page) in enumerate(batch):
                idx[j] = self.global_id(page)
            pad = self.bucket - len(batch)

            def stack(*leaves):
                arr = np.stack(leaves, axis=1)
                if pad:
                    z = np.zeros((arr.shape[0], pad) + arr.shape[2:],
                                 arr.dtype)
                    arr = np.concatenate([arr, z], axis=1)
                return arr

            data = jax.tree.map(stack, *[e.data for e in entries])
            self.write_fn(idx, data)
            self._count("reload", len(batch), time.perf_counter() - t0)
        self._update_gauges()

    # ---- prefill -> decode handoff --------------------------------------
    def export(self, pages: Sequence[Tuple[int, int]]):
        """Read whole pages to host (synchronous) for a cross-replica
        handoff. Returns a list of pool-shaped page trees, leaves
        (n_per, ps, Hkv, hd), in the given block order. The pages are not
        inserted into this tier — they belong to the receiving replica."""
        import jax

        out: List[object] = []
        for lo in range(0, len(pages), self.bucket):
            batch = pages[lo:lo + self.bucket]
            t0 = time.perf_counter()
            idx = np.full((self.bucket,), -1, np.int32)
            for j, page in enumerate(batch):
                idx[j] = self.global_id(page)
            dev = self.read_fn(idx)
            host = jax.tree.map(np.asarray, dev)          # blocks on d2h
            self._count("handoff_out", len(batch),
                        time.perf_counter() - t0)
            for j in range(len(batch)):
                out.append(jax.tree.map(lambda v: v[:, j], host))
        return out

    def inject(self, pages: Sequence[Tuple[int, int]], blocks) -> None:
        """Write exported page trees (from a peer connector's ``export``)
        into this engine's pool pages, block order matching ``pages``."""
        assert len(pages) == len(blocks)
        import jax

        for lo in range(0, len(pages), self.bucket):
            bp = pages[lo:lo + self.bucket]
            bb = blocks[lo:lo + self.bucket]
            t0 = time.perf_counter()
            idx = np.full((self.bucket,), -1, np.int32)
            for j, page in enumerate(bp):
                idx[j] = self.global_id(page)
            pad = self.bucket - len(bp)

            def stack(*leaves):
                arr = np.stack(leaves, axis=1)
                if pad:
                    z = np.zeros((arr.shape[0], pad) + arr.shape[2:],
                                 arr.dtype)
                    arr = np.concatenate([arr, z], axis=1)
                return arr

            data = jax.tree.map(stack, *bb)
            self.write_fn(idx, data)
            self._count("handoff_in", len(bp), time.perf_counter() - t0)

    # ---- lifecycle / stats ----------------------------------------------
    def reset(self) -> None:
        """Engine reset: drop committed and staged pages, zero the
        tier-level series (transfer counters follow the benchmark-phase
        reset convention of ``EngineMetrics``)."""
        self.tier.drop_all()
        self.tier.evicted_pages = 0
        self._staging.clear()
        self._staged_keys.clear()
        for op in ("spill", "reload", "handoff_out", "handoff_in"):
            self._pages.set(0, op=op, **self.labels)
            self._bytes.set(0, op=op, **self.labels)
            self._lat.reset(op=op, **self.labels)
        for c in (self._skipped, self._host_evict, self._hit_tok,
                  self._lookup_tok):
            c.set(0, **self.labels)
        self._update_gauges()

    def _update_gauges(self) -> None:
        self._g_pages.set(len(self.tier), **self.labels)
        self._g_bytes.set(self.tier.bytes_resident, **self.labels)
        lookup = self._lookup_tok.value(**self.labels)
        hit = self._hit_tok.value(**self.labels)
        self._g_hit.set(hit / lookup if lookup else 0.0, **self.labels)

    @property
    def hit_rate(self) -> float:
        lookup = self._lookup_tok.value(**self.labels)
        return (self._hit_tok.value(**self.labels) / lookup) if lookup \
            else 0.0

    def stats(self) -> Dict[str, float]:
        v = self.labels
        return {
            "capacity_pages": self.tier.capacity_pages,
            "resident_pages": len(self.tier),
            "resident_bytes": self.tier.bytes_resident,
            "staged_pages": len(self._staged_keys),
            "spill_pages": int(self._pages.value(op="spill", **v)),
            "spill_bytes": int(self._bytes.value(op="spill", **v)),
            "reload_pages": int(self._pages.value(op="reload", **v)),
            "reload_bytes": int(self._bytes.value(op="reload", **v)),
            "handoff_out_pages": int(
                self._pages.value(op="handoff_out", **v)),
            "handoff_in_pages": int(self._pages.value(op="handoff_in", **v)),
            "spills_skipped": int(self._skipped.value(**v)),
            "host_evicted_pages": int(self._host_evict.value(**v)),
            "hit_tokens": int(self._hit_tok.value(**v)),
            "lookup_tokens": int(self._lookup_tok.value(**v)),
            "hit_rate": self.hit_rate,
        }
