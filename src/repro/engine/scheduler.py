"""Continuous-batching scheduler: FIFO admission, slot reuse, paged
allocation, eviction on completion (JetStream-style driver state, adapted
to the round-robin SP page layout of ``engine.paged_cache``).

All state here is host-side numpy/python; the device sees only the page
*table* and per-slot scalars the engine assembles each step.

Policy
------
* **FIFO admission with head-of-line blocking**: requests are admitted in
  arrival order; if the head request does not fit (no free slot, or a shard
  lacks free pages) nothing behind it is admitted. Simple and starvation-free.
* **Worst-case reservation**: a request's pages for ``prompt_len +
  max_new_tokens`` positions are allocated at admission, so decode can never
  stall mid-generation. (Lazy growth + preemption à la vLLM is a possible
  refinement; the page-table plumbing already supports it.)
* **Round-robin block placement**: logical block ``b`` goes to SP shard
  ``b % P_sp`` — per-shard load for any single sequence is balanced to
  within one page, keeping per-device decode compute flat in ``P_sp``.
* **Ref-counted pages / prefix reuse**: every page lifecycle event goes
  through ``paged_cache.PagePool`` (never a raw free-list append). With a
  ``repro.gateway.prefix_cache.PrefixCache`` attached, admission matches
  the request's full prompt blocks against the block-hash trie, *shares*
  the hit pages (incref, no copy), and reserves fresh pages only for the
  uncached suffix — ``SlotState.cached_len`` tells the engine how many
  leading prompt tokens to skip at prefill.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.engine.paged_cache import PagePool


@dataclasses.dataclass
class Request:
    """One serving request (sampling follows ``engine.sampling``)."""

    uid: str
    tokens: List[int]                  # prompt token ids
    max_new_tokens: int
    temperature: float = 0.0           # <= 0 -> greedy
    top_k: int = 0                     # 0 disables
    top_p: float = 1.0                 # 1.0 disables
    seed: int = 0
    handoff: bool = False              # prefill-role request: stop after the
    #                                    first token and keep the prompt KV
    #                                    live until the gateway exports it to
    #                                    a decode replica
    priority: str = "batch"            # frontend priority class name; the
    #                                    engine itself is priority-blind

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Typed admission failure.

    ``reason`` is a stable machine-readable slug (one per failure mode so
    the HTTP layer can map it to a status code), ``detail`` the human
    string, and ``retry_after_steps`` an engine-step hint for when retrying
    could succeed — ``None`` means the request can never be admitted as-is
    (a client error, not back-pressure).
    """

    reason: str
    detail: str = ""
    retry_after_steps: Optional[int] = None

    @property
    def retryable(self) -> bool:
        return self.retry_after_steps is not None


@dataclasses.dataclass
class SlotState:
    req: Request
    slot: int
    arrived_step: int
    cache_len: int = 0                 # filled KV positions
    cached_len: int = 0                # leading prompt tokens from the prefix
    #                                    cache (multiple of page_size); the
    #                                    engine prefills only the suffix
    prefill_pos: int = 0               # prompt tokens whose KV has landed in
    #                                    pool pages (chunked prefill cursor;
    #                                    starts at cached_len, reaches
    #                                    prompt_len when prefill completes)
    host_len: int = 0                  # of cached_len, tokens whose blocks
    #                                    are host-tier hits: their KV must be
    #                                    reloaded into the fresh pages listed
    #                                    in pending_reload before any forward
    out: List[int] = dataclasses.field(default_factory=list)
    pages: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    hashes: List[int] = dataclasses.field(default_factory=list)
    # (chain hash, (shard, local page)) per host-hit block, block order
    pending_reload: List[Tuple[int, Tuple[int, int]]] = \
        dataclasses.field(default_factory=list)
    first_token_step: Optional[int] = None
    done_step: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.max_new_tokens


def bucket_pow2(n: int, lo: int = 1) -> int:
    """Smallest lo * 2^i >= n (length-bucketed compilation)."""
    b = lo
    while b < n:
        b *= 2
    return b


class Scheduler:
    def __init__(self, *, max_slots: int, page_size: int, sp: int,
                 pages_per_shard: int, max_len: int, prefix_cache=None):
        if max_len % page_size:
            max_len = (max_len // page_size + 1) * page_size
        self.max_slots = max_slots
        self.page_size = page_size
        self.sp = sp
        self.pages_per_shard = pages_per_shard
        self.max_len = max_len
        self.max_blocks = math.ceil(max_len / page_size)
        self.table_width = math.ceil(self.max_blocks / sp)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[SlotState]] = [None] * max_slots
        self.pool = PagePool(sp, pages_per_shard)
        # optional repro.gateway.prefix_cache.PrefixCache sharing this pool
        self.prefix_cache = prefix_cache
        # optional repro.engine.kv_connector.KVConnector: admission probes
        # its committed host tier for blocks past the device-trie match
        self.connector = None
        # disaggregated handoff inbox: (req, first token, exported KV
        # blocks) injected by the gateway, admitted like prefills but
        # skipping the forward entirely
        self.prefilled: Deque[Tuple[Request, int, list]] = collections.deque()
        self.table = np.full((max_slots, sp, self.table_width), -1, np.int32)
        self.finished: Dict[str, SlotState] = {}

    # ---- queue ----------------------------------------------------------
    def validate(self, req: Request) -> Optional[Rejection]:
        """Read-only admission probe: the :class:`Rejection` this request
        would draw, or ``None`` if it is serveable. All four reasons are
        permanent (``retry_after_steps=None``): they depend only on the
        request shape and the engine geometry, never on load."""
        if req.prompt_len < 1:
            return Rejection("empty_prompt", f"{req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            return Rejection(
                "bad_budget", f"{req.uid}: max_new_tokens must be >= 1")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            return Rejection(
                "too_long",
                f"{req.uid}: prompt {req.prompt_len} + budget "
                f"{req.max_new_tokens} exceeds engine max_len {self.max_len}")
        worst = max(self._per_shard_need(self._blocks_for(req)))
        if worst > self.pages_per_shard:
            return Rejection(
                "pool_too_small",
                f"{req.uid}: needs {worst} pages on a shard but the pool "
                f"holds {self.pages_per_shard}/shard — raise pages_per_shard "
                f"or shrink the request")
        return None

    def enqueue(self, req: Request) -> None:
        rej = self.validate(req)
        if rej is not None:
            raise ValueError(rej.detail)
        self.queue.append(req)

    # ---- paging ---------------------------------------------------------
    def _blocks_for(self, req: Request) -> int:
        return math.ceil((req.prompt_len + req.max_new_tokens)
                         / self.page_size)

    def _per_shard_need(self, nb: int) -> List[int]:
        """Pages shard s must supply for blocks 0..nb-1 (round-robin)."""
        return [nb // self.sp + (1 if s < nb % self.sp else 0)
                for s in range(self.sp)]

    def pages_in_use(self) -> int:
        return self.pool.pages_in_use()

    def pages_total(self) -> int:
        return self.pool.pages_total()

    # ---- admission / eviction ------------------------------------------
    def _alloc_evicting(self, shard: int) -> int:
        """Pop a free page on ``shard``, evicting cache-only pages if dry.
        Only called after :meth:`admit`'s feasibility check, so a dry pool
        here is a bookkeeping bug, not back-pressure."""
        if self.pool.available(shard) == 0 and self.prefix_cache is not None:
            self.prefix_cache.evict(shard, 1)
        if self.pool.available(shard) == 0:
            raise RuntimeError(
                f"shard {shard} dry after a feasible admission check")
        return self.pool.alloc(shard)

    def admit(self, step: int, limit: Optional[int] = None
              ) -> List[SlotState]:
        """FIFO-admit queued requests into free slots while pages last.

        With a prefix cache attached, the head request's full prompt blocks
        are matched first: hit pages are shared (incref — the cached KV is
        reused in place) and only the uncached suffix allocates fresh
        pages, evicting least-recently-used cache-only pages under
        pressure. Feasibility (free + evictable pages per shard) is checked
        *before* anything destructive: a head request that cannot get its
        suffix pages blocks without evicting a single cached block, without
        touching LRU stamps, and without skewing hit-rate stats — the probe
        is read-only until admission is certain.

        ``limit`` caps the admissions per call: the engine admits one at a
        time so a burst of shared-prefix arrivals hits the blocks the
        previous admission's prefill registered moments earlier.
        """
        admitted = []
        while self.queue and (limit is None or len(admitted) < limit):
            free_slot = next(
                (i for i, s in enumerate(self.slots) if s is None), None)
            if free_slot is None:
                break
            req = self.queue[0]
            nb = self._blocks_for(req)
            hashes: List[int] = []
            matched: List[Tuple[int, int]] = []
            host_hits: List[int] = []
            usable = 0
            if self.prefix_cache is not None:
                # all full prompt blocks (register_prefix inserts them)...
                hashes = self.prefix_cache.hashes(req.tokens)
                # ...but match at most (prompt_len - 1) // ps of them:
                # the next-token hidden state is not cached, so a fully-
                # cached prompt still forwards its final token through
                # the suffix prefill
                usable = (req.prompt_len - 1) // self.page_size
                matched = self.prefix_cache.match(hashes[:usable])
                if self.connector is not None and self.connector.enabled:
                    # host-tier hits extend the cached prefix past the
                    # device match — cheap (no recompute) but not free:
                    # they still need fresh pages, so they stay in `need`
                    # and the feasibility check below counts them like
                    # any uncached block. `has` is pure: a blocked
                    # admission leaves no trace in either tier.
                    b = len(matched)
                    while b < usable and self.connector.has(hashes[b]):
                        host_hits.append(hashes[b])
                        b += 1
            n_hits = len(matched)
            need = [0] * self.sp
            for b in range(n_hits, nb):
                need[b % self.sp] += 1
            # the hit pages are about to gain a live ref, so they must not
            # count as evictable capacity (exclude=matched)
            evictable = (self.prefix_cache.evictable_counts(
                self.sp, exclude=matched)
                if self.prefix_cache is not None else [0] * self.sp)
            if any(self.pool.available(s) + evictable[s] < need[s]
                   for s in range(self.sp)):
                break                                       # head-of-line
            hits: List[Tuple[int, int]] = []
            if self.prefix_cache is not None:
                hits = self.prefix_cache.acquire(
                    hashes[:usable])                        # increfs+stats
                assert hits == matched
            fresh = [(b % self.sp, self._alloc_evicting(b % self.sp))
                     for b in range(n_hits, nb)]
            self.queue.popleft()
            cached = (n_hits + len(host_hits)) * self.page_size
            st = SlotState(req=req, slot=free_slot, arrived_step=step,
                           cached_len=cached, prefill_pos=cached,
                           host_len=len(host_hits) * self.page_size,
                           hashes=hashes)
            # host-hit block b maps to fresh[b - n_hits]: the engine
            # reloads its KV there before the suffix prefill runs
            st.pending_reload = [(h, fresh[j])
                                 for j, h in enumerate(host_hits)]
            if self.connector is not None and self.connector.enabled \
                    and usable > n_hits:
                self.connector.note_probe(usable - n_hits, len(host_hits))
            st.pages = hits + fresh
            for b, (shard, page) in enumerate(st.pages):
                self.table[free_slot, shard, b // self.sp] = page
            self.slots[free_slot] = st
            admitted.append(st)
        return admitted

    # ---- disaggregated handoff (decode-role replicas) -------------------
    def enqueue_prefilled(self, req: Request, first_token: int,
                          blocks: list) -> None:
        """Queue a request whose prompt KV was prefilled on another
        replica: ``blocks`` are the exported page trees (one per block of
        ``ceil(prompt_len / page_size)``), ``first_token`` the token the
        prefill replica already sampled and emitted."""
        if req.prompt_len < 1:
            raise ValueError(f"{req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"{req.uid}: max_new_tokens must be >= 1")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"{req.uid}: prompt {req.prompt_len} + budget "
                f"{req.max_new_tokens} exceeds engine max_len {self.max_len}")
        nb_kv = math.ceil(req.prompt_len / self.page_size)
        if len(blocks) != nb_kv:
            raise ValueError(
                f"{req.uid}: handoff carries {len(blocks)} KV blocks, "
                f"prompt needs {nb_kv}")
        worst = max(self._per_shard_need(self._blocks_for(req)))
        if worst > self.pages_per_shard:
            raise ValueError(
                f"{req.uid}: needs {worst} pages on a shard but the pool "
                f"holds {self.pages_per_shard}/shard")
        self.prefilled.append((req, first_token, blocks))

    def admit_prefilled(self, step: int, limit: Optional[int] = None
                        ) -> List[Tuple[SlotState, int, list]]:
        """FIFO-admit handed-off requests into free slots. Every block
        allocates fresh pages (an injected prompt never shares the trie —
        its KV arrives from outside the pool), with the same read-only
        feasibility check as :meth:`admit`. The caller (the engine) must
        inject the returned blocks into the slot's pages before the next
        decode step."""
        out: List[Tuple[SlotState, int, list]] = []
        while self.prefilled and (limit is None or len(out) < limit):
            free_slot = next(
                (i for i, s in enumerate(self.slots) if s is None), None)
            if free_slot is None:
                break
            req, tok, blocks = self.prefilled[0]
            nb = self._blocks_for(req)
            need = self._per_shard_need(nb)
            evictable = (self.prefix_cache.evictable_counts(self.sp)
                         if self.prefix_cache is not None else [0] * self.sp)
            if any(self.pool.available(s) + evictable[s] < need[s]
                   for s in range(self.sp)):
                break                                       # head-of-line
            fresh = [(b % self.sp, self._alloc_evicting(b % self.sp))
                     for b in range(nb)]
            self.prefilled.popleft()
            st = SlotState(req=req, slot=free_slot, arrived_step=step)
            st.pages = fresh
            for b, (shard, page) in enumerate(st.pages):
                self.table[free_slot, shard, b // self.sp] = page
            self.slots[free_slot] = st
            out.append((st, tok, blocks))
        return out

    def register_prefix(self, st: SlotState) -> None:
        """Offer a freshly prefilled request's full prompt blocks to the
        prefix cache (the engine calls this right after the prefill+insert
        lands, when the pages hold valid KV). No-op without a cache."""
        if self.prefix_cache is None:
            return
        full = st.req.prompt_len // self.page_size
        self.prefix_cache.insert(st.hashes[:full], st.pages[:full])

    def finish(self, slot: int, step: int) -> SlotState:
        st = self.slots[slot]
        assert st is not None
        for shard, page in st.pages:
            self.pool.decref(shard, page)   # shared pages may stay cached
        st.pages = []
        st.done_step = step
        self.table[slot] = -1
        self.slots[slot] = None
        self.finished[st.req.uid] = st
        return st

    # ---- decode batch shape --------------------------------------------
    def active(self) -> List[SlotState]:
        return [s for s in self.slots if s is not None]

    def decode_width(self) -> int:
        """Bucketed per-shard table width for the current decode batch: the
        write at position cache_len needs blocks 0..cache_len//ps, i.e.
        ceil((cache_len//ps + 1) / sp) local blocks."""
        need = 1
        for st in self.active():
            blocks = st.cache_len // self.page_size + 1
            need = max(need, math.ceil(blocks / self.sp))
        return min(bucket_pow2(need), self.table_width)
