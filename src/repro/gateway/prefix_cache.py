"""Block-hash prefix cache: copy-on-write KV reuse over the paged pool.

The cache is a trie over *full prompt pages*. A node is one logical block
of ``page_size`` tokens, keyed by the chain hash

    h_0 = H(seed, tokens[0:ps])          h_b = H(h_{b-1}, tokens[b*ps:(b+1)*ps])

so equal hashes mean equal *prefixes*, not just equal blocks (the vLLM
automatic-prefix-caching construction). Each node pins one physical page
``(shard, local_page)`` in the engine's SP-sharded pool — block ``b`` lives
on shard ``b % P_sp``, so a node at depth ``b`` always names a page on that
shard and a trie hit reuses the exact round-robin layout the decode step
expects.

Reference counting (``paged_cache.PagePool``) carries the copy-on-write
semantics: the cache holds one reference per retained node, every live
request sharing the block holds another, and a page is recycled only when
the last holder lets go. Shared pages are immutable by construction —
decode writes land strictly past the full-prompt prefix — so "copy" never
actually happens; what COW buys here is that **eviction can never corrupt a
live request**: evicting a node only drops the cache's reference, and the
page body survives until the last sharing request finishes
(``dist_checks.check_gateway_prefix_cow`` proves this on the C=2 mesh).

Eviction is leaf-first and cost-aware: only nodes with no children and no
live sharer (refcount == 1, the cache's own hold) are candidates, so an
interior node is never dropped while a descendant could still be matched
through it. Candidates are ranked by the recompute cost a future miss on
their chain would pay (``cost_fn`` over tokens-in-chain — the engine
injects `plan.cost.prefill_step_cost`; the default is the token count
itself, the same ordering for any monotone cost), with the LRU stamp as
the tie-break, so an expensive deep chain outlives a cheap shallow one
that happens to be more recent. When a ``connector``
(`engine.kv_connector.KVConnector`) is attached, every dropped node's
page is offered to the pinned-host tier first — eviction then demotes KV
instead of destroying it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_HASH_SEED = 0x51ab5eed


def block_hashes(tokens: Sequence[int], page_size: int) -> List[int]:
    """Chain hashes of the *full* blocks of ``tokens`` (partial tail block
    excluded — its page is mutable until decode passes it, so it is never
    shared)."""
    out: List[int] = []
    prev = _HASH_SEED
    for b in range(len(tokens) // page_size):
        prev = hash((prev, tuple(tokens[b * page_size:(b + 1) * page_size])))
        out.append(prev)
    return out


@dataclasses.dataclass
class _Node:
    key: int                            # chain hash (position-qualified)
    page: Tuple[int, int]               # (shard, local page id) in the pool
    parent: Optional["_Node"]
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    stamp: int = 0                      # LRU clock tick of the last touch


class PrefixCache:
    """Trie of cached full prompt blocks over one engine's page pool."""

    def __init__(self, pool, *, page_size: int, sp: int,
                 cost_fn: Optional[Callable[[int], float]] = None,
                 connector=None):
        self.pool = pool                # paged_cache.PagePool (shared with
        #                                 the scheduler — same refcounts)
        self.page_size = page_size
        self.sp = sp
        # cost_fn(chain_tokens) -> relative recompute cost of losing a node
        # at that chain depth; tokens themselves are the cost-aware default
        self.cost_fn = cost_fn or float
        self.connector = connector      # engine.kv_connector.KVConnector
        self.children: Dict[int, _Node] = {}     # root level
        self._clock = 0
        # metrics (token-denominated where it matters for hit rate)
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.evicted_pages = 0
        self.inserted_pages = 0

    def hashes(self, tokens: Sequence[int]) -> List[int]:
        return block_hashes(tokens, self.page_size)

    # ---- lookup ---------------------------------------------------------
    def _walk(self, hashes: Sequence[int]) -> List[_Node]:
        nodes: List[_Node] = []
        level = self.children
        for h in hashes:
            node = level.get(h)
            if node is None:
                break
            nodes.append(node)
            level = node.children
        return nodes

    def match_len(self, hashes: Sequence[int]) -> int:
        """Longest cached prefix, in blocks. Read-only (router probes)."""
        return len(self._walk(hashes))

    def match(self, hashes: Sequence[int]) -> List[Tuple[int, int]]:
        """The longest cached prefix's pages, in block order. Read-only —
        no refcounts, stats or LRU stamps move (the scheduler probes with
        this before it knows whether admission is feasible)."""
        return [node.page for node in self._walk(hashes)]

    def evictable_counts(self, sp: int,
                         exclude: Sequence[Tuple[int, int]] = ()
                         ) -> List[int]:
        """Per-shard count of pages eviction could free right now:
        cache-only holds (refcount 1 — a live sharer implies every
        ancestor is live too, so a refcount-1 node's whole subtree is
        cache-only and reachable leaf-first). ``exclude`` masks pages
        about to gain a live ref (the admission's own prefix hits)."""
        out = [0] * sp
        ex = set(tuple(p) for p in exclude)
        for node in self._iter_nodes():
            if self.pool.refs[node.page] == 1 and node.page not in ex:
                out[node.page[0]] += 1
        return out

    def acquire(self, hashes: Sequence[int]) -> List[Tuple[int, int]]:
        """Match the longest cached prefix and take one reference per hit
        page for the admitting request. Returns the hit pages in block
        order; the caller owns the references (released via
        ``PagePool.decref`` when the request finishes or rolls back)."""
        nodes = self._walk(hashes)
        self._clock += 1
        for node in nodes:
            self.pool.incref(*node.page)
            node.stamp = self._clock
        self.hit_tokens += len(nodes) * self.page_size
        self.lookup_tokens += len(hashes) * self.page_size
        return [node.page for node in nodes]

    # ---- insert ---------------------------------------------------------
    def insert(self, hashes: Sequence[int],
               pages: Sequence[Tuple[int, int]]) -> int:
        """Retain a prefilled request's full prompt blocks.

        ``pages[b]`` must hold the valid KV of the block hashed by
        ``hashes[b]`` (the scheduler guarantees this: hit blocks come back
        in the same pages the trie already names, fresh blocks were just
        written by the prefill). Existing nodes are only LRU-touched; new
        nodes take one cache-hold reference on their page. Returns the
        number of newly retained pages.
        """
        assert len(hashes) == len(pages)
        self._clock += 1
        level = self.children
        parent: Optional[_Node] = None
        added = 0
        for h, page in zip(hashes, pages):
            node = level.get(h)
            if node is None:
                node = _Node(key=h, page=tuple(page), parent=parent)
                level[h] = node
                self.pool.incref(*page)          # the cache's own hold
                added += 1
            node.stamp = self._clock
            parent = node
            level = node.children
        self.inserted_pages += added
        return added

    # ---- eviction -------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            else:
                out.append(node)
        return out

    def _drop(self, node: _Node) -> None:
        level = node.parent.children if node.parent else self.children
        del level[node.key]
        self.pool.decref(*node.page)
        self.evicted_pages += 1

    def _chain_tokens(self, node: _Node) -> int:
        depth = 0
        cur: Optional[_Node] = node
        while cur is not None:
            depth += 1
            cur = cur.parent
        return depth * self.page_size

    def evict(self, shard: int, need: int) -> int:
        """Free up to ``need`` pages on ``shard`` by dropping leaf nodes
        nobody else references (refcount 1 == the cache's hold — a block
        shared with a live request is skipped: dropping it would not free
        a page, only forfeit future hits). Victims are the *cheapest to
        recompute* first (``cost_fn`` over tokens-in-chain, LRU stamp as
        tie-break). Blocks are round-robin over shards, so the page wanted
        on ``shard`` may sit mid-chain under leaves on *other* shards:
        when the target shard has no evictable leaf, the cheapest
        evictable leaf anywhere is dropped to unwind its chain toward one.
        With a connector attached the victim's page spills to the host
        tier before the device page is released. Returns pages freed on
        ``shard``."""
        freed = 0
        while freed < need:
            victims = [n for n in self._leaves()
                       if self.pool.refs[n.page] == 1]
            if not victims:
                break
            on_shard = [n for n in victims if n.page[0] == shard]
            victim = min(on_shard or victims,
                         key=lambda n: (self.cost_fn(self._chain_tokens(n)),
                                        n.stamp))
            if self.connector is not None:
                self.connector.spill(
                    key=victim.key, page=victim.page,
                    chain_tokens=self._chain_tokens(victim))
            self._drop(victim)
            if victim.page[0] == shard:
                freed += 1
        return freed

    def drop_all(self) -> None:
        """Release every cache hold (engine reset)."""
        while True:
            leaves = self._leaves()
            if not leaves:
                break
            for node in leaves:
                self._drop(node)
                self.evicted_pages -= 1          # reset, not pressure

    # ---- metrics --------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "hit_rate": self.hit_rate,
            "evicted_pages": self.evicted_pages,
            "inserted_pages": self.inserted_pages,
            "resident_pages": sum(1 for _ in self._iter_nodes()),
        }

    def _iter_nodes(self):
        stack = list(self.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node
