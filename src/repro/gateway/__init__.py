"""repro.gateway — prefix-cached, multi-replica serving gateway over
``repro.engine`` (see docs/SERVING.md, "The serving gateway").

Public surface:
  Gateway / build_gateway — N engine replicas on device submeshes, prefix-
                            aware + load-aware routing with session
                            affinity, per-request token streaming
  Router                  — the routing policy (probe replicas' tries,
                            break ties by outstanding tokens)
  PrefixCache             — block-hash trie over full prompt pages with
                            ref-counted, copy-on-write page reuse in the
                            SP-sharded paged pool; leaf-first LRU eviction
  block_hashes            — the chain hash over token pages
"""

from repro import compat as _compat  # noqa: F401  (jax shims)
from repro.gateway.gateway import Gateway, build_gateway, replica_meshes
from repro.gateway.prefix_cache import PrefixCache, block_hashes
from repro.gateway.router import Router

__all__ = [
    "Gateway", "build_gateway", "replica_meshes",
    "PrefixCache", "block_hashes", "Router",
]
