"""Request routing across engine replicas.

Three signals, in priority order:

* **session affinity** — a request carrying a ``session`` key goes to the
  replica that served that session before (its earlier turns' KV pages are
  in that replica's pool, so the prefix trie can hit them); the map is
  sticky until the caller resets the gateway.
* **prefix awareness** — otherwise each replica's trie is probed read-only
  (``PrefixCache.match_len``) with the request's block hashes, and the
  replica with the most cached prefix tokens wins: prefill work already
  paid anywhere should never be paid again somewhere else.
* **load** — ties (including the cold everyone-misses case) break to the
  replica with the fewest outstanding tokens (queued + remaining decode
  budget), then to the lowest replica index (deterministic routing — the
  serving benchmark replays workloads across cache-on/off phases and needs
  identical placement to compare tokens bit-for-bit).

Disaggregated gateways restrict new requests to the ``eligible`` replica
indices (the prefill/unified ones) — decode-role replicas only ever see
KV handed to them via ``Engine.add_prefilled``, never a raw prompt.

The ``engines`` need not be in-process ``Engine`` objects: anything with
an ``outstanding_tokens()`` method (e.g. ``repro.frontend``'s replica
clients, whose scheduler lives in another process) routes by that load
signal instead of a scheduler walk. **Liveness**: ``mark_dead(i)``
removes a replica from routing (a dead worker process must stop
receiving traffic instantly); its sticky sessions re-route on next use.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set


class Router:
    def __init__(self, engines: Sequence, *, prefix_aware: bool = True,
                 eligible: Optional[Sequence[int]] = None):
        self.engines = list(engines)
        self.prefix_aware = prefix_aware
        self.eligible = list(eligible) if eligible is not None \
            else list(range(len(self.engines)))
        self.affinity: Dict[str, int] = {}
        self.affinity_hits = 0
        self.routed: List[int] = [0] * len(self.engines)
        self.dead: Set[int] = set()

    # ---- liveness -------------------------------------------------------
    def mark_dead(self, i: int) -> None:
        """Stop routing to replica ``i`` (worker process died or is being
        drained). Sticky sessions pointing at it re-route on next use."""
        self.dead.add(i)
        self.affinity = {s: j for s, j in self.affinity.items() if j != i}

    def live_eligible(self) -> List[int]:
        return [i for i in self.eligible if i not in self.dead]

    def load(self, i: int) -> int:
        """Outstanding tokens on replica ``i`` (queued + admitted)."""
        eng = self.engines[i]
        fn = getattr(eng, "outstanding_tokens", None)
        if fn is not None:
            return fn()
        sched = eng.scheduler
        t = sum(r.prompt_len + r.max_new_tokens for r in sched.queue)
        t += sum(s.req.prompt_len + s.req.max_new_tokens - len(s.out)
                 for s in sched.active())
        return t

    def cached_tokens(self, i: int, req) -> int:
        cache = getattr(self.engines[i], "prefix_cache", None)
        if not self.prefix_aware or cache is None:
            return 0
        return cache.match_len(cache.hashes(req.tokens)) * cache.page_size

    def route(self, req, session: Optional[str] = None) -> int:
        live = self.live_eligible()
        if not live:
            raise RuntimeError("router: no live eligible replica")
        if session is not None and session in self.affinity:
            i = self.affinity[session]
            self.affinity_hits += 1
        else:
            i = min(live,
                    key=lambda j: (-self.cached_tokens(j, req),
                                   self.load(j), j))
            if session is not None:
                self.affinity[session] = i
        self.routed[i] += 1
        return i
