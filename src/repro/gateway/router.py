"""Request routing across engine replicas.

Three signals, in priority order:

* **session affinity** — a request carrying a ``session`` key goes to the
  replica that served that session before (its earlier turns' KV pages are
  in that replica's pool, so the prefix trie can hit them); the map is
  sticky until the caller resets the gateway.
* **prefix awareness** — otherwise each replica's trie is probed read-only
  (``PrefixCache.match_len``) with the request's block hashes, and the
  replica with the most cached prefix tokens wins: prefill work already
  paid anywhere should never be paid again somewhere else.
* **load** — ties (including the cold everyone-misses case) break to the
  replica with the fewest outstanding tokens (queued + remaining decode
  budget), then to the lowest replica index (deterministic routing — the
  serving benchmark replays workloads across cache-on/off phases and needs
  identical placement to compare tokens bit-for-bit).

Disaggregated gateways restrict new requests to the ``eligible`` replica
indices (the prefill/unified ones) — decode-role replicas only ever see
KV handed to them via ``Engine.add_prefilled``, never a raw prompt.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence


class Router:
    def __init__(self, engines: Sequence, *, prefix_aware: bool = True,
                 eligible: Optional[Sequence[int]] = None):
        self.engines = list(engines)
        self.prefix_aware = prefix_aware
        self.eligible = list(eligible) if eligible is not None \
            else list(range(len(self.engines)))
        self.affinity: Dict[str, int] = {}
        self.affinity_hits = 0
        self.routed: List[int] = [0] * len(self.engines)

    def load(self, i: int) -> int:
        """Outstanding tokens on replica ``i`` (queued + admitted)."""
        sched = self.engines[i].scheduler
        t = sum(r.prompt_len + r.max_new_tokens for r in sched.queue)
        t += sum(s.req.prompt_len + s.req.max_new_tokens - len(s.out)
                 for s in sched.active())
        return t

    def cached_tokens(self, i: int, req) -> int:
        cache = self.engines[i].prefix_cache
        if not self.prefix_aware or cache is None:
            return 0
        return cache.match_len(cache.hashes(req.tokens)) * cache.page_size

    def route(self, req, session: Optional[str] = None) -> int:
        if session is not None and session in self.affinity:
            i = self.affinity[session]
            self.affinity_hits += 1
        else:
            i = min(self.eligible,
                    key=lambda j: (-self.cached_tokens(j, req),
                                   self.load(j), j))
            if session is not None:
                self.affinity[session] = i
        self.routed[i] += 1
        return i
