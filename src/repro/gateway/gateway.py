"""The serving gateway: a multi-replica front end over ``repro.engine``.

One ``Gateway`` owns N ``Engine`` replicas, each on its own submesh slice
of the available devices (the replica's mesh shape — data x (C, R, C)
refinement — comes from one shared ``kind='decode'`` ``ExecutionPlan``
whose ``replicas``/``prefix_cache`` serving knobs this module consumes).
Requests enter through prefix-aware, load-aware routing with session
affinity (``gateway.router``), are served by the replicas' continuous
batching, and stream back per request: ``step()`` returns the (uid, token)
pairs emitted that tick and ``take(uid)`` drains a request's stream
incrementally, so callers can forward tokens while decode is still
running.

All replicas share one set of model parameters (initialised once, placed
per-replica by each engine's jits) and each runs its own prefix cache over
its own SP-sharded page pool — the router's job is to keep shared-prefix
traffic landing where its pages already are.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.engine import Engine, EngineConfig, Request
from repro.gateway.router import Router


def replica_meshes(plan, replicas: int):
    """One refined ``(data, sp_grp, sp_ring, sp_team)`` mesh per replica,
    over disjoint slices of the local device list. The plan's
    ``n_devices`` is the *per-replica* device count."""
    import jax
    from jax.sharding import Mesh

    from repro.dist.sharding import SP_AXES

    if plan.mesh_kind != "local":
        raise NotImplementedError(
            "multi-replica gateways currently build local (forced-host) "
            "meshes; production multi-host replicas are future work")
    devs = jax.devices()
    need = plan.n_devices * replicas
    if len(devs) < need:
        raise ValueError(
            f"gateway needs {need} devices for {replicas} replicas of "
            f"{plan.n_devices} but only {len(devs)} are available")
    out = []
    for i in range(replicas):
        grid = np.array(devs[i * plan.n_devices:(i + 1) * plan.n_devices])
        grid = grid.reshape(plan.data, plan.c, plan.r, plan.c)
        out.append(Mesh(grid, ("data",) + SP_AXES))
    return out


class Gateway:
    """add_request / step / take / collect driver over N engine replicas."""

    def __init__(self, model, plan, eng: EngineConfig = EngineConfig(),
                 params=None, registry: Optional[obs.Registry] = None,
                 tracer: Optional[obs.Tracer] = None):
        import jax

        self.plan = plan
        self.replicas = max(int(getattr(plan, "replicas", 1)), 1)
        # one shared registry; replicas write the same metric families
        # under distinguishing {replica=i} labels
        self.registry = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        if self.replicas == 1:
            meshes = [plan.build_mesh()]
        else:
            meshes = replica_meshes(plan, self.replicas)
        self.engines: List[Engine] = [
            Engine(model, plan, eng, params, mesh=m,
                   registry=self.registry, labels={"replica": str(i)},
                   tracer=self.tracer)
            for i, m in enumerate(meshes)]
        self.cfg = self.engines[0].cfg
        self.router = Router(self.engines,
                             prefix_aware=bool(plan.prefix_cache))
        self._owner: Dict[str, int] = {}
        self._streams: Dict[str, List[int]] = {}
        self._cursor: Dict[str, int] = {}
        self.wall_s = 0.0
        self.max_steps = eng.max_steps

    # ---- request lifecycle ---------------------------------------------
    def add_request(self, req: Request, session: Optional[str] = None,
                    replica: Optional[int] = None) -> int:
        """Route and enqueue; returns the replica index. ``replica`` pins
        the choice (the benchmark replays recorded placements so cache-on
        and cache-off phases compare the same per-replica workloads)."""
        with self.tracer.span("gateway/route", cat="gateway", uid=req.uid):
            i = self.router.route(req, session) if replica is None \
                else replica
        if replica is not None:
            self.router.routed[i] += 1
        self.registry.counter(
            "gateway_requests_routed_total",
            "Requests routed to each replica").inc(replica=str(i))
        self.engines[i].add_request(req)
        self._owner[req.uid] = i
        self._streams[req.uid] = []
        self._cursor[req.uid] = 0
        return i

    def step(self) -> List[Tuple[str, int]]:
        """One tick: step every replica with work; returns this tick's
        (uid, token) emissions (also appended to the per-request streams)."""
        t0 = time.monotonic()
        emitted: List[Tuple[str, int]] = []
        with self.tracer.span("gateway/step", cat="gateway"):
            for engine in self.engines:
                if not engine.idle():
                    emitted.extend(engine.step())
        for uid, tok in emitted:
            self._streams[uid].append(tok)
        self.wall_s += time.monotonic() - t0
        self.registry.gauge(
            "gateway_wall_seconds",
            "Host wall time spent inside gateway.step()").set(self.wall_s)
        return emitted

    def take(self, uid: str) -> List[int]:
        """Drain the tokens streamed for ``uid`` since the last take."""
        cur = self._cursor.get(uid, 0)
        out = self._streams.get(uid, [])[cur:]
        self._cursor[uid] = cur + len(out)
        return out

    def idle(self) -> bool:
        return all(e.idle() for e in self.engines)

    def run(self, max_steps: Optional[int] = None) -> Dict[str, List[int]]:
        limit = max_steps or self.max_steps
        n = 0
        while not self.idle():
            emitted = self.step()
            if not emitted and not any(
                    e.scheduler.active() for e in self.engines):
                # nothing decoding and nothing admissible: eviction was
                # already tried, so no future step can make progress
                raise RuntimeError(
                    "gateway stalled: queued requests cannot be admitted "
                    "(pool exhausted by live sequences?)")
            n += 1
            if n > limit:
                raise RuntimeError(f"gateway did not drain in {limit} steps")
        return self.collect()

    def collect(self) -> Dict[str, List[int]]:
        out: Dict[str, List[int]] = {}
        for engine in self.engines:
            out.update(engine.collect())
        return out

    def reset(self) -> None:
        """Drop requests, pools and prefix caches on every replica; keep
        compiled fns and the router's affinity map cleared."""
        for engine in self.engines:
            engine.reset()
        self.router = Router(self.engines,
                             prefix_aware=bool(self.plan.prefix_cache))
        self._owner.clear()
        self._streams.clear()
        self._cursor.clear()
        self.wall_s = 0.0

    # ---- metrics --------------------------------------------------------
    def compiles(self) -> Tuple[int, int]:
        """(prefill, decode) bucket-compile counters summed over replicas."""
        return (sum(e.metrics.prefill_compiles for e in self.engines),
                sum(e.metrics.decode_compiles for e in self.engines))

    def xla_compiles(self) -> Tuple[int, int]:
        pf = dc = 0
        for e in self.engines:
            a, b = e.xla_compiles()
            pf, dc = pf + a, dc + b
        return pf, dc

    def pallas_fallbacks(self) -> Dict[str, int]:
        """Trace-time pallas->ref fallback counts summed over the replica
        engines (each engine filters the dispatch layer's labeled counters
        by its own obs scope, so fallbacks traced by other engines or
        earlier tests in the process never leak in)."""
        out: Dict[str, int] = {}
        for e in self.engines:
            for k, v in e.pallas_fallbacks().items():
                out[k] = out.get(k, 0) + v
        return out

    def latency_quantiles(self) -> Dict[str, float]:
        """Gateway-wide p50/p95/p99 TTFT and inter-token gap: the replicas
        share one registry, so histogram quantiles with no label filter
        aggregate every replica's buckets."""
        out: Dict[str, float] = {}
        for short, metric in (("ttft", "serve_ttft_seconds"),
                              ("intertoken", "serve_intertoken_seconds")):
            h = self.registry.get(metric)
            for q in (0.5, 0.95, 0.99):
                out[f"{short}_p{int(q * 100)}_s"] = h.quantile(q)
            out[f"{short}_count"] = h.count()
        return out

    def metrics_dict(self) -> Dict[str, object]:
        per = [e.metrics.to_dict() for e in self.engines]
        tokens = sum(m["tokens_out"] for m in per)
        computed = sum(m["prefill_tokens_computed"] for m in per)
        cached = sum(m["prefill_tokens_cached"] for m in per)
        prompt = computed + cached
        return {
            "replicas": self.replicas,
            "tokens_out": tokens,
            "wall_s": self.wall_s,
            "tokens_per_s": tokens / self.wall_s if self.wall_s > 0 else 0.0,
            "prefill_tokens_computed": computed,
            "prefill_tokens_cached": cached,
            "prefix_hit_rate": cached / prompt if prompt else 0.0,
            "prefix_evictions": sum(m["prefix_evictions"] for m in per),
            "routed": list(self.router.routed),
            "affinity_hits": self.router.affinity_hits,
            "pallas_fallbacks": self.pallas_fallbacks(),
            "per_replica": per,
        }


def build_gateway(arch: str, *, smoke: bool = True, c: Optional[int] = 1,
                  data: int = 1, replicas: int = 1,
                  prefix_cache: bool = True,
                  eng: EngineConfig = EngineConfig(), params=None,
                  init_seed: int = 0, kernel: Optional[str] = None,
                  plan=None, registry: Optional[obs.Registry] = None,
                  tracer: Optional[obs.Tracer] = None) -> Gateway:
    """Convenience constructor mirroring ``engine.build_engine``: resolve a
    serve plan whose ``n_devices`` is the per-replica share of the local
    devices, then build the gateway on it."""
    import jax

    from repro.configs import registry as arch_registry
    from repro.models.factory import build_model
    from repro.plan import make_serve_plan

    cfg = arch_registry.get_smoke(arch) if smoke else arch_registry.get(arch)
    model = build_model(cfg)
    if plan is None:
        n_dev = len(jax.devices()) // max(replicas, 1)
        plan = make_serve_plan(
            cfg, arch=arch, n_devices=n_dev, data=data, c=c,
            decode_batch=eng.max_slots, page_size=eng.page_size,
            max_len=eng.max_len, mesh_kind="local", kernel_impl=kernel,
            replicas=replicas, prefix_cache=prefix_cache)
    if params is None:
        params = model.init(jax.random.PRNGKey(init_seed))
    return Gateway(model, plan, eng, params, registry=registry,
                   tracer=tracer)
