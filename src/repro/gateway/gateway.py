"""The serving gateway: a multi-replica front end over ``repro.engine``.

One ``Gateway`` owns N ``Engine`` replicas, each on its own submesh slice
of the available devices (the replica's mesh shape — data x (C, R, C)
refinement — comes from one shared ``kind='decode'`` ``ExecutionPlan``
whose ``replicas``/``prefix_cache`` serving knobs this module consumes).
Requests enter through prefix-aware, load-aware routing with session
affinity (``gateway.router``), are served by the replicas' continuous
batching, and stream back per request: ``step()`` returns the (uid, token)
pairs emitted that tick and ``take(uid)`` drains a request's stream
incrementally, so callers can forward tokens while decode is still
running.

All replicas share one set of model parameters (initialised once, placed
per-replica by each engine's jits) and each runs its own prefix cache over
its own SP-sharded page pool — the router's job is to keep shared-prefix
traffic landing where its pages already are.

**Disaggregated serving** (``plans=[...]``): instead of N clones of one
plan, the gateway can run one engine per *role plan*
(`plan.make_role_plans`) on disjoint submeshes — ``role='prefill'``
replicas take new requests, run the prompt through prefill, emit the
first token and stop; the gateway then exports the prompt KV through the
replica's `engine.kv_connector` (device → host → device on the smoke
path; an RDMA fabric would replace the middle hop), injects it into the
least-loaded ``role='decode'`` replica and lets decode continue there.
The streams stay bit-identical to a unified replica because the decode
engine resumes from the exact pages the prefill wrote and sampling is
keyed by (seed, position), not by which engine draws
(``dist_checks.check_gateway_disagg`` proves this against the unified
baseline).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro import obs
from repro.engine import Engine, EngineConfig, Rejection, Request
from repro.gateway.router import Router


def submeshes(plans):
    """One refined ``(data, sp_grp, sp_ring, sp_team)`` mesh per plan,
    over disjoint slices of the local device list (each plan's
    ``n_devices`` is that replica's device count)."""
    import jax
    from jax.sharding import Mesh

    from repro.dist.sharding import SP_AXES

    if any(p.mesh_kind != "local" for p in plans):
        raise NotImplementedError(
            "multi-replica gateways currently build local (forced-host) "
            "meshes; production multi-host replicas are future work")
    devs = jax.devices()
    need = sum(p.n_devices for p in plans)
    if len(devs) < need:
        raise ValueError(
            f"gateway needs {need} devices for {len(plans)} replicas of "
            f"{[p.n_devices for p in plans]} but only {len(devs)} are "
            f"available")
    out, off = [], 0
    for p in plans:
        grid = np.array(devs[off:off + p.n_devices])
        off += p.n_devices
        grid = grid.reshape(p.data, p.c, p.r, p.c)
        out.append(Mesh(grid, ("data",) + SP_AXES))
    return out


def replica_meshes(plan, replicas: int):
    """One mesh per replica of a single shared plan (homogeneous case)."""
    return submeshes([plan] * replicas)


class Gateway:
    """add_request / step / take / collect driver over N engine replicas."""

    def __init__(self, model, plan, eng: EngineConfig = EngineConfig(),
                 params=None, registry: Optional[obs.Registry] = None,
                 tracer: Optional[obs.Tracer] = None, plans=None):
        import jax

        if plans:
            self.plans = list(plans)
            plan = plan if plan is not None else self.plans[0]
            key = {(p.page_size, p.decode_batch, p.seq_len, p.kernel_impl,
                    p.arch) for p in self.plans}
            if len(key) != 1:
                raise ValueError(
                    "disaggregated role plans must agree on page_size/"
                    "decode_batch/seq_len/kernel (the KV handoff is only "
                    f"bit-exact between identical engines); got {key}")
        else:
            replicas = max(int(getattr(plan, "replicas", 1)), 1)
            if getattr(plan, "role", "unified") != "unified":
                raise ValueError(
                    "a single-plan gateway is role='unified'; build one "
                    "plan per role (plan.make_role_plans) and pass "
                    "plans=[...] to disaggregate")
            self.plans = [plan] * replicas
        self.plan = plan
        self.replicas = len(self.plans)
        self.roles = [getattr(p, "role", "unified") for p in self.plans]
        # prefill/unified replicas take new requests; handoffs land on
        # decode replicas (or unified ones when none are dedicated)
        self._entry = [i for i, r in enumerate(self.roles)
                       if r in ("prefill", "unified")]
        self._decode_targets = \
            [i for i, r in enumerate(self.roles) if r == "decode"] or \
            [i for i, r in enumerate(self.roles) if r == "unified"]
        if not self._entry:
            raise ValueError("no prefill or unified replica to admit "
                             "requests")
        if "prefill" in self.roles and not self._decode_targets:
            raise ValueError("prefill replicas need a decode (or unified) "
                             "replica to hand finished prompts to")
        # one shared registry; replicas write the same metric families
        # under distinguishing {replica=i} labels
        self.registry = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        if self.replicas == 1:
            meshes = [plan.build_mesh()]
        else:
            meshes = submeshes(self.plans)
        self.engines: List[Engine] = [
            Engine(model, p, eng, params, mesh=m,
                   registry=self.registry, labels={"replica": str(i)},
                   tracer=self.tracer)
            for i, (p, m) in enumerate(zip(self.plans, meshes))]
        self.cfg = self.engines[0].cfg
        self.router = Router(self.engines,
                             prefix_aware=bool(plan.prefix_cache),
                             eligible=self._entry)
        self._owner: Dict[str, int] = {}
        self._streams: Dict[str, List[int]] = {}
        self._cursor: Dict[str, int] = {}
        # disaggregation state: original request by uid while its 1-token
        # prefill twin runs, and uid -> decode replica after the handoff
        self._pending_handoff: Dict[str, Request] = {}
        self._handoff_dst: Dict[str, int] = {}
        self.handoffs = 0
        self.wall_s = 0.0
        self.max_steps = eng.max_steps
        self.draining = False

    # ---- request lifecycle ---------------------------------------------
    def add_request(self, req: Request, session: Optional[str] = None,
                    replica: Optional[int] = None) -> Union[int, Rejection]:
        """Route and enqueue; returns the replica index, or a typed
        :class:`Rejection` when admission fails (a draining gateway or an
        unserveable request — never a raise, so the HTTP layer can answer
        429/503 instead of 500). ``replica`` pins the choice (the
        benchmark replays recorded placements so cache-on and cache-off
        phases compare the same per-replica workloads)."""
        if self.draining:
            return Rejection("draining",
                             "gateway is draining: not accepting requests")
        if not self.router.live_eligible():
            return Rejection("no_live_replica",
                             "no live replica can admit requests",
                             retry_after_steps=1)
        with self.tracer.span("gateway/route", cat="gateway", uid=req.uid):
            i = self.router.route(req, session) if replica is None \
                else replica
        if replica is not None:
            self.router.routed[i] += 1
        if self.roles[i] == "prefill":
            # the prefill replica runs a 1-token twin; the original budget
            # and sampling state resume on the decode replica at handoff
            twin = dataclasses.replace(req, max_new_tokens=1, handoff=True)
            rej = self.engines[i].add_request(twin)
            if rej is not None:
                return rej
            self._pending_handoff[req.uid] = req
        else:
            rej = self.engines[i].add_request(req)
            if rej is not None:
                return rej
        self.registry.counter(
            "gateway_requests_routed_total",
            "Requests routed to each replica").inc(replica=str(i))
        self._owner[req.uid] = i
        self._streams[req.uid] = []
        self._cursor[req.uid] = 0
        return i

    def preempt(self, uid: str) -> Optional[Request]:
        """Evict ``uid`` from whichever replica holds it and return the
        resume request (``Engine.preempt`` semantics: re-admitting it —
        anywhere — continues the stream bit-identically)."""
        i = self._owner.get(uid)
        if i is None:
            return None
        return self.engines[i].preempt(uid)

    def _drain_handoffs(self) -> None:
        """Move every finished prefill-role prompt to a decode replica:
        export its KV pages to host, inject into the least-loaded decode
        target, release the prefill slot. Export strictly precedes
        release — releasing first could recycle the pages mid-copy."""
        for i, engine in enumerate(self.engines):
            if self.roles[i] != "prefill":
                continue
            for st in engine.take_handoffs():
                uid = st.req.uid
                orig = self._pending_handoff.pop(uid)
                with self.tracer.span("gateway/handoff", cat="gateway",
                                      uid=uid):
                    if orig.max_new_tokens <= 1:
                        # nothing left to decode; the prefill stream is
                        # already the whole response
                        engine.release_handoff(st)
                        continue
                    blocks = engine.export_kv(st)
                    j = min(self._decode_targets,
                            key=lambda k: (self.router.load(k), k))
                    self.engines[j].add_prefilled(orig, st.out[0], blocks)
                    engine.release_handoff(st)
                self._handoff_dst[uid] = j
                self._owner[uid] = j
                self.handoffs += 1
                self.registry.counter(
                    "gateway_handoffs_total",
                    "Prefill->decode KV handoffs").inc(replica=str(j))

    def step(self) -> List[Tuple[str, int]]:
        """One tick: step every replica with work; returns this tick's
        (uid, token) emissions (also appended to the per-request streams)."""
        t0 = time.monotonic()
        emitted: List[Tuple[str, int]] = []
        with self.tracer.span("gateway/step", cat="gateway"):
            for engine in self.engines:
                if not engine.idle():
                    emitted.extend(engine.step())
            self._drain_handoffs()
        for uid, tok in emitted:
            self._streams[uid].append(tok)
        self.wall_s += time.monotonic() - t0
        self.registry.gauge(
            "gateway_wall_seconds",
            "Host wall time spent inside gateway.step()").set(self.wall_s)
        return emitted

    def take(self, uid: str) -> List[int]:
        """Drain the tokens streamed for ``uid`` since the last take."""
        cur = self._cursor.get(uid, 0)
        out = self._streams.get(uid, [])[cur:]
        self._cursor[uid] = cur + len(out)
        return out

    def idle(self) -> bool:
        return all(e.idle() for e in self.engines)

    def run(self, max_steps: Optional[int] = None) -> Dict[str, List[int]]:
        limit = max_steps or self.max_steps
        n = 0
        while not self.idle():
            emitted = self.step()
            if not emitted and not any(
                    e.scheduler.active() or e.scheduler.prefilled
                    for e in self.engines):
                # nothing decoding and nothing admissible: eviction was
                # already tried, so no future step can make progress
                raise RuntimeError(
                    "gateway stalled: queued requests cannot be admitted "
                    "(pool exhausted by live sequences?)")
            n += 1
            if n > limit:
                raise RuntimeError(f"gateway did not drain in {limit} steps")
        return self.collect()

    def collect(self) -> Dict[str, List[int]]:
        out: Dict[str, List[int]] = {}
        for engine in self.engines:
            out.update(engine.collect())
        # a handed-off uid finishes on both sides: the prefill replica's
        # 1-token twin and the decode replica's full stream — the decode
        # side wins regardless of replica index order
        for uid, j in self._handoff_dst.items():
            done = self.engines[j].collect()
            if uid in done:
                out[uid] = done[uid]
        return out

    def reset(self) -> None:
        """Drop requests, pools and prefix caches on every replica; keep
        compiled fns and the router's affinity map cleared."""
        for engine in self.engines:
            engine.reset()
        self.router = Router(self.engines,
                             prefix_aware=bool(self.plan.prefix_cache),
                             eligible=self._entry)
        self._owner.clear()
        self._streams.clear()
        self._cursor.clear()
        self._pending_handoff.clear()
        self._handoff_dst.clear()
        self.handoffs = 0
        self.wall_s = 0.0
        self.draining = False

    def shutdown(self, drain: bool = True,
                 max_steps: Optional[int] = None) -> Dict[str, List[int]]:
        """Stop accepting requests and wind the gateway down.

        ``drain=True`` finishes every in-flight request first (same loop
        as :meth:`run`) and then flushes each replica's staged host-tier
        spills so nothing committed to the host tier is torn; ``False``
        abandons in-flight work. Returns the finished streams. Idempotent
        — a second call is a no-op returning the collected streams."""
        self.draining = True
        if drain and not self.idle():
            self.run(max_steps)
        for engine in self.engines:
            engine.connector.flush()
        return self.collect()

    # ---- metrics --------------------------------------------------------
    def compiles(self) -> Tuple[int, int]:
        """(prefill, decode) bucket-compile counters summed over replicas."""
        return (sum(e.metrics.prefill_compiles for e in self.engines),
                sum(e.metrics.decode_compiles for e in self.engines))

    def xla_compiles(self) -> Tuple[int, int]:
        pf = dc = 0
        for e in self.engines:
            a, b = e.xla_compiles()
            pf, dc = pf + a, dc + b
        return pf, dc

    def pallas_fallbacks(self) -> Dict[str, int]:
        """Trace-time pallas->ref fallback counts summed over the replica
        engines (each engine filters the dispatch layer's labeled counters
        by its own obs scope, so fallbacks traced by other engines or
        earlier tests in the process never leak in)."""
        out: Dict[str, int] = {}
        for e in self.engines:
            for k, v in e.pallas_fallbacks().items():
                out[k] = out.get(k, 0) + v
        return out

    def latency_quantiles(self) -> Dict[str, float]:
        """Gateway-wide p50/p95/p99 TTFT and inter-token gap: the replicas
        share one registry, so histogram quantiles with no label filter
        aggregate every replica's buckets."""
        out: Dict[str, float] = {}
        for short, metric in (("ttft", "serve_ttft_seconds"),
                              ("intertoken", "serve_intertoken_seconds")):
            h = self.registry.get(metric)
            for q in (0.5, 0.95, 0.99):
                out[f"{short}_p{int(q * 100)}_s"] = h.quantile(q)
            out[f"{short}_count"] = h.count()
        return out

    def metrics_dict(self) -> Dict[str, object]:
        per = [e.metrics.to_dict() for e in self.engines]
        tokens = sum(m["tokens_out"] for m in per)
        computed = sum(m["prefill_tokens_computed"] for m in per)
        cached = sum(m["prefill_tokens_cached"] for m in per)
        prompt = computed + cached
        return {
            "replicas": self.replicas,
            "roles": list(self.roles),
            "tokens_out": tokens,
            "wall_s": self.wall_s,
            "tokens_per_s": tokens / self.wall_s if self.wall_s > 0 else 0.0,
            "prefill_tokens_computed": computed,
            "prefill_tokens_cached": cached,
            "prefix_hit_rate": cached / prompt if prompt else 0.0,
            "prefix_evictions": sum(m["prefix_evictions"] for m in per),
            "routed": list(self.router.routed),
            "affinity_hits": self.router.affinity_hits,
            "handoffs": self.handoffs,
            "pallas_fallbacks": self.pallas_fallbacks(),
            "per_replica": per,
        }

    def stats(self) -> Dict[str, object]:
        """`metrics_dict` plus the aggregated host-tier section. Also
        refreshes the ``gateway_host_tier_hit_rate`` gauge so the tier's
        effectiveness lands in every Prometheus scrape / --metrics-dump,
        not only in callers of this method."""
        d = self.metrics_dict()
        per = [e.connector.stats() for e in self.engines]
        agg = {k: sum(t[k] for t in per) for k in (
            "resident_pages", "resident_bytes", "spill_pages",
            "spill_bytes", "reload_pages", "reload_bytes",
            "handoff_out_pages", "handoff_in_pages", "spills_skipped",
            "host_evicted_pages", "hit_tokens", "lookup_tokens")}
        agg["enabled"] = any(e.connector.enabled for e in self.engines)
        agg["hit_rate"] = agg["hit_tokens"] / agg["lookup_tokens"] \
            if agg["lookup_tokens"] else 0.0
        self.registry.gauge(
            "gateway_host_tier_hit_rate",
            "Fraction of non-device-cached lookup tokens served from the "
            "pinned-host KV tier, over all replicas").set(agg["hit_rate"])
        d["host_tier"] = {**agg, "per_replica": per}
        return d


def build_gateway(arch: str, *, smoke: bool = True, c: Optional[int] = 1,
                  data: int = 1, replicas: int = 1,
                  prefix_cache: bool = True, host_tier_bytes: int = 0,
                  roles=None,
                  eng: EngineConfig = EngineConfig(), params=None,
                  init_seed: int = 0, kernel: Optional[str] = None,
                  plan=None, plans=None,
                  registry: Optional[obs.Registry] = None,
                  tracer: Optional[obs.Tracer] = None) -> Gateway:
    """Convenience constructor mirroring ``engine.build_engine``: resolve a
    serve plan whose ``n_devices`` is the per-replica share of the local
    devices, then build the gateway on it. ``roles=['prefill','decode']``
    builds a disaggregated gateway (one plan per role via
    `plan.make_role_plans`, overriding ``replicas``); ``host_tier_bytes``
    sizes the per-engine pinned-host KV tier (needs ``prefix_cache``)."""
    import jax

    from repro.configs import registry as arch_registry
    from repro.models.factory import build_model
    from repro.plan import make_role_plans, make_serve_plan

    cfg = arch_registry.get_smoke(arch) if smoke else arch_registry.get(arch)
    model = build_model(cfg)
    if plan is None and plans is None:
        if roles:
            n_dev = len(jax.devices()) // len(roles)
            plans = make_role_plans(
                cfg, roles=roles, n_devices=n_dev, arch=arch, data=data,
                c=c, decode_batch=eng.max_slots, page_size=eng.page_size,
                max_len=eng.max_len, mesh_kind="local", kernel_impl=kernel,
                prefix_cache=prefix_cache, host_tier_bytes=host_tier_bytes)
        else:
            n_dev = len(jax.devices()) // max(replicas, 1)
            plan = make_serve_plan(
                cfg, arch=arch, n_devices=n_dev, data=data, c=c,
                decode_batch=eng.max_slots, page_size=eng.page_size,
                max_len=eng.max_len, mesh_kind="local", kernel_impl=kernel,
                replicas=replicas, prefix_cache=prefix_cache,
                host_tier_bytes=host_tier_bytes)
    if params is None:
        params = model.init(jax.random.PRNGKey(init_seed))
    return Gateway(model, plan, eng, params, registry=registry,
                   tracer=tracer, plans=plans)
