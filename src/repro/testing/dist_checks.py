"""Distributed correctness checks for StarTrail attention.

Run standalone with 8 forced host devices (pytest launches this module in a
subprocess so the main test session keeps seeing 1 device):

    python -m repro.testing.dist_checks [check_name ...]

Every check compares the distributed implementation bit-for-bit semantics
(<= tolerance) against the single-device full-attention oracle in
``repro.kernels.ref`` — forward and gradients.
"""

import os
import sys

if __name__ == "__main__":  # set before jax import
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import startrail as st
from repro.core import topology as topo_lib
from repro.core import ulysses as ulysses_lib
from repro.core import zigzag as zz
from repro.kernels import ref as ref_kernels

AXES = ("sp_grp", "sp_ring", "sp_team")


def make_mesh(c: int, p: int):
    r = p // (c * c)
    devs = np.array(jax.devices()[:p]).reshape(c, r, c)
    return jax.sharding.Mesh(devs, AXES)


def to_sharded_layout(x: np.ndarray, positions: np.ndarray) -> np.ndarray:
    """Reorder global (B, N, ...) so an even split over axis 1 matches the
    per-shard position layout."""
    return np.take(x, positions.reshape(-1), axis=1)


def from_sharded_layout(x: np.ndarray, positions: np.ndarray) -> np.ndarray:
    inv = zz.inverse_permutation_for(positions)
    return np.take(x, inv, axis=1)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def check_attention(c, p, *, causal, scheme, window=None, hq=4, hkv=2,
                    dtype=jnp.float32, seq=64, batch=2, d=8, impl="ref",
                    block_skip=False, tol=2e-4):
    """StarTrail forward + grads vs full-attention oracle."""
    mesh = make_mesh(c, p)
    cfg = st.StarTrailConfig(
        seq_len=seq, axes=AXES, seq_scheme=scheme, causal=causal,
        window=window, block_impl=impl, block_skip=block_skip,
    )
    key = jax.random.PRNGKey(0)
    kq, kk, kv, kg = jax.random.split(key, 4)
    q = _rand(kq, (batch, seq, hq, d), dtype)
    k = _rand(kk, (batch, seq, hkv, d), dtype)
    v = _rand(kv, (batch, seq, hkv, d), dtype)
    do = _rand(kg, (batch, seq, hq, d), dtype)

    positions = zz.make_positions(seq, p, scheme)
    spec = P(None, AXES, None, None)

    def local(q, k, v):
        return st.startrail_attention(q, k, v, cfg)

    dist = jax.jit(
        jax.shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                      out_specs=spec, check_vma=False)
    )

    qs = jnp.asarray(to_sharded_layout(np.asarray(q), positions))
    ks = jnp.asarray(to_sharded_layout(np.asarray(k), positions))
    vs = jnp.asarray(to_sharded_layout(np.asarray(v), positions))
    dos = jnp.asarray(to_sharded_layout(np.asarray(do), positions))

    # forward
    o_dist = from_sharded_layout(np.asarray(dist(qs, ks, vs)), positions)
    o_ref = np.asarray(
        ref_kernels.mha_reference(q, k, v, causal=causal, window=window)
    )
    err = np.abs(o_dist.astype(np.float32) - o_ref.astype(np.float32)).max()
    assert err < tol, f"forward err {err} (C={c}, causal={causal}, {scheme})"

    # gradients
    def loss_dist(q, k, v):
        return (dist(q, k, v).astype(jnp.float32) * dos.astype(jnp.float32)).sum()

    def loss_ref(q, k, v):
        o = ref_kernels.mha_reference(q, k, v, causal=causal, window=window)
        return (o.astype(jnp.float32) * do.astype(jnp.float32)).sum()

    gd = jax.jit(jax.grad(loss_dist, argnums=(0, 1, 2)))(qs, ks, vs)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gd, gr):
        a = from_sharded_layout(np.asarray(a), positions)
        e = np.abs(a.astype(np.float32) - np.asarray(b).astype(np.float32)).max()
        assert e < tol, f"grad d{name} err {e} (C={c}, causal={causal}, {scheme})"
    return err


def check_ulysses(p=4, seq=32, hq=8, hkv=4, d=8, causal=True):
    mesh = make_mesh(1, p)  # (1, p, 1)
    cfg = st.StarTrailConfig(seq_len=seq, axes=AXES, seq_scheme="contiguous",
                             causal=causal)
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (2, seq, hq, d))
    k = _rand(kk, (2, seq, hkv, d))
    v = _rand(kv, (2, seq, hkv, d))
    spec = P(None, AXES, None, None)
    dist = jax.jit(jax.shard_map(
        lambda q, k, v: ulysses_lib.ulysses_attention(q, k, v, cfg),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False))
    o = np.asarray(dist(q, k, v))
    o_ref = np.asarray(ref_kernels.mha_reference(q, k, v, causal=causal))
    err = np.abs(o - o_ref).max()
    assert err < 2e-4, f"ulysses err {err}"


def check_decode(p=8, cache_len=64, hq=4, hkv=2, d=8):
    c = 2
    mesh = make_mesh(c, p)
    cfg = st.StarTrailConfig(seq_len=cache_len, axes=AXES,
                             seq_scheme="contiguous", causal=True)
    key = jax.random.PRNGKey(2)
    kq, kk, kv = jax.random.split(key, 3)
    B = 2
    q = _rand(kq, (B, 1, hq, d))
    k = _rand(kk, (B, cache_len, hkv, d))
    v = _rand(kv, (B, cache_len, hkv, d))
    pos_q = jnp.array([cache_len - 1], jnp.int32)

    spec_kv = P(None, AXES, None, None)

    def local(q, k, v):
        # contiguous cache shard positions
        gi = jax.lax.axis_index(AXES[0])
        ji = jax.lax.axis_index(AXES[1])
        ti = jax.lax.axis_index(AXES[2])
        r = p // (c * c)
        rank = (gi * r + ji) * c + ti
        pos_k = st.shard_positions(rank, cache_len, p, "contiguous")
        return st.decode_attention(q, k, v, pos_q, pos_k, cfg)

    dist = jax.jit(jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None, None, None), spec_kv, spec_kv),
        out_specs=P(None, None, None, None), check_vma=False))
    o = np.asarray(dist(q, k, v))
    o_ref, _ = ref_kernels.block_attention(
        q, k, v, pos_q, jnp.arange(cache_len, dtype=jnp.int32), causal=True)
    err = np.abs(o - np.asarray(o_ref)).max()
    assert err < 2e-4, f"decode err {err}"


def check_topology_vs_paper():
    """Structural formulation == verbatim paper Algs. 2/3 for many (P, C)."""
    for p in (4, 8, 16, 64, 256):
        for c in topo_lib.valid_c_values(p):
            tp = topo_lib.StarTrailTopology(p, c)
            tp.check_invariants()
            d_t, d_a = tp.num_teams, c
            # Alg 2: member (r_t, r_a)'s send target == structural placement
            perm = dict(tp.init_placement_permutation())
            for r_t in range(d_t):
                for r_a in range(d_a):
                    src = r_t * c + r_a
                    assert perm[src] == topo_lib.paper_get_init_send(r_t, r_a, d_t, d_a), (
                        p, c, r_t, r_a)
            # Alg 3: ring neighbours == structural ring permutation
            ring = dict(tp.ring_permutation(shift=1))
            for r_t in range(d_t):
                for r_a in range(d_a):
                    src = r_t * c + r_a
                    nxt, _last = topo_lib.paper_get_p2p_config(r_t, r_a, d_t, d_a)
                    # our ring sends j -> j-1 i.e. to the *last* team; the
                    # paper's "next" is the other direction. Both tours are
                    # valid; assert we send to one of the two neighbours and
                    # the tour is a single cycle per ring.
                    assert ring[src] in (nxt, _last), (p, c, src)


CHECKS = {
    "topology": check_topology_vs_paper,
    "ring_causal_zigzag": functools.partial(
        check_attention, 1, 8, causal=True, scheme="zigzag"),
    "ring_full_contig": functools.partial(
        check_attention, 1, 8, causal=False, scheme="contiguous"),
    "st2_causal_zigzag": functools.partial(
        check_attention, 2, 8, causal=True, scheme="zigzag"),
    "st2_causal_contig": functools.partial(
        check_attention, 2, 8, causal=True, scheme="contiguous"),
    "st2_full": functools.partial(
        check_attention, 2, 8, causal=False, scheme="contiguous"),
    "st2_window": functools.partial(
        check_attention, 2, 8, causal=True, scheme="zigzag", window=16),
    "st2_window_skip": functools.partial(
        check_attention, 2, 8, causal=True, scheme="contiguous", window=16,
        block_skip=True),
    "st2_mha": functools.partial(
        check_attention, 2, 8, causal=True, scheme="zigzag", hq=4, hkv=4),
    "st2_mqa": functools.partial(
        check_attention, 2, 8, causal=True, scheme="zigzag", hq=4, hkv=1),
    "st2_bf16": functools.partial(
        check_attention, 2, 8, causal=True, scheme="zigzag",
        dtype=jnp.bfloat16, tol=5e-2),
    "st2_r1": functools.partial(  # R=1: fully-collective degenerate point
        check_attention, 2, 4, causal=True, scheme="zigzag"),
    "st2_pallas": functools.partial(
        check_attention, 2, 8, causal=True, scheme="zigzag", impl="pallas",
        seq=64, d=64, tol=5e-4),
    "ulysses": check_ulysses,
    "decode": check_decode,
    # 16-device factorisations (run with device_count=16): C=4 is the
    # fully-collective degenerate point at P=16 (R=1); C=2 gives R=4 rings
    "st4_p16": functools.partial(
        check_attention, 4, 16, causal=True, scheme="zigzag", seq=64),
    "st2_p16_r4": functools.partial(
        check_attention, 2, 16, causal=True, scheme="zigzag", seq=64),
    "st2_p16_window": functools.partial(
        check_attention, 2, 16, causal=True, scheme="contiguous", window=24,
        block_skip=True, seq=64),
}


def main(argv):
    names = argv or list(CHECKS)
    failures = []
    for name in names:
        try:
            CHECKS[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"FAIL {name}: {e!r}")
    if failures:
        sys.exit(1)
    print(f"ALL {len(names)} DISTRIBUTED CHECKS PASSED")




# ---------------------------------------------------------------------------
# end-to-end manual-SPMD model equivalence: spmd loss/grads == local mode
# ---------------------------------------------------------------------------

def check_spmd_model(arch="h2o-danube-1.8b", c=2, data=2, seq=32, batch=2,
                     tol=2e-3, grad_tol=None, check_grads=True):
    """tol guards the loss equivalence; grad_tol (default: tol) the grads —
    kept separate so archs with large-magnitude grads can loosen only the
    grad bound without weakening the loss check."""
    import dataclasses as dc

    from repro.configs import registry
    from repro.configs.base import MoEConfig, RunConfig, ShapeConfig
    from repro.core import zigzag as zz
    from repro.dist import meshes
    from repro.models.factory import build_model
    from repro.models.runtime import Runtime
    from repro.train import step as train_step

    cfg = registry.get_smoke(arch)
    if cfg.moe is not None:
        # avoid token dropping so local and spmd routing agree exactly
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    shape = ShapeConfig("test", seq_len=seq, global_batch=batch, kind="train")
    run_cfg = RunConfig(c=c, seq_scheme="zigzag")

    r = 8 // (data * c * c)
    mesh = meshes.local_mesh_for_tests(c=c, r=r, data=data)

    # one island build: the fwd+bwd vg island already returns the loss, so
    # the grad checks reuse its compile; loss-only archs keep the cheaper
    # forward-only island
    if check_grads:
        island_fn, rt = train_step.build_value_and_grad_fn(model, mesh,
                                                           run_cfg, shape)
    else:
        island_fn, rt = train_step.build_loss_fn(model, mesh, run_cfg, shape)
    rt_local = train_step.make_runtime(model, run_cfg, shape, mode="local")

    params = model.init(jax.random.PRNGKey(0))
    batch_g = model.make_batch(jax.random.PRNGKey(1), shape)

    # permute batch into the sharded layout
    psp = c * c * r
    positions = zz.make_positions(seq, psp, rt.st_cfg.seq_scheme)
    perm = positions.reshape(-1)
    batch_s = dict(batch_g)
    for k in batch_s:
        batch_s[k] = jnp.take(batch_s[k], perm, axis=1)

    out = jax.jit(island_fn)(params, batch_s)
    l_spmd, g_spmd = out if check_grads else (out, None)
    l_local = jax.jit(lambda p, b: model.loss(rt_local, p, b))(params, batch_g)
    err = abs(float(l_spmd) - float(l_local))
    assert err < tol, f"{arch}: spmd loss {l_spmd} vs local {l_local}"

    if check_grads:
        g_local = jax.jit(jax.grad(
            lambda p: model.loss(rt_local, p, batch_g)))(params)
        errs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            g_spmd, g_local)
        leaves = np.array(jax.tree.leaves(errs))
        assert np.all(np.isfinite(leaves)), f"{arch}: NaN/inf in grads"
        worst = float(leaves.max())
        assert worst < (tol if grad_tol is None else grad_tol), (
            f"{arch}: grad mismatch {worst}: " + str(
                {k: v for k, v in jax.tree_util.tree_leaves_with_path(errs)
                 if v == worst}))
    return float(l_spmd)


def check_spmd_train_step(arch="h2o-danube-1.8b", c=2, data=2):
    """Full jitted train step on the refined mesh: runs, loss finite+decreases."""
    from repro.configs import registry
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.core import zigzag as zz
    from repro.dist import meshes
    from repro.models.factory import build_model
    from repro.optim import adamw
    from repro.train import step as train_step

    cfg = registry.get_smoke(arch)
    model = build_model(cfg)
    shape = ShapeConfig("test", seq_len=32, global_batch=2, kind="train")
    run_cfg = RunConfig(c=c, seq_scheme="zigzag")
    r = 8 // (data * c * c)
    mesh = meshes.local_mesh_for_tests(c=c, r=r, data=data)

    jstep, sh = train_step.build_train_step(
        model, mesh, run_cfg, shape,
        adamw.AdamWConfig(learning_rate=1e-2, warmup_steps=0))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params, adamw.AdamWConfig())
    batch_g = model.make_batch(jax.random.PRNGKey(1), shape)
    psp = c * c * r
    positions = zz.make_positions(shape.seq_len, psp, sh["rt"].st_cfg.seq_scheme)
    perm = positions.reshape(-1)
    batch_s = {k: jnp.take(v, perm, axis=1) for k, v in batch_g.items()}

    losses = []
    for _ in range(3):
        params, opt, metrics = jstep(params, opt, batch_s)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], f"loss not decreasing: {losses}"


CHECKS.update({
    "spmd_dense_swa": functools.partial(check_spmd_model, "h2o-danube-1.8b"),
    "spmd_dense_c1": functools.partial(check_spmd_model, "h2o-danube-1.8b",
                                       c=1),
    "spmd_moe": functools.partial(check_spmd_model, "phi3.5-moe-42b-a6.6b"),
    "spmd_hybrid": functools.partial(check_spmd_model, "jamba-1.5-large-398b",
                                     tol=5e-3),
    "spmd_vlm": functools.partial(check_spmd_model, "paligemma-3b"),
    # grad_tol 3e-2 abs: frontend_proj/embed grads are O(16) and accumulate
    # over vocab-parallel scatter transposes — f32 reassociation noise
    # (~1.5e-3 relative); the loss itself matches to 1e-6 so its bound
    # stays at the default
    "spmd_encdec": functools.partial(check_spmd_model,
                                     "seamless-m4t-large-v2", grad_tol=3e-2),
    "spmd_xlstm_runs": functools.partial(check_spmd_model, "xlstm-1.3b",
                                         tol=1e9, check_grads=False),
    "spmd_train_step": check_spmd_train_step,
})



def check_spmd_serve(arch="h2o-danube-1.8b", c=2, data=2, seq=32):
    """Decode + prefill steps lower and run on the refined mesh; decode
    matches the local-mode decode step."""
    from repro.configs import registry
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.dist import meshes
    from repro.models.factory import build_model
    from repro.serve import kv_cache, step as serve_step
    from repro.train import step as train_step

    cfg = registry.get_smoke(arch)
    model = build_model(cfg)
    shape = ShapeConfig("t", seq_len=seq, global_batch=2, kind="decode")
    run_cfg = RunConfig(c=c, seq_scheme="contiguous")
    r = 8 // (data * c * c)
    mesh = meshes.local_mesh_for_tests(c=c, r=r, data=data)

    params = model.init(jax.random.PRNGKey(0))
    cache = kv_cache.init_cache(cfg, 2, seq)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 1), 0,
                                cfg.vocab_size, jnp.int32)

    jdecode, info = serve_step.build_decode_step(model, mesh, run_cfg, shape)
    tok_s, cache_s = jdecode(params, cache, tokens)

    # local-mode reference decode
    import dataclasses as dc
    rt_local = dc.replace(
        train_step.make_runtime(model, run_cfg, shape, mode="local"),
        batch_axes=())
    if cfg.encdec:
        local_fn = lambda: serve_step.encdec_decode_step(
            rt_local, params, cache, tokens, cfg, seq - 1)
    else:
        local_fn = lambda: serve_step.lm_decode_step(
            rt_local, params, cache, tokens, cfg, seq - 1)
    tok_l, _ = jax.jit(local_fn)()
    assert np.array_equal(np.asarray(tok_s), np.asarray(tok_l)), (
        f"{arch}: decode tokens differ: {tok_s} vs {tok_l}")

    if not cfg.encdec:
        # prefill lowers and runs
        shape_p = ShapeConfig("t", seq_len=seq, global_batch=2, kind="prefill")
        jprefill, _ = serve_step.build_prefill_step(model, mesh, run_cfg, shape_p)
        batch = {k: v for k, v in model.make_batch(
            jax.random.PRNGKey(1), shape_p).items() if k != "labels"}
        tok0, cache0 = jprefill(params, batch)
        assert np.all(np.isfinite(np.asarray(tok0, np.float32)))


CHECKS.update({
    "serve_dense": functools.partial(check_spmd_serve, "h2o-danube-1.8b"),
    "serve_moe": functools.partial(check_spmd_serve, "phi3.5-moe-42b-a6.6b"),
    "serve_hybrid": functools.partial(check_spmd_serve, "jamba-1.5-large-398b"),
    "serve_xlstm": functools.partial(check_spmd_serve, "xlstm-1.3b"),
    "serve_encdec": functools.partial(check_spmd_serve, "seamless-m4t-large-v2"),
})


# ---------------------------------------------------------------------------
# serving engine (continuous batching + paged cache + vocab-parallel sampling)
# ---------------------------------------------------------------------------

def _tie_fixture():
    """(rt, cfg, mesh, x) for direct vocab-parallel head checks: logits for
    token v are exactly table[v, 0] (x is the first basis vector)."""
    from repro.configs.base import ModelConfig
    from repro.dist import meshes
    from repro.models.runtime import Runtime

    mesh = meshes.local_mesh_for_tests(c=2, r=2, data=1)  # sp = 8
    cfg = ModelConfig(name="tie", family="dense", num_layers=1, d_model=4,
                      num_heads=1, num_kv_heads=1, d_ff=8, vocab_size=64)
    st_cfg = st.StarTrailConfig(seq_len=8, axes=AXES, seq_scheme="contiguous")
    rt = Runtime(mode="spmd", st_cfg=st_cfg, batch_axes=())
    x = np.zeros((1, 1, 4), np.float32)
    x[0, 0, 0] = 1.0
    return rt, cfg, mesh, x


def check_greedy_tie():
    """vocab_parallel_greedy regression: an exact cross-shard logit tie must
    resolve to the lowest shard's candidate (= the smallest global token
    id), not to an averaged id that neither shard proposed."""
    from repro.serve import step as serve_step

    rt, cfg, mesh, x = _tie_fixture()
    table = np.zeros((64, 4), np.float32)
    table[:, 0] = -np.arange(64, dtype=np.float32) * 1e-3
    table[9, 0] = 5.0     # shard 1 (v_local = 8)
    table[17, 0] = 5.0    # shard 2 — exact tie
    fn = jax.jit(jax.shard_map(
        lambda t, x: serve_step.vocab_parallel_greedy(rt, {"table": t}, x, cfg),
        mesh=mesh, in_specs=(P(AXES, None), P(None, None, None)),
        out_specs=P(None, None), check_vma=False))
    tok = int(np.asarray(fn(table, x))[0, 0])
    assert tok == 9, f"cross-shard tie broke to {tok}, want token 9"
    # three-way tie including a same-shard pair -> still the smallest id
    table[11, 0] = 5.0
    tok = int(np.asarray(fn(table, x))[0, 0])
    assert tok == 9, f"three-way tie broke to {tok}, want token 9"


def check_engine_sampling():
    """Vocab-parallel sampling on the mesh: greedy == argmax; top-k/top-p
    samples stay inside the host-computed candidate sets; same key -> same
    token (determinism)."""
    from repro.engine import sampling as sampling_lib

    rt, cfg, mesh, x = _tie_fixture()
    rng = np.random.default_rng(0)
    table = np.zeros((64, 4), np.float32)
    table[:, 0] = rng.normal(size=64).astype(np.float32)
    full = table[:, 0].astype(np.float64)

    def run(temp, top_k, top_p, fold):
        fn = jax.jit(jax.shard_map(
            lambda t, x, keys: sampling_lib.sample(
                rt, {"table": t}, x, cfg,
                temperature=jnp.full((1,), temp, jnp.float32),
                top_k=jnp.full((1,), top_k, jnp.int32),
                top_p=jnp.full((1,), top_p, jnp.float32), keys=keys),
            mesh=mesh, in_specs=(P(AXES, None), P(None, None, None), P()),
            out_specs=P(None, None), check_vma=False))
        keys = np.asarray(jax.random.fold_in(jax.random.PRNGKey(0), fold))
        return int(np.asarray(fn(table, x, keys[None]))[0, 0])

    assert run(0.0, 0, 1.0, 0) == int(np.argmax(full)), "greedy != argmax"

    topk_set = set(np.argsort(full)[-5:].tolist())
    seen = set()
    for i in range(24):
        t = run(1.0, 5, 1.0, i)
        assert t in topk_set, f"top-k sample {t} outside top-5 {topk_set}"
        seen.add(t)
    assert len(seen) > 1, "top-k sampling degenerate (one token in 24 draws)"

    probs = np.exp(full - full.max())
    probs /= probs.sum()
    order = np.argsort(-probs)
    csum = np.cumsum(probs[order])
    nucleus = set(order[:int(np.searchsorted(csum, 0.5) + 1)].tolist())
    for i in range(24):
        t = run(1.0, 0, 0.5, i)
        assert t in nucleus, f"top-p sample {t} outside nucleus {nucleus}"

    assert run(0.7, 8, 0.9, 3) == run(0.7, 8, 0.9, 3), "sampling not deterministic"


def check_engine_mixed(arch="h2o-danube-1.8b"):
    """Acceptance: a mixed workload (8 requests, different prompt lengths,
    budgets, sampling settings, arriving over time) through the engine —
    decode compiles at most once per width bucket, replay adds no compiles,
    and every request's output is bit-identical to serving it alone."""
    from repro.engine import EngineConfig, Request, build_engine

    eng = build_engine(arch, smoke=True, c=2, data=1,
                       eng=EngineConfig(max_slots=4, page_size=4,
                                        pages_per_shard=32, max_len=128))
    rng = np.random.default_rng(1)
    vocab = eng.cfg.vocab_size
    reqs, arrivals = [], []
    for i in range(8):
        plen = int(rng.integers(2, 28))
        gen = int(rng.integers(2, 10))
        temp = 0.0 if i % 2 == 0 else 0.9
        reqs.append(Request(
            uid=f"r{i}", tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, temperature=temp, top_k=16 * (i % 3),
            top_p=[1.0, 0.9, 0.8][i % 3], seed=100 + i))
        arrivals.append(i)  # one new arrival per step

    def run_workload():
        pending = list(zip(arrivals, reqs))
        while pending or not eng.idle():
            step = eng.metrics.steps
            while pending and pending[0][0] <= step:
                eng.add_request(pending.pop(0)[1])
            eng.step()
        return eng.collect()

    mixed = run_workload()
    assert len(mixed) == 8 and all(
        len(mixed[r.uid]) == r.max_new_tokens for r in reqs)
    pc, dc = eng.metrics.prefill_compiles, eng.metrics.decode_compiles
    # once-per-bucket: each bucket fn must hold exactly one XLA trace
    # (xla_compiles counts traces, not dict misses — catches silent
    # retracing from operand dtype/sharding drift)
    assert eng.xla_compiles() == (len(eng._prefill_fns),
                                  len(eng._decode_fns)), (
        f"a bucket fn compiled more than once: {eng.xla_compiles()} traces "
        f"for {(len(eng._prefill_fns), len(eng._decode_fns))} buckets")

    # replay: every bucket is warm, zero new compiles
    eng.reset()
    replay = run_workload()
    assert replay == mixed, "replay of the same workload diverged"
    assert (eng.metrics.prefill_compiles, eng.metrics.decode_compiles) == \
        (pc, dc), "recompiled on replay"
    assert eng.xla_compiles() == (len(eng._prefill_fns),
                                  len(eng._decode_fns)), \
        "silent XLA retrace on replay"

    # solo: each request alone, bit-identical outputs
    for r in reqs:
        eng.reset()
        eng.add_request(r)
        solo = eng.run()
        assert solo[r.uid] == mixed[r.uid], (
            f"{r.uid}: batched {mixed[r.uid]} != solo {solo[r.uid]}")


def check_engine_moe(arch="phi3.5-moe-42b-a6.6b"):
    """The engine also serves MoE stacks (expert-parallel decode over the
    paged cache); outputs drain and replay deterministically. (MoE capacity
    couples tokens across the batch, so solo-vs-batched bit-equality is not
    asserted — see docs/SERVING.md.)"""
    from repro.engine import EngineConfig, Request, build_engine

    eng = build_engine(arch, smoke=True, c=1, data=1,
                       eng=EngineConfig(max_slots=2, page_size=4,
                                        pages_per_shard=16, max_len=64))
    rng = np.random.default_rng(2)
    vocab = eng.cfg.vocab_size
    reqs = [Request(uid=f"m{i}",
                    tokens=rng.integers(0, vocab, 5 + 3 * i).tolist(),
                    max_new_tokens=3 + i) for i in range(3)]
    for r in reqs:
        eng.add_request(r)
    out = eng.run()
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)
    eng.reset()
    for r in reqs:
        eng.add_request(r)
    assert eng.run() == out, "MoE engine replay nondeterministic"


def check_paged_decode_dist(c=2, p=8, hq=4, hkv=2, d=16, ps=4, w=3):
    """The Pallas paged-decode kernel inside a shard_map island on the
    refined mesh: partial (o, lse) per shard + lse-combine psum must match
    the dense full-cache oracle, with ragged per-row cache lengths and
    round-robin page ownership."""
    from repro.kernels import dispatch as kernels

    mesh = make_mesh(c, p)
    B = 3
    pages_loc = 8
    cache_max = w * p * ps
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (B, 1, hq, d))
    k_full = _rand(kk, (B, cache_max, hkv, d))
    v_full = _rand(kv, (B, cache_max, hkv, d))
    cache_len = jnp.array([cache_max - 1, ps + 1, 0], jnp.int32)[:B]

    # host-side round-robin paging of the dense cache: global block g lives
    # on shard g % p as that shard's g // p-th block; per-shard pools +
    # a (B, p, w) table with distinct local pages per (slot, block)
    pools_k = np.zeros((p, pages_loc, ps, hkv, d), np.float32)
    pools_v = np.zeros((p, pages_loc, ps, hkv, d), np.float32)
    table = np.full((B, p, w), -1, np.int32)
    next_free = [0] * p
    for b in range(B):
        blocks = int(cache_len[b]) // ps + 1
        for g in range(blocks):
            sh, j = g % p, g // p
            page = next_free[sh]
            next_free[sh] += 1
            table[b, sh, j] = page
            sl = slice(g * ps, (g + 1) * ps)
            pools_k[sh, page] = np.asarray(k_full[b, sl])
            pools_v[sh, page] = np.asarray(v_full[b, sl])

    spec_pool = P(AXES, None, None, None, None)

    def island(q, pool_k, pool_v, table, cache_len):
        gi = jax.lax.axis_index(AXES[0])
        ji = jax.lax.axis_index(AXES[1])
        ti = jax.lax.axis_index(AXES[2])
        r = p // (c * c)
        rank = (gi * r + ji) * c + ti
        tbl = jax.lax.dynamic_index_in_dim(table, rank, axis=1,
                                           keepdims=False)
        o_p, lse_p = kernels.paged_decode(
            q, pool_k[0], pool_v[0], tbl, cache_len, rank, sp=p,
            page_size=ps, impl="pallas")
        return st.combine_decode_partials(o_p, lse_p, AXES)

    fn = jax.jit(jax.shard_map(
        island, mesh=mesh,
        in_specs=(P(None, None, None, None), spec_pool, spec_pool,
                  P(None, None, None), P(None)),
        out_specs=P(None, None, None, None), check_vma=False))
    o = np.asarray(fn(q, jnp.asarray(pools_k), jnp.asarray(pools_v),
                      jnp.asarray(table), cache_len))

    pos = jnp.arange(cache_max, dtype=jnp.int32)
    for b in range(B):
        cl = int(cache_len[b])
        pos_k = jnp.where(pos <= cl, pos, cl + 1)
        o_ref, _ = ref_kernels.block_attention(
            q[b:b + 1], k_full[b:b + 1], v_full[b:b + 1],
            jnp.array([cl], jnp.int32), pos_k, causal=True)
        err = np.abs(o[b] - np.asarray(o_ref)[0]).max()
        assert err < 2e-4, f"paged decode row {b} err {err}"


def check_engine_paged_kernel(arch="h2o-danube-1.8b"):
    """Acceptance (paged-decode kernel): the engine under
    kernel_impl='pallas' (interpret mode on CPU) emits bit-identical tokens
    to the ref gather path for the same mixed workload, and holds the same
    once-per-bucket compile guarantee."""
    from repro.engine import EngineConfig, Request, build_engine

    rng = np.random.default_rng(3)
    outs = {}
    engines = {}
    for kern in ("ref", "pallas"):
        eng = build_engine(arch, smoke=True, c=2, data=1, kernel=kern,
                           eng=EngineConfig(max_slots=2, page_size=4,
                                            pages_per_shard=16, max_len=64))
        assert eng.kernel_impl == kern
        vocab = eng.cfg.vocab_size
        rng_w = np.random.default_rng(3)
        reqs = [Request(uid=f"p{i}",
                        tokens=rng_w.integers(0, vocab, 3 + 4 * i).tolist(),
                        max_new_tokens=2 + i, seed=50 + i)
                for i in range(3)]
        eng.add_request(reqs[0])
        eng.add_request(reqs[1])
        eng.step()
        eng.add_request(reqs[2])        # joins the running batch
        outs[kern] = eng.run()
        assert eng.xla_compiles() == (len(eng._prefill_fns),
                                      len(eng._decode_fns)), (
            f"{kern}: a bucket fn compiled more than once")
        engines[kern] = eng
    assert outs["pallas"] == outs["ref"], (
        f"paged-kernel tokens diverged from the ref path:\n"
        f"  ref:    {outs['ref']}\n  pallas: {outs['pallas']}")

    # replay on the warm pallas engine: zero new compiles
    eng = engines["pallas"]
    pc, dc = eng.metrics.prefill_compiles, eng.metrics.decode_compiles
    eng.reset()
    vocab = eng.cfg.vocab_size
    rng_w = np.random.default_rng(3)
    for i in range(3):
        eng.add_request(Request(
            uid=f"p{i}", tokens=rng_w.integers(0, vocab, 3 + 4 * i).tolist(),
            max_new_tokens=2 + i, seed=50 + i))
    assert eng.run() == outs["pallas"], "pallas replay diverged"
    assert (eng.metrics.prefill_compiles, eng.metrics.decode_compiles) == \
        (pc, dc), "pallas engine recompiled on replay"


def check_gateway_prefix_cow(arch="h2o-danube-1.8b"):
    """Acceptance (gateway prefix cache, C=2 mesh): two requests sharing a
    long prefix then diverging produce exactly the tokens of solo
    cold-cache runs — the second request's prefill reads the first's pages
    in place (copy-on-write sharing, >0 hit rate) — and dropping the
    shared prefix from the cache *while a request is live* never corrupts
    it (ref counts keep the pages alive until the request finishes)."""
    from repro.engine import EngineConfig, Request
    from repro.gateway import build_gateway

    eng_cfg = EngineConfig(max_slots=2, page_size=4, pages_per_shard=16,
                           max_len=64)
    gw = build_gateway(arch, smoke=True, c=2, data=1, replicas=1,
                       prefix_cache=True, eng=eng_cfg)
    assert gw.engines[0].sp == 8 and gw.plan.c == 2
    rng = np.random.default_rng(11)
    vocab = gw.cfg.vocab_size
    shared = rng.integers(0, vocab, 16).tolist()
    req_a = Request("a", shared + rng.integers(0, vocab, 5).tolist(), 4,
                    seed=1)
    req_b = Request("b", shared + rng.integers(0, vocab, 7).tolist(), 4,
                    seed=2)

    # --- shared-prefix serving: A cold, B hits A's pages
    gw.add_request(req_a)
    gw.step()                                     # A prefilled + registered
    gw.add_request(req_b)
    out = gw.run()
    m = gw.engines[0].metrics
    assert m.prefill_tokens_cached == 16, (
        f"B should reuse A's 16 shared-prefix tokens, cached="
        f"{m.prefill_tokens_cached}")
    # shared blocks resolved to the SAME physical pages for both slots
    cache = gw.engines[0].prefix_cache
    assert cache.hit_tokens == 16 and cache.hit_rate > 0

    # --- solo cold-cache references
    cold = build_gateway(arch, smoke=True, c=2, data=1, replicas=1,
                         prefix_cache=False, eng=eng_cfg)
    for r in (req_a, req_b):
        cold.reset()
        cold.add_request(r)
        solo = cold.run()
        assert solo[r.uid] == out[r.uid], (
            f"{r.uid}: cached {out[r.uid]} != solo cold {solo[r.uid]}")

    # --- evict the shared prefix while a sharing request is LIVE
    gw.reset()
    gw.add_request(req_a)
    gw.step()
    gw.add_request(req_b)
    gw.step()                                     # B admitted, sharing pages
    live_cached = gw.engines[0].scheduler.active()
    assert any(s.cached_len for s in live_cached), "B should be a live hit"
    cache = gw.engines[0].prefix_cache
    cache.drop_all()                              # cache lets go of *all*
    #                                               holds; B still refs them
    assert gw.engines[0].scheduler.pool.pages_in_use() > 0
    out2 = gw.run()
    assert out2 == out, (
        f"evicting the shared prefix under a live request corrupted it:\n"
        f"  before: {out}\n  after:  {out2}")
    # prefix gone from the trie: a re-arrival misses but stays correct
    gw.add_request(Request("a2", req_a.tokens, 4, seed=1))
    pre = gw.engines[0].metrics.prefill_tokens_cached
    out3 = gw.run()
    assert gw.engines[0].metrics.prefill_tokens_cached == pre, \
        "dropped prefix should not hit"
    assert out3["a2"] == out["a"], "post-eviction cold rerun diverged"

    # --- pool-pressure eviction: a tiny pool (2 pages/shard) fills with
    # retained prompt blocks; fresh admissions must reclaim cache-only
    # pages (leaf-first LRU) and serving proceeds
    gp = build_gateway(arch, smoke=True, c=2, data=1, replicas=1,
                       prefix_cache=True,
                       eng=EngineConfig(max_slots=2, page_size=4,
                                        pages_per_shard=2, max_len=64))
    filler = [Request(f"f{i}", rng.integers(0, vocab, 9).tolist(), 1,
                      seed=10 + i) for i in range(4)]
    for r in filler:                              # retained after finish
        gp.add_request(r)
    out_f = gp.run()
    big = Request("big", rng.integers(0, vocab, 24).tolist(), 8, seed=99)
    gp.add_request(big)
    out4 = gp.run()
    assert len(out4["big"]) == 8 and all(
        len(out_f[r.uid]) == 1 for r in filler)
    assert gp.engines[0].prefix_cache.evicted_pages > 0, \
        "pool pressure should have evicted cache-only pages"

    # --- host tier: the same kind of pool-pressure eviction, but with a
    # pinned-host tier attached the evicted family prefix is *demoted*
    # (spilled) instead of destroyed — a re-arrival reloads its KV from
    # host into fresh pool pages and the stream stays bit-identical to a
    # big-pool serve that never evicted anything
    rng = np.random.default_rng(17)
    fam = rng.integers(0, vocab, 16).tolist()
    first = Request("h0", fam + rng.integers(0, vocab, 3).tolist(), 3,
                    seed=21)
    # deep filler chains (24 tokens > the 16-token family) so cost-aware
    # eviction unwinds the *family* chain, spilling it block by block
    evictors = [Request(f"e{i}", rng.integers(0, vocab, 24).tolist(), 1,
                        seed=30 + i) for i in range(2)]
    again = Request("h1", fam + rng.integers(0, vocab, 5).tolist(), 3,
                    seed=22)

    def serve_pressure(gw):
        out = {}
        gw.add_request(first)
        out.update(gw.run())                      # family registered
        for r in evictors:
            gw.add_request(r)
            out.update(gw.run())                  # family evicted/spilled
        gw.add_request(again)
        out.update(gw.run())                      # re-arrival: host reload
        return out

    ref_out = serve_pressure(build_gateway(        # 16 pages/shard: roomy,
        arch, smoke=True, c=2, data=1, replicas=1,  # nothing ever evicts
        prefix_cache=True, eng=eng_cfg))
    tiny = EngineConfig(max_slots=2, page_size=4, pages_per_shard=2,
                        max_len=64)
    gwt = build_gateway(arch, smoke=True, c=2, data=1, replicas=1,
                        prefix_cache=True, host_tier_bytes=64 << 20,
                        eng=tiny)
    tier_out = serve_pressure(gwt)
    assert tier_out == ref_out, (
        f"host-tier reload diverged from the never-evicted serve:\n"
        f"  ref:  {ref_out}\n  tier: {tier_out}")
    tier = gwt.stats()["host_tier"]
    assert tier["spill_pages"] >= 4, tier         # the 4 family blocks
    assert tier["reload_pages"] >= 4, tier
    assert tier["hit_tokens"] >= 16 and tier["hit_rate"] > 0, tier
    # both transfer islands (read + write) compiled exactly once
    assert gwt.engines[0].transfer_xla_compiles() <= 2, \
        "transfer bucket recompiled"
    # same pressure without the tier: same tokens, but the re-arrival pays
    # full recompute (no host hits) — the tier's win is the avoided prefill
    gwo = build_gateway(arch, smoke=True, c=2, data=1, replicas=1,
                        prefix_cache=True, eng=tiny)
    off_out = serve_pressure(gwo)
    assert off_out == ref_out, "tier-off pressure serve diverged"
    assert gwo.stats()["host_tier"]["hit_tokens"] == 0


def check_gateway_replicas(arch="h2o-danube-1.8b"):
    """Acceptance (multi-replica gateway): 2 engine replicas on disjoint
    4-device C=2 submeshes; prefix-aware routing sends shared-prefix
    traffic to the replica holding the pages, session affinity pins
    sessions, and every request's tokens are bit-identical to a solo
    cold-cache run on the same replica mesh."""
    from repro.engine import EngineConfig, Request
    from repro.gateway import build_gateway

    eng_cfg = EngineConfig(max_slots=2, page_size=4, pages_per_shard=32,
                           max_len=64)
    gw = build_gateway(arch, smoke=True, c=2, data=1, replicas=2,
                       prefix_cache=True, eng=eng_cfg)
    assert len(gw.engines) == 2 and gw.plan.n_devices == 4
    assert gw.engines[0].mesh.devices.ravel()[0] != \
        gw.engines[1].mesh.devices.ravel()[0]
    rng = np.random.default_rng(5)
    vocab = gw.cfg.vocab_size
    shared = rng.integers(0, vocab, 12).tolist()
    reqs = {
        "s0": Request("s0", shared + rng.integers(0, vocab, 3).tolist(), 3,
                      seed=1),
        "s1": Request("s1", shared + rng.integers(0, vocab, 5).tolist(), 3,
                      seed=2),
        "u0": Request("u0", rng.integers(0, vocab, 14).tolist(), 3, seed=3),
        "aff": Request("aff", rng.integers(0, vocab, 9).tolist(), 3, seed=4),
    }
    r0 = gw.add_request(reqs["s0"])
    gw.step()                                     # s0 registered on r0
    assert gw.add_request(reqs["s1"]) == r0, \
        "prefix-aware routing should follow s0's cached pages"
    assert gw.add_request(reqs["u0"]) != r0, \
        "load-aware routing should spread cold traffic"
    gw.add_request(reqs["aff"], session="sess")
    out = gw.run()
    aff_replica = gw._owner["aff"]
    late = Request("aff2", reqs["aff"].tokens, 3, seed=4)
    assert gw.add_request(late, session="sess") == aff_replica, \
        "session affinity should pin the replica"
    out.update(gw.run())
    assert out["aff2"] == out["aff"], "affinity rerun diverged"
    m = gw.metrics_dict()
    assert m["prefix_hit_rate"] > 0 and m["prefill_tokens_cached"] >= 12
    assert m["affinity_hits"] == 1 and sorted(m["routed"])[-1] >= 2

    # solo cold-cache runs, pinned to the replica that served each request
    cold = build_gateway(arch, smoke=True, c=2, data=1, replicas=2,
                         prefix_cache=False, eng=eng_cfg)
    for uid, r in reqs.items():
        cold.reset()
        cold.add_request(r, replica=gw._owner[uid])
        solo = cold.run()
        assert solo[uid] == out[uid], (
            f"{uid}: gateway {out[uid]} != solo cold {solo[uid]}")


def check_gateway_disagg(arch="h2o-danube-1.8b"):
    """Acceptance (disaggregated prefill/decode): one prefill-role and one
    decode-role replica on disjoint 4-device C=2 submeshes. Prompts enter
    the prefill replica only, run prefill + the first sampled token, then
    the prompt KV hands off through the connector (device -> host ->
    device) and decode resumes on the decode replica — every stream
    bit-identical to a unified gateway on an identical 4-device mesh, and
    the decode replica never prefills a raw prompt."""
    from repro.configs import registry as arch_registry
    from repro.engine import EngineConfig, Request
    from repro.gateway import build_gateway
    from repro.plan import make_serve_plan

    eng_cfg = EngineConfig(max_slots=2, page_size=4, pages_per_shard=16,
                           max_len=64)
    gw = build_gateway(arch, smoke=True, c=2, data=1,
                       roles=["prefill", "decode"], prefix_cache=True,
                       eng=eng_cfg)
    assert gw.roles == ["prefill", "decode"]
    assert all(p.n_devices == 4 and p.c == 2 for p in gw.plans)
    assert set(gw.engines[0].mesh.devices.ravel()).isdisjoint(
        gw.engines[1].mesh.devices.ravel())

    rng = np.random.default_rng(7)
    vocab = gw.cfg.vocab_size
    reqs = [
        Request("g", rng.integers(0, vocab, 11).tolist(), 4, seed=1),
        Request("s", rng.integers(0, vocab, 17).tolist(), 5,
                temperature=0.8, top_k=8, top_p=0.9, seed=3),
        Request("one", rng.integers(0, vocab, 5).tolist(), 1, seed=4),
        Request("g2", rng.integers(0, vocab, 6).tolist(), 3, seed=5),
    ]
    owners = [gw.add_request(r) for r in reqs]
    assert owners == [0] * 4, "new requests must enter the prefill replica"
    out = gw.run()
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)
    # 'one' finished inside its prefill (budget 1): no handoff for it
    assert gw.handoffs == 3, gw.handoffs
    tier = gw.stats()["host_tier"]
    assert tier["handoff_out_pages"] == tier["handoff_in_pages"] > 0, tier
    assert gw.engines[1].metrics.prefills == 0, \
        "decode replica must never see a raw prompt"
    assert gw.engines[1].metrics.decode_steps > 0

    # unified baseline on an identical 4-device C=2 mesh
    cfg = arch_registry.get_smoke(arch)
    uplan = make_serve_plan(cfg, arch=arch, n_devices=4, c=2,
                            decode_batch=2, page_size=4, max_len=64,
                            mesh_kind="local", prefix_cache=True)
    uni = build_gateway(arch, smoke=True, eng=eng_cfg, plan=uplan)
    for r in reqs:
        uni.add_request(r)
    ref = uni.run()
    assert out == ref, (
        f"disaggregated streams diverged from the unified gateway:\n"
        f"  unified: {ref}\n  disagg:  {out}")

    # replay on the warm disaggregated gateway: same tokens, no recompiles
    compiles = [(e.metrics.prefill_compiles, e.metrics.decode_compiles,
                 e.transfer_xla_compiles()) for e in gw.engines]
    gw.reset()
    for r in reqs:
        gw.add_request(r)
    assert gw.run() == out, "disagg replay diverged"
    assert [(e.metrics.prefill_compiles, e.metrics.decode_compiles,
             e.transfer_xla_compiles()) for e in gw.engines] == compiles, \
        "disaggregated gateway recompiled on replay"


def check_chunked_prefill_dist(arch="h2o-danube-1.8b"):
    """Acceptance (chunked prefill, C=2 mesh): splitting long prompts into
    bucket-aligned chunks across driver steps emits bit-identical tokens
    to monolithic prefill on the SP-sharded paged pool. Both engines run
    kernel_impl='pallas' (interpret mode on CPU), so every suffix chunk
    exercises the Pallas paged-prefill kernel against the sharded page
    table and the dense chunk partial runs the ragged/flash kernels — with
    zero pallas->ref fallbacks; a replay on the warm chunked engine must
    add no compiles."""
    from repro.engine import EngineConfig, Request, build_engine

    common = dict(max_slots=2, page_size=4, pages_per_shard=16, max_len=64)

    def workload(vocab):
        rng = np.random.default_rng(5)
        return [
            Request(uid="long", tokens=rng.integers(0, vocab, 23).tolist(),
                    max_new_tokens=3, seed=1),
            Request(uid="short", tokens=rng.integers(0, vocab, 5).tolist(),
                    max_new_tokens=4, temperature=0.8, top_k=8, top_p=0.9,
                    seed=2),
            Request(uid="mid", tokens=rng.integers(0, vocab, 13).tolist(),
                    max_new_tokens=2, seed=3),
        ]

    outs = {}
    engines = {}
    params = None
    for mode, chunk in (("mono", 0), ("chunked", 8)):
        eng = build_engine(arch, smoke=True, c=2, data=1, kernel="pallas",
                           eng=EngineConfig(prefill_chunk=chunk, **common),
                           params=params)
        params = eng.params
        reqs = workload(eng.cfg.vocab_size)
        eng.add_request(reqs[0])
        eng.add_request(reqs[1])
        eng.step()
        eng.add_request(reqs[2])            # joins mid-stream
        outs[mode] = eng.run()
        assert eng.pallas_fallbacks() == {}, (
            f"{mode}: pallas->ref fallbacks traced: "
            f"{eng.pallas_fallbacks()}")
        engines[mode] = eng
    assert engines["chunked"].metrics.prefill_chunks > \
        engines["chunked"].metrics.prefills, "long prompts did not chunk"
    assert outs["chunked"] == outs["mono"], (
        f"chunked tokens diverged from monolithic prefill:\n"
        f"  mono:    {outs['mono']}\n  chunked: {outs['chunked']}")

    eng = engines["chunked"]
    pc, dc = eng.metrics.prefill_compiles, eng.metrics.decode_compiles
    eng.reset()
    reqs = workload(eng.cfg.vocab_size)
    eng.add_request(reqs[0])
    eng.add_request(reqs[1])
    eng.step()
    eng.add_request(reqs[2])
    assert eng.run() == outs["chunked"], "chunked replay diverged"
    assert (eng.metrics.prefill_compiles, eng.metrics.decode_compiles) == \
        (pc, dc), "chunked engine recompiled on replay"


CHECKS.update({
    "greedy_tie": check_greedy_tie,
    "engine_sampling": check_engine_sampling,
    "engine_mixed": check_engine_mixed,
    "engine_moe": check_engine_moe,
    "paged_decode_dist": check_paged_decode_dist,
    "engine_paged_kernel": check_engine_paged_kernel,
    "gateway_prefix_cow": check_gateway_prefix_cow,
    "gateway_replicas": check_gateway_replicas,
    "gateway_disagg": check_gateway_disagg,
    "chunked_prefill_dist": check_chunked_prefill_dist,
})


# ---------------------------------------------------------------------------
# plan layer: microbatched grad accumulation, scheme cross-checks, plans
# ---------------------------------------------------------------------------

def _gqa_smoke_cfg(arch="h2o-danube-1.8b", hq=8, hkv=4):
    """Smoke config with head counts Ulysses can shard at SP=4. f32 params
    so cross-scheme deltas measure reassociation, not bf16 rounding."""
    import dataclasses as dc

    from repro.configs import registry

    return dc.replace(registry.get_smoke(arch), num_heads=hq,
                      num_kv_heads=hkv, param_dtype="float32")


def check_microbatch_equiv(arch="h2o-danube-1.8b", c=2, data=2, seq=64,
                           batch=8, tol=5e-5):
    """Gradient accumulation is bit-consistent: for a fixed global batch,
    loss/grads with microbatches=4 match microbatches=1 within f32
    accumulation tolerance (acceptance criterion)."""
    import dataclasses as dc

    from repro.configs import registry
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.core import zigzag as zz
    from repro.dist import meshes
    from repro.models.factory import build_model
    from repro.train import step as train_step

    # f32 params: the mb=4-vs-mb=1 delta must be pure f32 reassociation
    # noise, not per-microbatch bf16 rounding
    cfg = dc.replace(registry.get_smoke(arch), param_dtype="float32")
    model = build_model(cfg)
    shape = ShapeConfig("test", seq_len=seq, global_batch=batch, kind="train")
    r = 8 // (data * c * c)
    mesh = meshes.local_mesh_for_tests(c=c, r=r, data=data)

    run1 = RunConfig(c=c, seq_scheme="zigzag", microbatches=1)
    run4 = dc.replace(run1, microbatches=4)
    vg1, rt = train_step.build_value_and_grad_fn(model, mesh, run1, shape)
    vg4, _ = train_step.build_value_and_grad_fn(model, mesh, run4, shape)

    params = model.init(jax.random.PRNGKey(0))
    batch_g = model.make_batch(jax.random.PRNGKey(1), shape)
    psp = c * c * r
    perm = zz.make_positions(seq, psp, rt.st_cfg.seq_scheme).reshape(-1)
    batch_s = {k: jnp.take(v, perm, axis=1) for k, v in batch_g.items()}

    l1, g1 = jax.jit(vg1)(params, batch_s)
    l4, g4 = jax.jit(vg4)(params, batch_s)
    lerr = abs(float(l1) - float(l4))
    assert lerr < tol, f"loss mb=1 {l1} vs mb=4 {l4} (err {lerr})"
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), g1, g4)
    worst = max(jax.tree.leaves(errs))
    assert worst < tol, f"grad mismatch mb=1 vs mb=4: {worst}"


def check_scheme_crosscheck(data=2, seq=64, batch=4, tol=2e-3):
    """ulysses vs startrail vs C=1 ring: losses and grads agree on the
    8-device smoke mesh for a GQA config (satellite acceptance)."""
    from repro.configs.base import ShapeConfig
    from repro.core import zigzag as zz
    from repro.models.factory import build_model
    from repro.plan import make_plan
    from repro.train import step as train_step

    cfg = _gqa_smoke_cfg()
    model = build_model(cfg)
    shape = ShapeConfig("test", seq_len=seq, global_batch=batch, kind="train")

    results = {}
    for scheme, c in (("ring", 1), ("startrail", 2), ("ulysses", 1)):
        plan = make_plan(cfg, shape, arch="gqa-test", n_devices=8, data=data,
                         scheme=scheme, c=c, mesh_kind="local")
        mesh = plan.build_mesh()
        vg, rt = train_step.build_value_and_grad_fn(
            model, mesh, plan.run_config(), shape)
        params = model.init(jax.random.PRNGKey(0))
        batch_g = model.make_batch(jax.random.PRNGKey(1), shape)
        perm = zz.make_positions(seq, plan.sp_size,
                                 rt.st_cfg.seq_scheme).reshape(-1)
        batch_s = {k: jnp.take(v, perm, axis=1) for k, v in batch_g.items()}
        loss, grads = jax.jit(vg)(params, batch_s)
        results[scheme] = (float(loss), grads)

    l_ring, g_ring = results["ring"]
    for scheme in ("startrail", "ulysses"):
        l, g = results[scheme]
        assert abs(l - l_ring) < tol, (
            f"{scheme} loss {l} vs ring {l_ring}")
        errs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))), g, g_ring)
        worst = max(jax.tree.leaves(errs))
        assert worst < tol, f"{scheme} grads vs ring: {worst}"


def check_ulysses_rejected():
    """Ulysses raises cleanly for the kv=1 (paligemma) config: at the plan
    layer (cost model) and at trace time in core/ulysses.py."""
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.plan import cost as plan_cost, make_plan

    cfg = registry.get_smoke("paligemma-3b")   # kv=1
    shape = ShapeConfig("test", seq_len=64, global_batch=4, kind="train")
    for fn in (lambda: plan_cost.check_scheme(cfg, 8, "ulysses"),
               lambda: make_plan(cfg, shape, n_devices=8, data=1,
                                 scheme="ulysses", mesh_kind="local")):
        try:
            fn()
        except ValueError as e:
            assert "head counts divisible" in str(e), e
        else:
            raise AssertionError("ulysses not rejected for kv=1 at plan level")

    # trace-time guard in core/ulysses.py (existing behaviour, kept)
    mesh = make_mesh(1, 8)
    cfg_st = st.StarTrailConfig(seq_len=32, axes=AXES,
                                seq_scheme="contiguous", causal=True)
    q = _rand(jax.random.PRNGKey(0), (1, 32, 4, 8))
    kv = _rand(jax.random.PRNGKey(1), (1, 32, 1, 8))
    spec = P(None, AXES, None, None)
    try:
        jax.jit(jax.shard_map(
            lambda q, k, v: ulysses_lib.ulysses_attention(q, k, v, cfg_st),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec,
            check_vma=False)).lower(q, kv, kv)
    except ValueError as e:
        assert "head counts divisible" in str(e), e
    else:
        raise AssertionError("core ulysses did not raise for kv=1")


def check_plan_constructs():
    """Every emitted ExecutionPlan actually constructs: for each assigned
    arch, the cost-model plan's mesh refines and the train step lowers on
    the smoke mesh (microbatched for even per-device batches)."""
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.models.factory import build_model
    from repro.optim import adamw
    from repro.plan import make_plan

    shape = ShapeConfig("test", seq_len=64, global_batch=4, kind="train")
    meshes_built = {}
    for arch in registry.ASSIGNED_ARCHS:
        cfg = registry.get_smoke(arch)
        model = build_model(cfg)
        plan = make_plan(cfg, shape, arch=arch, n_devices=8, data=2,
                         microbatches=2, mesh_kind="local")
        assert plan.sp_size == 4 and plan.c * plan.c * plan.r == 4, plan
        key = (plan.c, plan.r, plan.data)
        if key not in meshes_built:
            meshes_built[key] = plan.build_mesh()
        adam_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_dtype)
        jstep, _ = plan.build_train_step(model, adam_cfg,
                                         mesh=meshes_built[key])
        params = model.abstract()
        opt = adamw.abstract_state(params, adam_cfg)
        batch = model.input_specs(shape)
        jstep.lower(params, opt, batch)   # traces the whole island
        print(f"  plan_constructs: {arch} scheme={plan.scheme} c={plan.c} "
              f"r={plan.r} lowered", flush=True)


def check_commlog_c2(arch="h2o-danube-1.8b", seq=64):
    """obs.commlog on the C=2 smoke mesh: the compiled attention island's
    HLO collectives match the eq. 2-4 analytical wire volumes per kind
    (within the 5% gate — exactly 1.0 here), and ``CommLog.record_step``
    ticks the registry counters by precisely per_step bytes x steps."""
    from repro import obs
    from repro.configs import registry as arch_registry
    from repro.obs import commlog
    from repro.plan import cost as plan_cost
    from repro.plan import make_serve_plan

    cfg = arch_registry.get_smoke(arch)
    plan = make_serve_plan(cfg, arch=arch, n_devices=8, data=1, c=2,
                           mesh_kind="local")
    assert plan.c == 2 and plan.sp_size == 8, plan

    rep = commlog.comm_report(cfg, plan, seq_len=seq)
    assert rep["within_tolerance"], rep["per_collective"]
    live = [k for k, row in rep["per_collective"].items()
            if row["analytical_bytes"]]
    # C=2 exercises every paper term except Ulysses' all-to-all
    assert set(live) == {"all-gather", "collective-permute",
                         "reduce-scatter"}, rep["per_collective"]
    for kind in live:
        row = rep["per_collective"][kind]
        assert abs(row["ratio"] - 1.0) <= rep["tolerance"], (kind, row)
    print(f"  commlog_c2: ratios "
          f"{ {k: rep['per_collective'][k]['ratio'] for k in live} }",
          flush=True)

    # CommLog prices per-layer volumes x layers x fwd+bwd multiplier and
    # ticks the counters by exactly that per step
    reg = obs.Registry()
    log = commlog.CommLog(reg, cfg, plan, batch=2)
    per_layer = commlog.analytical_wire_volumes(cfg, plan, batch=2)
    mult = plan_cost.num_attention_layers(cfg) * log.TRAIN_STEP_MULTIPLIER
    assert log.per_step == {k: v * mult for k, v in per_layer.items()}
    steps = 3
    for _ in range(steps):
        log.record_step()
    counter = reg.get("comm_bytes_total")
    for kind, v in log.per_step.items():
        if v:
            assert counter.value(collective=kind) == v * steps, kind
    assert reg.value("comm_steps_total") == steps


def check_pipelined_bitexact(c=2, p=8, seq=64, batch=2, hq=4, hkv=2, d=8):
    """Acceptance (pipelined ring): the double-buffered scan (permute
    issued before the block kernel) and chunked ring transfers are
    *bit-identical* — np.array_equal on loss and every grad, bf16 inputs —
    to the sequential compute-then-permute baseline on the C=2 smoke mesh.
    Also covers the windowed block_skip path (where whole ring steps are
    skipped, the prefetched pack must still circulate identically)."""
    mesh = make_mesh(c, p)
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    spec = P(None, AXES, None, None)

    def run(pipeline, comm_chunks, *, scheme="zigzag", window=None,
            block_skip=False):
        cfg = st.StarTrailConfig(
            seq_len=seq, axes=AXES, seq_scheme=scheme, causal=True,
            window=window, block_skip=block_skip,
            pipeline=pipeline, comm_chunks=comm_chunks)
        dist = jax.jit(jax.shard_map(
            lambda q, k, v: st.startrail_attention(q, k, v, cfg),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec,
            check_vma=False))

        def loss(q, k, v):
            return (dist(q, k, v).astype(jnp.float32) ** 2).sum()

        q = _rand(kq, (batch, seq, hq, d), jnp.bfloat16)
        k = _rand(kk, (batch, seq, hkv, d), jnp.bfloat16)
        v = _rand(kv, (batch, seq, hkv, d), jnp.bfloat16)
        l, g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))(q, k, v)
        return np.asarray(l), [np.asarray(x) for x in g]

    l0, g0 = run(False, 1)
    for pipe, cc in ((True, 1), (True, 2), (True, 4), (False, 2)):
        l1, g1 = run(pipe, cc)
        assert np.array_equal(l0, l1), (
            f"loss differs pipeline={pipe} cc={cc}: {l0} vs {l1}")
        for name, a, b in zip("qkv", g0, g1):
            assert np.array_equal(a, b), (
                f"d{name} not bit-identical pipeline={pipe} cc={cc}")

    lw0, gw0 = run(False, 1, scheme="contiguous", window=16, block_skip=True)
    lw1, gw1 = run(True, 2, scheme="contiguous", window=16, block_skip=True)
    assert np.array_equal(lw0, lw1), "windowed skip loss differs pipelined"
    for name, a, b in zip("qkv", gw0, gw1):
        assert np.array_equal(a, b), (
            f"windowed skip d{name} not bit-identical pipelined")


def check_bwd_skip_equiv(c=2, p=8, seq=64, batch=2, hq=4, hkv=2, d=8,
                         window=16, tol=2e-5):
    """block_skip over the backward ring scan: grads with dead-block
    skipping == grads without, f32 tolerance, on the windowed contiguous
    layout where whole (Q-chunk, K-chunk) ring steps fall outside the
    attention window."""
    mesh = make_mesh(c, p)
    key = jax.random.PRNGKey(4)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (batch, seq, hq, d))
    k = _rand(kk, (batch, seq, hkv, d))
    v = _rand(kv, (batch, seq, hkv, d))
    spec = P(None, AXES, None, None)

    def run(block_skip):
        cfg = st.StarTrailConfig(
            seq_len=seq, axes=AXES, seq_scheme="contiguous", causal=True,
            window=window, block_skip=block_skip)
        dist = jax.jit(jax.shard_map(
            lambda q, k, v: st.startrail_attention(q, k, v, cfg),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec,
            check_vma=False))

        def loss(q, k, v):
            return (dist(q, k, v).astype(jnp.float32) ** 2).sum()

        l, g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))(q, k, v)
        return float(l), [np.asarray(x) for x in g]

    l_skip, g_skip = run(True)
    l_full, g_full = run(False)
    # the loss sums ~4k squared terms: bound it relatively (reassociation)
    assert abs(l_skip - l_full) < 1e-6 * max(abs(l_full), 1.0), (
        f"loss skip {l_skip} vs {l_full}")
    for name, a, b in zip("qkv", g_skip, g_full):
        e = np.abs(a - b).max()
        assert e < tol, f"d{name} skip-vs-full err {e}"


CHECKS.update({
    "microbatch_equiv": check_microbatch_equiv,
    "scheme_crosscheck": check_scheme_crosscheck,
    "ulysses_rejected": check_ulysses_rejected,
    "plan_constructs": check_plan_constructs,
    "commlog_c2": check_commlog_c2,
    "pipelined_bitexact": check_pipelined_bitexact,
    "bwd_skip_equiv": check_bwd_skip_equiv,
})

if __name__ == "__main__":
    main(sys.argv[1:])
