"""Serving caches: SP-sharded KV cache + SSM layer states.

Layouts (global shapes; shardings in brackets):
  attention : k, v (B, S_cache, Hkv, D)      [batch over data, S over SP]
  mamba     : conv (B, K-1, di)              [batch over data]
              state (B, Hm, N, P)            [batch over data]
  mlstm     : state (B, H, dk, dv+1)         [batch over data]
  slstm     : h, c (B, H, dh)                [batch over data]

SSM states are small (no sequence dim) and stay batch-sharded only; the KV
cache carries the sequence dim and shards over the SP axes (contiguous
layout). For global_batch=1 long-context decode the batch axes are empty
(replicated) — all parallelism comes from the SP-sharded cache.

Cache arrays are sized at *capacity* (a multiple of the SP degree, e.g.
``seq_len``); the decode step treats slots [0, cache_len) as filled
(cache_len = capacity - 1 for the dry-run shapes) and writes the new token
at slot ``cache_len`` on its owning SP shard.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MambaConfig, ModelConfig
from repro.dist.sharding import SP_AXES
from repro.models import transformer


def _attn_cache_spec(cfg: ModelConfig, b: int, s: int, dtype):
    hd = cfg.head_dim_
    return {
        "k": jax.ShapeDtypeStruct((b, s, cfg.num_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((b, s, cfg.num_kv_heads, hd), dtype),
    }


def _mamba_cache_spec(cfg: ModelConfig, b: int, dtype):
    m = cfg.mamba or MambaConfig()
    di = m.expand * cfg.d_model
    hm = di // m.head_dim
    return {
        "conv": jax.ShapeDtypeStruct((b, m.d_conv - 1, di), dtype),
        "state": jax.ShapeDtypeStruct((b, hm, m.d_state, m.head_dim),
                                      jnp.float32),
    }


def _mlstm_cache_spec(cfg: ModelConfig, b: int):
    dk = cfg.d_model // cfg.num_heads
    return {"state": jax.ShapeDtypeStruct(
        (b, cfg.num_heads, dk, dk + 1), jnp.float32)}


def _slstm_cache_spec(cfg: ModelConfig, b: int):
    dh = cfg.d_model // cfg.num_heads
    return {
        "h": jax.ShapeDtypeStruct((b, cfg.num_heads, dh), jnp.float32),
        "c": jax.ShapeDtypeStruct((b, cfg.num_heads, dh), jnp.float32),
    }


def cache_spec(cfg: ModelConfig, batch: int, capacity: int):
    """Abstract cache tree: {'stack': {subN: ...} period-stacked[, 'enc_out']}."""
    dtype = jnp.dtype(cfg.param_dtype)
    pat = transformer.layer_pattern(cfg)
    n_periods = cfg.num_layers // len(pat)

    def stack(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype),
            tree)

    subs = {}
    for i, (mixer, _) in enumerate(pat):
        if mixer == "attn":
            sub = _attn_cache_spec(cfg, batch, capacity, dtype)
        elif mixer == "mamba":
            sub = _mamba_cache_spec(cfg, batch, dtype)
        elif mixer == "mlstm":
            sub = _mlstm_cache_spec(cfg, batch)
        else:
            sub = _slstm_cache_spec(cfg, batch)
        subs[f"sub{i}"] = stack(sub)
    out = {"stack": subs}
    if cfg.encdec:
        out["enc_out"] = jax.ShapeDtypeStruct(
            (batch, capacity, cfg.d_model), dtype)
    return out


def cache_partition_for(cfg: ModelConfig, batch_axes: Tuple[str, ...]):
    """PartitionSpec tree matching cache_spec (leading dim = period stack)."""
    b = tuple(batch_axes) if batch_axes else None
    pat = transformer.layer_pattern(cfg)
    subs = {}
    for i, (mixer, _) in enumerate(pat):
        if mixer == "attn":
            sub = {"k": P(None, b, SP_AXES, None, None),
                   "v": P(None, b, SP_AXES, None, None)}
        elif mixer == "mamba":
            sub = {"conv": P(None, b, None, None),
                   "state": P(None, b, None, None, None)}
        elif mixer == "mlstm":
            sub = {"state": P(None, b, None, None, None)}
        else:
            sub = {"h": P(None, b, None, None), "c": P(None, b, None, None)}
        subs[f"sub{i}"] = sub
    out = {"stack": subs}
    if cfg.encdec:
        out["enc_out"] = P(b, SP_AXES, None)
    return out


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    """Concrete zero cache (smoke tests / examples)."""
    spec = cache_spec(cfg, batch, capacity)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
