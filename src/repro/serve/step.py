"""Serving: prefill and decode steps (manual SPMD, same island style as
training).

decode: one new token per sequence against the SP-sharded KV cache.
  * attention -> per-shard partial attention + global lse-combine psum
    (``core.startrail.decode_attention``): for M=1 queries the concentric
    ring degenerates to a reduction, which is the communication-optimal
    configuration.
  * mamba/mlstm/slstm -> single-step recurrences on the cached state.
  * vocab-parallel greedy sampling (local top-1 + global argmax combine;
    full logits are never gathered).

prefill: the full forward pass with cache write-out per layer (attention
K/V sharded in place; SSM states via the cross-shard-corrected final state).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import MambaConfig, ModelConfig, RunConfig, ShapeConfig
from repro.core import combine
from repro.core import startrail as st
from repro.dist import sharding as shard_rules
from repro.kernels import dispatch as kernels
from repro.models import blocks, moe as moe_lib, ssm, transformer
from repro.models.factory import Model
from repro.models.runtime import Runtime
from repro.serve import kv_cache
from repro.train import step as train_step


# ---------------------------------------------------------------------------
# per-mixer decode updates
# ---------------------------------------------------------------------------

def _attn_decode(rt: Runtime, p, x, cache, cfg: ModelConfig, cache_len,
                 paged=None, active=None):
    """x: (B, 1, D) replicated over SP.

    cache_len: static int (whole batch at one length — the classic decode
      path) or a traced (B,) int32 array of per-sequence lengths (the
      engine's continuously-batched path).
    cache: contiguous k/v slices (B, S_loc, Hkv, hd), or — when ``paged``
      is an ``engine.paged_cache.PagedTables`` — this shard's page-pool
      slices (pages_loc, page_size, Hkv, hd).
    active: optional (B,) bool; inactive slots write nothing (engine slots
      between requests).
    """
    B = x.shape[0]
    h = blocks.rmsnorm(p["norm"], x, cfg.norm_eps)
    wq = rt.dense(p["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(p["wk"], ("embed", "kv_heads", "head_dim"))
    wv = rt.dense(p["wv"], ("embed", "kv_heads", "head_dim"))
    wo = rt.dense(p["wo"], ("heads", "head_dim", "embed_out"))

    cl = jnp.asarray(cache_len, jnp.int32)
    if cl.ndim == 0:
        cl = jnp.broadcast_to(cl, (B,))
    pos_new = cl[:, None]                                       # (B, 1)
    q = blocks.rope(jnp.einsum("bsd,dhk->bshk", h, wq), pos_new, cfg.rope_theta)
    k_new = blocks.rope(jnp.einsum("bsd,dhk->bshk", h, wk), pos_new, cfg.rope_theta)
    v_new = jnp.einsum("bsd,dhk->bshk", h, wv)

    if paged is not None:
        # paged pool: write the token, then hand the page table straight to
        # the dispatch layer — the Pallas kernel indexes the pool tiles via
        # the table (no dense gather); the ref impl gathers and reuses the
        # jnp oracle. Partial (o, lse) merge across shards exactly as the
        # contiguous path does.
        from repro.engine import paged_cache as paged_lib

        new_cache, tbl = paged_lib.write_token(
            rt, cache, k_new, v_new, paged, cl, active)
        o_p, lse_p = kernels.paged_decode(
            q, new_cache["k"], new_cache["v"], tbl, cl, rt.sp_rank(),
            sp=rt.sp_size(), page_size=paged.page_size, window=cfg.window,
            impl=rt.kernel_impl)
        o = st.combine_decode_partials(
            o_p, lse_p, rt.sp_axes).astype(x.dtype)
        out = jnp.einsum("bshk,hkd->bsd", o, wo)
        return x + out, new_cache
    else:
        s_loc = cache["k"].shape[1]
        pos_k = rt.positions_contig(s_loc)
        # append the new K/V into its owning shard's slot
        local_slot = cl - (rt.sp_rank() if rt.mode == "spmd" else 0) * s_loc
        write = jnp.arange(s_loc)[None] == local_slot[:, None]  # (B, S_loc)
        if active is not None:
            write &= active[:, None]
        write = write[..., None, None]
        k_cache = jnp.where(write, k_new.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(write, v_new.astype(cache["v"].dtype), cache["v"])
        new_cache = {"k": k_cache, "v": v_cache}
        valid = pos_k[None] <= cl[:, None]                      # (B, S_loc)
        # hide unfilled slots by pushing their positions beyond the query
        pos_k = jnp.where(valid, pos_k[None], (cl + 1)[:, None])

    cfg_st = dataclasses.replace(
        rt.st_cfg, causal=True, window=cfg.window, prefix_len=None)
    if rt.mode == "local":
        o = kernels.prefill(q, k_cache, v_cache, pos_new, pos_k,
                            causal=True, window=cfg.window,
                            impl=rt.kernel_impl)
    else:
        o = st.decode_attention(q, k_cache, v_cache, pos_new, pos_k, cfg_st)
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    return x + out, new_cache


def _mamba_decode(rt: Runtime, p, x, cache, cfg: ModelConfig):
    m = cfg.mamba or MambaConfig()
    B = x.shape[0]
    D = cfg.d_model
    di = m.expand * D
    hm = di // m.head_dim
    n = m.d_state

    h = blocks.rmsnorm(p["norm_in"], x, cfg.norm_eps)
    proj = rt.dense(p["in_proj"], ("embed", "mamba_inner"))
    u = jnp.einsum("bsd,dx->bsx", h, proj)
    xin, z, Bc, Cc, dt_raw = jnp.split(
        u, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv = cache["conv"]                       # (B, K-1, di)
    window = jnp.concatenate([conv, xin], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    xc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)[:, None]
    xc = jax.nn.silu(xc)
    conv_new = window[:, 1:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,1,Hm)
    decay = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)[:, 0]
    xh = xc.reshape(B, hm, m.head_dim)
    v = xh * dt[:, 0, :, None]
    state = cache["state"]                     # (B, Hm, N, P)
    state = state * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bc[:, 0].astype(jnp.float32), v)
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), state)
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = blocks.rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    out_proj = rt.dense(p["out_proj"], ("mamba_inner", "embed_out"))
    return x + jnp.einsum("bsx,xd->bsd", y, out_proj), {
        "conv": conv_new, "state": state}


def _mlstm_decode(rt: Runtime, p, x, cache, cfg: ModelConfig):
    B = x.shape[0]
    h = blocks.rmsnorm(p["norm"], x, cfg.norm_eps)
    wq = rt.dense(p["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(p["wk"], ("embed", "heads", "head_dim"))
    wv = rt.dense(p["wv"], ("embed", "heads", "head_dim"))
    wi = rt.dense(p["wi"], ("embed", "heads"))
    wf = rt.dense(p["wf"], ("embed", "heads"))
    wo = rt.dense(p["wo"], ("heads", "head_dim", "embed_out"))
    dk = wq.shape[-1]

    q = jnp.einsum("bsd,dhk->bhk", h[:, :1], wq)[:, None][:, 0] * dk ** -0.5
    k = jnp.einsum("bsd,dhk->bhk", h[:, :1], wk)
    v = jnp.einsum("bsd,dhk->bhk", h[:, :1], wv)
    ig = jax.nn.sigmoid(jnp.einsum("bsd,dh->bh", h[:, :1], wi).astype(jnp.float32))
    f = jax.nn.sigmoid(jnp.einsum("bsd,dh->bh", h[:, :1], wf).astype(jnp.float32))

    k = k.astype(jnp.float32) * ig[..., None]
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((B, v.shape[1], 1), jnp.float32)], -1)
    state = cache["state"]                      # (B, H, dk, dv+1)
    state = state * f[..., None, None] + k[..., :, None] * v_aug[..., None, :]
    y_aug = jnp.einsum("bhk,bhkp->bhp", q.astype(jnp.float32), state)
    y, ndot = y_aug[..., :-1], y_aug[..., -1]
    y = y / jnp.maximum(jnp.abs(ndot), 1.0)[..., None]
    out = jnp.einsum("bhk,hkd->bd", y.astype(x.dtype), wo)[:, None]
    return x + out, {"state": state}


def _slstm_decode(rt: Runtime, p, x, cache, cfg: ModelConfig):
    B = x.shape[0]
    hq = cfg.num_heads
    dh = cfg.d_model // hq
    h = blocks.rmsnorm(p["norm"], x, cfg.norm_eps)
    wx = rt.dense(p["wx"], ("embed", "mamba_inner"))
    r = p["r"].astype(jnp.float32)
    wo = rt.dense(p["wo"], ("embed_nosplit", "embed_out"))

    gx = jnp.einsum("bsd,dg->bg", h[:, :1], wx).astype(jnp.float32)
    gx = gx.reshape(B, hq, 4 * dh)
    hs, cs = cache["h"], cache["c"]
    gr = jnp.einsum("bhk,hkg->bhg", hs, r)
    z, i, f, o = jnp.split(gx + gr, 4, axis=-1)
    cs = jax.nn.sigmoid(f) * cs + jax.nn.sigmoid(i) * jnp.tanh(z)
    hs = jax.nn.sigmoid(o) * jnp.tanh(cs)
    y = hs.reshape(B, 1, cfg.d_model).astype(x.dtype)
    return x + jnp.einsum("bsd,de->bse", y, wo), {"h": hs, "c": cs}


def _cross_decode(rt: Runtime, p, x, enc_out, cfg: ModelConfig):
    """Cross-attention for one decoder token vs the full encoder output."""
    h = blocks.rmsnorm(p["norm"], x, cfg.norm_eps)
    wq = rt.dense(p["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(p["wk"], ("embed", "kv_heads", "head_dim"))
    wv = rt.dense(p["wv"], ("embed", "kv_heads", "head_dim"))
    wo = rt.dense(p["wo"], ("heads", "head_dim", "embed_out"))
    q = jnp.einsum("bsd,dhk->bshk", h, wq)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, wv)
    s_loc = k.shape[1]
    pos_k = rt.positions_contig(s_loc)
    pos_q = jnp.array([0], jnp.int32)
    if rt.mode == "local":
        o = kernels.prefill(q, k, v, pos_q, pos_k, causal=False)
    else:
        cfg_st = dataclasses.replace(rt.st_cfg, causal=False, window=None)
        o = st.decode_attention(q, k, v, pos_q, pos_k, cfg_st)
    return x + jnp.einsum("bshk,hkd->bsd", o, wo)


# ---------------------------------------------------------------------------
# full decode step
# ---------------------------------------------------------------------------

def lm_decode_step(rt: Runtime, params, cache, tokens, cfg: ModelConfig,
                   cache_len, paged=None, active=None, sampling=None):
    """tokens: (B, 1) int32 (replicated across SP). Returns (next_token,
    new_cache).

    cache_len: static int, or (B,) traced per-sequence lengths (engine).
    paged: ``engine.paged_cache.PagedTables`` — attention caches are page
      pools instead of contiguous slices (SSM states stay slot-batched).
    active: (B,) bool — engine slots currently serving a request.
    sampling: None for greedy, or a dict {temperature, top_k, top_p, keys}
      of per-sequence (B,)-shaped arrays ((B, 2) for keys — PRNG keys *not*
      yet folded with the position; the fold happens here so solo and
      batched serving draw identical noise).
    """
    pat = transformer.layer_pattern(cfg)
    x = blocks.embed(rt, params["embed"], tokens, cfg, tokens_replicated=True)

    def period_fn(x, p_and_cache):
        p, c = p_and_cache
        new_c = {}
        for i, (mixer, mlp) in enumerate(pat):
            sub_p, sub_c = p[f"sub{i}"], c[f"sub{i}"]
            if mixer == "attn":
                x, nc = _attn_decode(rt, sub_p["mixer"], x, sub_c, cfg,
                                     cache_len, paged=paged, active=active)
            elif mixer == "mamba":
                x, nc = _mamba_decode(rt, sub_p["mixer"], x, sub_c, cfg)
            elif mixer == "mlstm":
                x, nc = _mlstm_decode(rt, sub_p["mixer"], x, sub_c, cfg)
            else:
                x, nc = _slstm_decode(rt, sub_p["mixer"], x, sub_c, cfg)
            new_c[f"sub{i}"] = nc
            if mlp == "mlp":
                x = blocks.mlp_block(rt, sub_p["mlp"], x, cfg)
            elif mlp == "moe":
                x, _ = moe_lib.moe_block(rt, sub_p["mlp"], x, cfg)
        return x, new_c

    n_p = jax.tree.leaves(params["stack"])[0].shape[0]
    x, new_subs = jax.lax.scan(period_fn, x, (params["stack"], cache["stack"]),
                               unroll=n_p if rt.unroll_scans else 1)
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    if sampling is None:
        next_tok = vocab_parallel_greedy(rt, head, x, cfg)
    else:
        from repro.engine import sampling as sampling_lib

        cl = jnp.asarray(cache_len, jnp.int32)
        if cl.ndim == 0:
            cl = jnp.broadcast_to(cl, (x.shape[0],))
        # key the noise by the sampled token's *position* so a request's
        # sample stream is independent of slot/step placement
        keys = jax.vmap(jax.random.fold_in)(sampling["keys"], cl + 1)
        next_tok = sampling_lib.sample(
            rt, head, x, cfg, temperature=sampling["temperature"],
            top_k=sampling["top_k"], top_p=sampling["top_p"], keys=keys,
            sc=sampling.get("sc", sampling_lib.SamplingConfig()))
    return next_tok, {"stack": new_subs}


def vocab_parallel_greedy(rt: Runtime, head_params, x, cfg: ModelConfig):
    """Greedy next token without gathering full logits: local top-1 over this
    shard's vocab slice, then a lexicographic global combine. Ties break
    toward the lowest shard (pmin over winning ranks) and the lowest local
    index — deterministically the smallest global token id among the tied
    maxima (see ``engine.sampling.lowest_shard_argmax``)."""
    from repro.engine import sampling as sampling_lib

    return sampling_lib.greedy(rt, head_params, x, cfg)


def encdec_decode_step(rt: Runtime, params, cache, tokens,
                       cfg: ModelConfig, cache_len: int):
    """Decoder-side decode step with static encoder output in the cache."""
    enc_out = cache["enc_out"]
    x = blocks.embed(rt, params["embed"], tokens, cfg, tokens_replicated=True)

    def period_fn(x, pc):
        p, c = pc
        x, nc = _attn_decode(rt, p["attn"], x, c, cfg, cache_len)
        x = _cross_decode(rt, p["cross"], x, enc_out, cfg)
        x = blocks.mlp_block(rt, p["mlp"], x, cfg)
        return x, nc

    n_p = jax.tree.leaves(params["decoder"])[0].shape[0]
    x, new_sub = jax.lax.scan(period_fn, x,
                              (params["decoder"], cache["stack"]["sub0"]),
                              unroll=n_p if rt.unroll_scans else 1)
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    next_tok = vocab_parallel_greedy(rt, params["lm_head"], x, cfg)
    return next_tok, {"stack": {"sub0": new_sub}, "enc_out": enc_out}


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

def batch_axes_for(shape: ShapeConfig, mesh, multi_pod: bool):
    """Shard batch over (pod, data) when divisible, else replicate (B=1
    long-context decode gets all its parallelism from the SP axes)."""
    axes = ("pod", "data") if multi_pod else ("data",)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if shape.global_batch % n == 0 else ()


def build_decode_step(model: Model, mesh, run_cfg: RunConfig,
                      shape: ShapeConfig):
    """Jitted decode step over the production mesh + input/cache specs."""
    cfg = model.cfg
    cache_len = shape.seq_len - 1
    b_axes = batch_axes_for(shape, mesh, run_cfg.multi_pod)
    rt = dataclasses.replace(
        train_step.make_runtime(model, run_cfg, shape, mode="spmd"),
        batch_axes=b_axes)
    # decode caches are contiguous-sharded
    rt = dataclasses.replace(
        rt, st_cfg=dataclasses.replace(rt.st_cfg, seq_scheme="contiguous"))

    param_specs = model.partition(run_cfg.sharding_rules)
    cache_specs_tree = kv_cache.cache_partition_for(cfg, b_axes)
    tok_spec = P(tuple(b_axes) if b_axes else None, None)

    if cfg.encdec:
        def island(params, cache, tokens):
            return encdec_decode_step(rt, params, cache, tokens, cfg,
                                      cache_len)
    else:
        def island(params, cache, tokens):
            return lm_decode_step(rt, params, cache, tokens, cfg, cache_len)

    fn = jax.shard_map(
        island, mesh=mesh,
        in_specs=(param_specs, cache_specs_tree, tok_spec),
        out_specs=(tok_spec, cache_specs_tree),
        check_vma=False)
    return jax.jit(fn), dict(rt=rt, cache_len=cache_len,
                             cache_specs=cache_specs_tree,
                             param_specs=param_specs, tok_spec=tok_spec)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def lm_prefill(rt: Runtime, params, batch, cfg: ModelConfig,
               prompt_len=None, return_hidden=False):
    """Full forward pass over the prompt, collecting the serving cache.

    batch: {tokens (B, S)[, frontend_emb]}. Returns (next_token, cache).
    Attention K/V stay SP-sharded in place (contiguous layout); SSM states
    come from the cross-shard-corrected final state of the last shard.

    prompt_len: optional traced (B,) int32 — real prompt lengths when the
      sequence is right-padded to a compile bucket (engine path); the
      next-token hidden state is taken from position ``prompt_len - 1``
      instead of the last slot. Causal attention makes right-padding
      harmless to every position before it.
    return_hidden: return the (B, 1, D) pre-head hidden state (replicated
      across SP) instead of a greedily sampled token, so callers can apply
      their own sampling.
    """
    pat = transformer.layer_pattern(cfg)
    tokens = batch["tokens"]
    x = blocks.embed(rt, params["embed"], tokens, cfg)
    prefix_len = None
    if cfg.frontend_stub is not None and "frontend_emb" in batch:
        prefix_len = int(cfg.prefix_len_frac * rt.st_cfg.seq_len)
        pos = rt.positions(tokens.shape[1])
        is_prefix = (pos < prefix_len)[None, :, None]
        x = jnp.where(is_prefix, batch["frontend_emb"].astype(x.dtype), x)

    def period_fn(x, p):
        caches = {}
        for i, (mixer, mlp) in enumerate(pat):
            sub = p[f"sub{i}"]
            if mixer == "attn":
                x, (k, v) = blocks.attention_block(
                    rt, sub["mixer"], x, cfg, causal=True, window=cfg.window,
                    prefix_len=prefix_len, return_kv=True)
                caches[f"sub{i}"] = {"k": k, "v": v}
            elif mixer == "mamba":
                x, st_c = ssm.mamba_block(rt, sub["mixer"], x, cfg,
                                          return_state=True)
                caches[f"sub{i}"] = st_c
            elif mixer == "mlstm":
                x, st_c = ssm.mlstm_block(rt, sub["mixer"], x, cfg,
                                          return_state=True)
                caches[f"sub{i}"] = st_c
            else:
                x, st_c = ssm.slstm_block(rt, sub["mixer"], x, cfg,
                                          return_state=True)
                caches[f"sub{i}"] = st_c
            if mlp == "mlp":
                x = blocks.mlp_block(rt, sub["mlp"], x, cfg)
            elif mlp == "moe":
                x, _ = moe_lib.moe_block(rt, sub["mlp"], x, cfg)
        return x, caches

    n_p = jax.tree.leaves(params["stack"])[0].shape[0]
    x, cache = jax.lax.scan(period_fn, x, params["stack"],
                            unroll=n_p if rt.unroll_scans else 1)
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    if prompt_len is None:
        # next token from the LAST position: the last SP shard's final slot
        # (contiguous layout); broadcast its hidden state then sample.
        last = x[:, -1:, :]
        if rt.mode == "spmd":
            is_last = rt.sp_rank() == rt.sp_size() - 1
            last = jax.lax.psum(
                jnp.where(is_last, last, jnp.zeros_like(last)), rt.sp_axes)
    else:
        # per-sequence last position prompt_len-1: exactly one (shard, slot)
        # matches, so a one-hot contraction + psum broadcasts it everywhere
        target = jnp.asarray(prompt_len, jnp.int32) - 1          # (B,)
        pos = rt.positions_contig(x.shape[1])                    # (S_loc,)
        onehot = (pos[None] == target[:, None]).astype(jnp.float32)
        last = jnp.einsum("bs,bsd->bd", onehot,
                          x.astype(jnp.float32))[:, None]
        if rt.mode == "spmd":
            last = jax.lax.psum(last, rt.sp_axes)
        last = last.astype(x.dtype)
    if return_hidden:
        return last, {"stack": cache}
    next_tok = vocab_parallel_greedy(rt, head, last, cfg)
    return next_tok, {"stack": cache}


def _attn_prefill_paged(rt: Runtime, p, x, pool_sub, cfg: ModelConfig,
                        cached_len, prompt_len, table_row, page_size: int):
    """One attention layer of the prefix-cached (suffix) prefill.

    x: (1, S_loc, D) — the prompt *suffix* (positions ``cached_len ..``),
      SP-sharded contiguously, right-padded to the compile bucket.
    pool_sub: {'k','v'} this shard's page-pool slices
      (pages_loc, page_size, Hkv, hd) for this layer.
    table_row: (P_sp, W) the slot's full page-table row (static W — the
      suffix prefill runs once per request, so unlike the decode step it
      does not bucket the table width).
    cached_len / prompt_len: traced scalars — tokens served from the prefix
      cache / real prompt length.

    The suffix attends to two disjoint key sets and the partials merge
    exactly (``core.combine``):
      * **cached prefix** — this shard's round-robin pages, read in place
        (the tokens the cache hit lets us skip); queries are all-gathered
        so each shard scores every suffix query against its own pages, and
        a psum-combine (with lse) merges the shards;
      * **suffix itself** — K/V all-gathered over SP (O(suffix), the same
        order insert_prompt already pays), scored locally per shard.
    The same gathered suffix K/V is then scattered into this shard's owned
    pages, continuing the round-robin layout from block ``cached_len/ps``.

    Attention here goes through the dispatch layer with the runtime's
    ``kernel_impl``: the cached-prefix partial dispatches to
    ``kernels.paged_prefill`` (under 'pallas' the kernel DMAs prefix K/V
    tiles straight off the page table — no dense gather), and the suffix
    self-attention partial runs the shared-position flash kernel (its
    positions are 1-D traced vectors).
    """
    B, S_loc = x.shape[0], x.shape[1]
    sp = rt.sp_size()
    rank = rt.sp_rank()
    ps = page_size
    h = blocks.rmsnorm(p["norm"], x, cfg.norm_eps)
    wq = rt.dense(p["wq"], ("embed", "heads", "head_dim"))
    wk = rt.dense(p["wk"], ("embed", "kv_heads", "head_dim"))
    wv = rt.dense(p["wv"], ("embed", "kv_heads", "head_dim"))
    wo = rt.dense(p["wo"], ("heads", "head_dim", "embed_out"))

    pos_loc = cached_len + rt.positions_contig(S_loc)           # (S_loc,)
    q = blocks.rope(jnp.einsum("bsd,dhk->bshk", h, wq), pos_loc,
                    cfg.rope_theta)
    k = blocks.rope(jnp.einsum("bsd,dhk->bshk", h, wk), pos_loc,
                    cfg.rope_theta)
    v = jnp.einsum("bsd,dhk->bshk", h, wv)

    kg = rt.all_gather_model(k, axis=1)              # (1, S_b, Hkv, hd)
    vg = rt.all_gather_model(v, axis=1)
    qg = rt.all_gather_model(q, axis=1)              # (1, S_b, Hq, hd)
    S_b = S_loc * sp
    pos_all = cached_len + jnp.arange(S_b, dtype=jnp.int32)

    # --- cached-prefix partial: every suffix query vs this shard's pages
    tbl = jax.lax.dynamic_index_in_dim(table_row, rank, axis=0,
                                       keepdims=False)          # (W,)
    W = tbl.shape[0]
    pages_loc = pool_sub["k"].shape[0]
    o_pre, lse_pre = kernels.paged_prefill(
        qg, pool_sub["k"], pool_sub["v"], tbl[None],
        jnp.reshape(cached_len, (1,)).astype(jnp.int32), rank, sp=sp,
        page_size=ps, window=cfg.window, impl=rt.kernel_impl)
    o_pre, lse_pre = st.combine_partials_with_lse(o_pre, lse_pre,
                                                  rt.sp_axes)
    lo = rank * S_loc
    o_pre = jax.lax.dynamic_slice_in_dim(o_pre, lo, S_loc, axis=1)
    lse_pre = jax.lax.dynamic_slice_in_dim(lse_pre, lo, S_loc, axis=2)

    # --- suffix self-attention partial (local queries, gathered keys)
    o_suf, lse_suf = kernels.block_fwd(
        q, kg, vg, pos_loc, pos_all, causal=True, window=cfg.window,
        impl=rt.kernel_impl)
    o, _ = combine.combine_pair(o_pre, lse_pre, o_suf, lse_suf)
    x = x + jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), wo)

    # --- scatter the suffix K/V into this shard's owned pages
    G = S_b // ps
    kb = kg[0].reshape(G, ps, *kg.shape[2:])
    vb = vg[0].reshape(G, ps, *vg.shape[2:])
    start_block = cached_len // ps                   # cached_len % ps == 0
    gidx = jnp.arange(G, dtype=jnp.int32)
    gglob = start_block + gidx
    j = gglob // sp
    page = tbl[jnp.clip(j, 0, W - 1)]
    mine = ((gglob % sp) == rank) & (gidx * ps < prompt_len - cached_len) \
        & (j < W) & (page >= 0)
    page = jnp.where(mine, page, pages_loc)          # OOB -> drop
    pool_k = pool_sub["k"].at[page].set(kb.astype(pool_sub["k"].dtype),
                                        mode="drop")
    pool_v = pool_sub["v"].at[page].set(vb.astype(pool_sub["v"].dtype),
                                        mode="drop")
    return x, {"k": pool_k, "v": pool_v}


def lm_prefill_paged(rt: Runtime, params, batch, cfg: ModelConfig, *,
                     prompt_len, cached_len, pools, table_row,
                     page_size: int):
    """Prefix-cached prefill: forward only the prompt *suffix*, reading the
    cached prefix KV from the paged pool and writing the suffix KV into the
    reserved pages. Returns ``(last_hidden, new_pools)`` with the (1, 1, D)
    hidden state of position ``prompt_len - 1`` replicated across SP.

    batch: {tokens: (1, S_bucket)} — the suffix tokens (prompt positions
      ``cached_len ..``), right-padded; prompt_len/cached_len: (1,) traced.
    pools: {'stack': {subN: {'k','v'}}} this shard's full pool slices
      (n_periods leading dim, scanned with the params).
    table_row: (P_sp, W) the admitted slot's page-table row.

    Only all-attention stacks reach this path (``paged_cache.supported``
    gates the engine), so every mixer here is 'attn'.
    """
    pat = transformer.layer_pattern(cfg)
    cl = jnp.asarray(prompt_len, jnp.int32)[0]
    cc = jnp.asarray(cached_len, jnp.int32)[0]
    tokens = batch["tokens"]
    x = blocks.embed(rt, params["embed"], tokens, cfg)

    def period_fn(x, p_and_pool):
        p, pool = p_and_pool
        new_pool = {}
        for i, (mixer, mlp) in enumerate(pat):
            assert mixer == "attn", "paged prefill covers attention mixers"
            # MoE is unreachable too: Engine rejects prefix caching for MoE
            # stacks (capacity couples prefix KV to the suffix)
            assert mlp != "moe", "prefix-cached prefill excludes MoE"
            x, new_pool[f"sub{i}"] = _attn_prefill_paged(
                rt, p[f"sub{i}"]["mixer"], x, pool[f"sub{i}"], cfg,
                cc, cl, table_row, page_size)
            if mlp == "mlp":
                x = blocks.mlp_block(rt, p[f"sub{i}"]["mlp"], x, cfg)
        return x, new_pool

    n_p = jax.tree.leaves(params["stack"])[0].shape[0]
    x, new_subs = jax.lax.scan(period_fn, x,
                               (params["stack"], pools["stack"]),
                               unroll=n_p if rt.unroll_scans else 1)
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    # last real position prompt_len-1 sits at suffix offset pl-1-cached_len:
    # one (shard, slot) matches; one-hot contraction + psum broadcasts it
    target = cl - 1 - cc
    pos = rt.positions_contig(x.shape[1])
    onehot = (pos == target).astype(jnp.float32)[None]
    last = jnp.einsum("bs,bsd->bd", onehot, x.astype(jnp.float32))[:, None]
    last = jax.lax.psum(last, rt.sp_axes).astype(x.dtype)
    return last, {"stack": new_subs}


def encdec_prefill(rt: Runtime, params, batch, cfg: ModelConfig):
    """Encoder forward + empty decoder cache (seamless serving entry)."""
    from repro.models import encdec as encdec_lib
    from jax.ad_checkpoint import checkpoint_name

    fp = rt.dense(params["frontend_proj"], ("embed_nosplit", "embed_out"))
    x = jnp.einsum("bsd,de->bse", batch["frontend_emb"].astype(fp.dtype), fp)

    def enc_body(x, p):
        x = blocks.attention_block(rt, p["attn"], x, cfg, causal=False)
        x = blocks.mlp_block(rt, p["mlp"], x, cfg)
        return x, None

    x, _ = jax.lax.scan(enc_body, x, params["encoder"])
    enc_out = blocks.rmsnorm(params["enc_norm"], x, cfg.norm_eps)
    return enc_out


def build_prefill_step(model: Model, mesh, run_cfg: RunConfig,
                       shape: ShapeConfig):
    """Jitted prefill over the production mesh."""
    cfg = model.cfg
    b_axes = batch_axes_for(shape, mesh, run_cfg.multi_pod)
    rt = dataclasses.replace(
        train_step.make_runtime(model, run_cfg, shape, mode="spmd"),
        batch_axes=b_axes)
    rt = dataclasses.replace(
        rt, st_cfg=dataclasses.replace(rt.st_cfg, seq_scheme="contiguous"))

    param_specs = model.partition(run_cfg.sharding_rules)
    seq = shard_rules.SP_AXES
    b = tuple(b_axes) if b_axes else None
    batch_specs = {"tokens": P(b, seq)}
    if cfg.frontend_stub is not None:
        batch_specs["frontend_emb"] = P(b, seq, None)
    tok_spec = P(b, None)

    if cfg.encdec:
        def island(params, batch):
            return encdec_prefill(rt, params, batch, cfg)

        out_specs = P(b, seq, None)
    else:
        def island(params, batch):
            return lm_prefill(rt, params, batch, cfg)

        cache_part = kv_cache.cache_partition_for(cfg, b_axes)
        out_specs = (tok_spec, {"stack": cache_part["stack"]})

    fn = jax.shard_map(island, mesh=mesh, in_specs=(param_specs, batch_specs),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn), dict(rt=rt, batch_specs=batch_specs,
                             param_specs=param_specs)
