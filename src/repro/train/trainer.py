"""Training loop: checkpoint/restart, straggler detection, metrics.

The loop is deliberately thin — all heavy lifting is in the jitted step —
but carries the production concerns: restore-on-start, periodic async
checkpoints, deterministic data resume, straggler watermark, and a jsonl
metrics stream.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.dist import checkpoint, elastic
from repro.models.factory import Model
from repro.optim import adamw
from repro.train import step as train_step


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    metrics_path: Optional[str] = None
    seed: int = 0


def train(model: Model, mesh, run_cfg: RunConfig, shape: ShapeConfig,
          adam_cfg: adamw.AdamWConfig, tcfg: TrainerConfig,
          data_source=None, params=None) -> Dict:
    """Run the loop; returns final metrics. Restores from ckpt_dir if a
    checkpoint exists (fault-tolerant restart)."""
    jstep, sh = train_step.build_train_step(model, mesh, run_cfg, shape,
                                            adam_cfg)
    rt = sh["rt"]
    sp_size = 1
    for a in rt.sp_axes:
        sp_size *= mesh.shape[a]

    if data_source is None:
        data_source = SyntheticLM(model.cfg, shape, seed=tcfg.seed,
                                  seq_scheme=rt.st_cfg.seq_scheme,
                                  sp_size=sp_size)

    start = 0
    if params is None:
        params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt = adamw.init_state(params, adam_cfg)
    if tcfg.ckpt_dir:
        # common step across params + opt trees: a crash between the two
        # writes leaves them one step apart, and only a step present in
        # both is a consistent restore point
        last = checkpoint.latest_common_step(
            tcfg.ckpt_dir, pathlib.Path(tcfg.ckpt_dir) / "opt")
        if last is not None:
            params = checkpoint.restore(tcfg.ckpt_dir, last, params,
                                        sh["params"])
            opt = checkpoint.restore(
                pathlib.Path(tcfg.ckpt_dir) / "opt", last, opt, sh["opt"])
            start = last
            print(f"[trainer] restored step {last}")

    params = jax.device_put(params, sh["params"])
    opt = jax.device_put(opt, sh["opt"])

    prefetch = Prefetcher(data_source, start_step=start)
    detector = elastic.StragglerDetector()
    metrics_f = open(tcfg.metrics_path, "a") if tcfg.metrics_path else None
    pending_ckpt = None
    last_metrics: Dict = {}

    try:
        for step_i in range(start, tcfg.num_steps):
            detector.step_start()
            _, batch_np = prefetch.next()
            batch = jax.device_put(batch_np, sh["batch"])
            params, opt, metrics = jstep(params, opt, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            straggling = detector.step_end()
            if straggling:
                metrics["straggler_flag"] = 1.0
            last_metrics = {"step": step_i + 1, **metrics}
            if (step_i + 1) % tcfg.log_every == 0 or step_i == start:
                print(f"[trainer] step {step_i + 1} "
                      f"loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f}", flush=True)
            if metrics_f:
                metrics_f.write(json.dumps(last_metrics) + "\n")
                metrics_f.flush()
            if tcfg.ckpt_dir and (step_i + 1) % tcfg.ckpt_every == 0:
                for t in pending_ckpt or ():
                    t.join()
                # both writes async: save() snapshots to host in this
                # thread before returning, and restore takes the latest
                # step common to both trees, so a crash mid-write only
                # costs the torn step, never consistency
                pending_ckpt = [
                    checkpoint.save(tcfg.ckpt_dir, step_i + 1, params,
                                    blocking=False),
                    checkpoint.save(pathlib.Path(tcfg.ckpt_dir) / "opt",
                                    step_i + 1, opt, blocking=False),
                ]
    finally:
        prefetch.stop()
        for t in pending_ckpt or ():
            t.join()
        if metrics_f:
            metrics_f.close()
    return last_metrics
