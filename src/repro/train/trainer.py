"""Training loop: checkpoint/restart, straggler detection, metrics.

The loop is deliberately thin — all heavy lifting is in the jitted step —
but carries the production concerns: restore-on-start, periodic async
checkpoints, deterministic data resume, straggler watermark, and a jsonl
metrics stream. The run itself is described by an
``repro.plan.ExecutionPlan``: the trainer builds its mesh, runtime and
microbatching from the plan, never from hand-assembled pieces.

Metrics stay on-device between log boundaries: converting a jax scalar to
``float`` blocks the host on the step *and* transfers it, so the loop
buffers the (tiny, replicated) metric arrays and materialises them only on
``log_every`` / checkpoint boundaries and at exit — the jsonl stream still
carries every step, just written in batches (a hard kill can lose at most
the un-flushed tail; Python-level failures flush in ``finally``). The loop
still waits on the *previous* step before dispatching past it (a one-deep
async pipeline): the device keeps computing while the host prepares the
next batch, run-ahead stays bounded, and the straggler detector keeps
measuring real step durations rather than dispatch time.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

import jax

from repro import obs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.dist import checkpoint, elastic
from repro.models.factory import Model
from repro.optim import adamw
from repro.plan.plan import ExecutionPlan


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    metrics_path: Optional[str] = None
    seed: int = 0


def train(model: Model, plan: ExecutionPlan, adam_cfg: adamw.AdamWConfig,
          tcfg: TrainerConfig, data_source=None, params=None,
          mesh=None, tracer: Optional[obs.Tracer] = None,
          registry: Optional[obs.Registry] = None) -> Dict:
    """Run the loop; returns final metrics. Restores from ckpt_dir if a
    checkpoint exists (fault-tolerant restart).

    ``tracer`` spans the loop phases (train/data, train/step, train/ckpt);
    ``registry`` additionally gets per-step analytical comm-volume
    counters (``obs.commlog.CommLog``). Both default to disabled/off.
    """
    tracer = tracer if tracer is not None else obs.NULL_TRACER
    mesh = mesh if mesh is not None else plan.build_mesh()
    shape = plan.shape_config()
    jstep, sh = plan.build_train_step(model, adam_cfg, mesh=mesh)
    rt = sh["rt"]
    sp_size = 1
    for a in rt.sp_axes:
        sp_size *= mesh.shape[a]

    if data_source is None:
        data_source = SyntheticLM(model.cfg, shape, seed=tcfg.seed,
                                  seq_scheme=rt.st_cfg.seq_scheme,
                                  sp_size=sp_size)

    start = 0
    if params is None:
        params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt = adamw.init_state(params, adam_cfg)
    if tcfg.ckpt_dir:
        # common step across params + opt trees: a crash between the two
        # writes leaves them one step apart, and only a step present in
        # both is a consistent restore point
        last = checkpoint.latest_common_step(
            tcfg.ckpt_dir, pathlib.Path(tcfg.ckpt_dir) / "opt")
        if last is not None:
            params = checkpoint.restore(tcfg.ckpt_dir, last, params,
                                        sh["params"])
            opt = checkpoint.restore(
                pathlib.Path(tcfg.ckpt_dir) / "opt", last, opt, sh["opt"])
            start = last
            print(f"[trainer] restored step {last}")

    params = jax.device_put(params, sh["params"])
    opt = jax.device_put(opt, sh["opt"])

    prefetch = Prefetcher(data_source, start_step=start)
    detector = elastic.StragglerDetector()
    metrics_f = open(tcfg.metrics_path, "a") if tcfg.metrics_path else None
    pending_ckpt = None
    last_metrics: Dict = {}
    commlog = None
    if registry is not None:
        from repro.obs.commlog import CommLog

        commlog = CommLog(registry, model.cfg, plan)
    # (step_i, on-device metrics, straggler flag, host phase timings)
    # buffered between flushes — float() conversion of the *device*
    # metrics is the only host sync in the loop; the phase timings are
    # plain perf_counter floats (the step phase measures dispatch + the
    # wait on the previous step's loss, i.e. the one-deep pipeline's
    # steady-state step duration shifted by one step — no extra sync)
    pending_metrics: List[Tuple[int, Dict, bool, Dict[str, float]]] = []

    def flush_metrics() -> Dict:
        nonlocal last_metrics
        for si, dev_m, straggling, phases in pending_metrics:
            m = {k: float(v) for k, v in dev_m.items()}
            if straggling:
                m["straggler_flag"] = 1.0
            last_metrics = {"step": si + 1, **m, **phases}
            if metrics_f:
                metrics_f.write(json.dumps(last_metrics) + "\n")
        if metrics_f and pending_metrics:
            metrics_f.flush()
        pending_metrics.clear()
        return last_metrics

    prev_loss = None
    try:
        for step_i in range(start, tcfg.num_steps):
            detector.step_start()
            t0 = time.perf_counter()
            with tracer.span("train/data", cat="train", step=step_i + 1):
                _, batch_np = prefetch.next()
                batch = jax.device_put(batch_np, sh["batch"])
            t1 = time.perf_counter()
            with tracer.span("train/step", cat="train", step=step_i + 1):
                params, opt, metrics = jstep(params, opt, batch)
                # one-deep pipeline: dispatch is async, so wait on the
                # *previous* step's (on-device, transfer-free) loss — the
                # device is already busy with this step, and the detector's
                # window sees real step durations (shifted by one step)
                if prev_loss is not None:
                    jax.block_until_ready(prev_loss)
            t2 = time.perf_counter()
            prev_loss = metrics["loss"]
            straggling = detector.step_end()
            if commlog is not None:
                commlog.record_step()
            phases = {"data_s": t1 - t0, "step_s": t2 - t1, "ckpt_s": 0.0}
            pending_metrics.append((step_i, metrics, straggling, phases))
            ckpt_boundary = (tcfg.ckpt_dir
                             and (step_i + 1) % tcfg.ckpt_every == 0)
            if ckpt_boundary:
                # before the boundary flush, so the launch cost (join the
                # previous pair + snapshot-to-host) lands in this step's
                # jsonl record
                t3 = time.perf_counter()
                with tracer.span("train/ckpt", cat="train",
                                 step=step_i + 1):
                    for t in pending_ckpt or ():
                        t.join()
                    # both writes async: save() snapshots to host in this
                    # thread before returning, and restore takes the latest
                    # step common to both trees, so a crash mid-write only
                    # costs the torn step, never consistency
                    pending_ckpt = [
                        checkpoint.save(tcfg.ckpt_dir, step_i + 1, params,
                                        blocking=False),
                        checkpoint.save(pathlib.Path(tcfg.ckpt_dir) / "opt",
                                        step_i + 1, opt, blocking=False),
                    ]
                phases["ckpt_s"] = time.perf_counter() - t3
            if ((step_i + 1) % tcfg.log_every == 0 or step_i == start
                    or ckpt_boundary or step_i + 1 == tcfg.num_steps):
                m = flush_metrics()
                if (step_i + 1) % tcfg.log_every == 0 or step_i == start:
                    print(f"[trainer] step {step_i + 1} "
                          f"loss={m['loss']:.4f} "
                          f"gnorm={m['grad_norm']:.3f}", flush=True)
    finally:
        prefetch.stop()
        flush_metrics()
        for t in pending_ckpt or ():
            t.join()
        if metrics_f:
            metrics_f.close()
    return last_metrics
