"""Train-step builder: one shard_map island for loss+grads, AdamW outside.

The island is the whole model forward/backward (manual SPMD: StarTrail
attention rings, FSDP gathers, vocab-parallel CE, MoE all-to-alls — every
collective explicit). Gradients leave the island fully reduced (all_gather
transposes reduce-scatter over ``data``; replicated params psum over the
batch axes, including ``pod`` — so only the gradient reduction crosses the
DCI boundary, overlapped by XLA with backward compute). The optimizer is
pure elementwise on identically-sharded trees (ZeRO: every moment stays
shard-local).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.startrail import StarTrailConfig
from repro.dist import sharding as shard_rules
from repro.models.factory import Model
from repro.models.runtime import Runtime
from repro.optim import adamw
from repro.optim import grad as grad_lib


def make_runtime(model: Model, run_cfg: RunConfig, shape: ShapeConfig,
                 mode: str = "spmd") -> Runtime:
    cfg = model.cfg
    scheme = run_cfg.seq_scheme
    if cfg.family in ("ssm", "hybrid"):
        scheme = "contiguous"   # SSM state passing needs contiguity
    st = StarTrailConfig(
        seq_len=shape.seq_len,
        seq_scheme=scheme,
        causal=True,
        window=cfg.window,
        block_impl=run_cfg.block_impl,
        block_skip=run_cfg.block_skip or (cfg.window is not None
                                          and scheme == "contiguous"),
        unroll=run_cfg.unroll_scans,
    )
    batch_axes = ("pod", "data") if run_cfg.multi_pod else ("data",)
    return Runtime(mode=mode, st_cfg=st, batch_axes=batch_axes,
                   rules=run_cfg.sharding_rules,
                   unroll_scans=run_cfg.unroll_scans)


def batch_partition(model: Model, rt: Runtime):
    seq = shard_rules.SP_AXES
    b = tuple(rt.batch_axes)
    specs = {
        "tokens": P(b, seq),
        "labels": P(b, seq),
    }
    if model.cfg.frontend_stub is not None:
        specs["frontend_emb"] = P(b, seq, None)
    return specs


def build_train_step(model: Model, mesh, run_cfg: RunConfig,
                     shape: ShapeConfig, adam_cfg: adamw.AdamWConfig):
    """Returns (jitted_step, shardings) with
    step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    rt = make_runtime(model, run_cfg, shape)
    param_specs = model.partition(run_cfg.sharding_rules)
    batch_specs = batch_partition(model, rt)

    def island(params, batch):
        return model.loss(rt, params, batch, remat=run_cfg.remat)

    loss_fn = jax.shard_map(
        island, mesh=mesh,
        in_specs=(param_specs, batch_specs),
        out_specs=P(),
        check_vma=False,
    )

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if run_cfg.grad_compression == "int8":
            grads = grad_lib.int8_roundtrip(grads)
        params, opt_state, metrics = adamw.apply(params, grads, opt_state,
                                                 adam_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs)
    opt_sh = adamw.state_partition(params_sh)
    opt_sh["step"] = NamedSharding(mesh, P())
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs)
    metrics_sh = None  # replicated scalars

    jstep = jax.jit(
        step,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )
    return jstep, dict(params=params_sh, opt=opt_sh, batch=batch_sh, rt=rt)


def build_loss_fn(model: Model, mesh, run_cfg: RunConfig, shape: ShapeConfig):
    """Loss-only island (used by eval and the dry-run)."""
    rt = make_runtime(model, run_cfg, shape)
    param_specs = model.partition(run_cfg.sharding_rules)
    batch_specs = batch_partition(model, rt)

    def island(params, batch):
        return model.loss(rt, params, batch, remat=run_cfg.remat)

    return jax.shard_map(
        island, mesh=mesh, in_specs=(param_specs, batch_specs),
        out_specs=P(), check_vma=False), rt
