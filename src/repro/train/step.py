"""Train-step builder: one shard_map island for loss+grads, AdamW outside.

The island is the whole model forward/backward (manual SPMD: StarTrail
attention rings, FSDP gathers, vocab-parallel CE, MoE all-to-alls — every
collective explicit). Gradients leave the island fully reduced (all_gather
transposes reduce-scatter over ``data``; replicated params psum over the
batch axes, including ``pod`` — so only the gradient reduction crosses the
DCI boundary, overlapped by XLA with backward compute). The optimizer is
pure elementwise on identically-sharded trees (ZeRO: every moment stays
shard-local).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.startrail import StarTrailConfig
from repro.dist import sharding as shard_rules
from repro.models.factory import Model
from repro.models.runtime import Runtime
from repro.optim import adamw
from repro.optim import grad as grad_lib


def make_runtime(model: Model, run_cfg: RunConfig, shape: ShapeConfig,
                 mode: str = "spmd") -> Runtime:
    cfg = model.cfg
    scheme = run_cfg.seq_scheme
    if cfg.family in ("ssm", "hybrid"):
        scheme = "contiguous"   # SSM state passing needs contiguity
    st = StarTrailConfig(
        seq_len=shape.seq_len,
        seq_scheme=scheme,
        causal=True,
        window=cfg.window,
        block_impl=run_cfg.block_impl,
        block_skip=run_cfg.block_skip or (cfg.window is not None
                                          and scheme == "contiguous"),
        unroll=run_cfg.unroll_scans,
        pipeline=run_cfg.pipeline_scan,
        comm_chunks=run_cfg.comm_chunks,
    )
    batch_axes = ("pod", "data") if run_cfg.multi_pod else ("data",)
    # 'ring' is the C=1 degenerate StarTrail config; 'ulysses' dispatches
    # per-layer in Runtime.attention (head-count permitting)
    impl = "ulysses" if run_cfg.attention_scheme == "ulysses" else "startrail"
    return Runtime(mode=mode, st_cfg=st, batch_axes=batch_axes,
                   rules=run_cfg.sharding_rules, attention_impl=impl,
                   kernel_impl=run_cfg.kernel_impl,
                   unroll_scans=run_cfg.unroll_scans)


def batch_partition(model: Model, rt: Runtime):
    seq = shard_rules.SP_AXES
    b = tuple(rt.batch_axes)
    specs = {
        "tokens": P(b, seq),
        "labels": P(b, seq),
    }
    if model.cfg.frontend_stub is not None:
        specs["frontend_emb"] = P(b, seq, None)
    return specs


def _mentioned_axes(spec) -> set:
    out = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.update(entry)
        else:
            out.add(entry)
    return out


def _make_vg_island(model: Model, mesh, run_cfg: RunConfig, rt: Runtime,
                    param_specs, batch_specs):
    """shard_map island computing (loss, grads) — forward AND backward run
    inside one manual-SPMD region.

    Differentiating *inside* the island (rather than ``jax.grad`` around the
    shard_map) keeps every AD residual local to the region, which older jax
    requires (its shard_map partial-eval rule cannot shard scalar residuals
    crossing the boundary) and which is the intended design anyway: the
    compiler sees one fused fwd+bwd program per device.

    Reduction convention (matches shard_map's own transpose): the loss is
    replicated (every path runs through a psum over all mesh axes), so the
    per-device cotangent seed is 1/n_devices and each gradient leaf is
    psum'd over the mesh axes its PartitionSpec does not mention — FSDP
    leaves already reduce-scattered by the all_gather transposes, replicated
    leaves (norm scales, routers) summed over batch + SP axes, including
    ``pod``.

    With ``run_cfg.microbatches > 1`` the island runs gradient accumulation:
    a ``jax.lax.scan`` over equal microbatch slices of the per-device batch,
    f32 grad accumulators, loss averaged — so the global batch no longer has
    to fit in one step. The accumulation is in f32 regardless of the param
    dtype, which keeps microbatches=M within f32 reassociation noise of
    microbatches=1 (asserted by the `microbatch_equiv` dist check).
    """
    n_dev = mesh.size
    mb = max(run_cfg.microbatches, 1)

    def island(params, batch):
        def loss_fn(p, b):
            return model.loss(rt, p, b, remat=run_cfg.remat)

        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                if x.shape[0] % mb:
                    raise ValueError(
                        f"per-device batch {x.shape[0]} not divisible by "
                        f"microbatches={mb}")
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            def body(carry, mbatch):
                loss_acc, g_acc = carry
                l_mb, g_mb = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, g_mb)
                return (loss_acc + l_mb.astype(jnp.float32), g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, g_sum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0),
                jax.tree.map(split, batch))
            loss = loss_sum / mb
            grads = jax.tree.map(lambda g: g / mb, g_sum)

        inv = 1.0 / n_dev

        def reduce_leaf(g, p, spec):
            g32 = g.astype(jnp.float32) * inv
            unmentioned = tuple(a for a in mesh.axis_names
                                if a not in _mentioned_axes(spec))
            if unmentioned:  # reduce in f32, downcast once at the end
                g32 = jax.lax.psum(g32, unmentioned)
            return g32.astype(p.dtype)

        grads = jax.tree.map(reduce_leaf, grads, params, param_specs)
        return loss, grads

    return jax.shard_map(
        island, mesh=mesh,
        in_specs=(param_specs, batch_specs),
        out_specs=(P(), param_specs),
        check_vma=False,
    )


def build_value_and_grad_fn(model: Model, mesh, run_cfg: RunConfig,
                            shape: ShapeConfig):
    """Returns (vg_fn, rt) with vg_fn(params, batch) -> (loss, grads), the
    fwd+bwd island of `_make_vg_island` (used standalone by dist_checks)."""
    rt = make_runtime(model, run_cfg, shape)
    param_specs = model.partition(run_cfg.sharding_rules)
    batch_specs = batch_partition(model, rt)
    return _make_vg_island(model, mesh, run_cfg, rt, param_specs,
                           batch_specs), rt


def build_train_step(model: Model, mesh, run_cfg: RunConfig,
                     shape: ShapeConfig, adam_cfg: adamw.AdamWConfig):
    """Returns (jitted_step, shardings) with
    step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    rt = make_runtime(model, run_cfg, shape)
    param_specs = model.partition(run_cfg.sharding_rules)
    batch_specs = batch_partition(model, rt)
    vg_fn = _make_vg_island(model, mesh, run_cfg, rt, param_specs,
                            batch_specs)

    def step(params, opt_state, batch):
        loss, grads = vg_fn(params, batch)
        if run_cfg.grad_compression == "int8":
            grads = grad_lib.int8_roundtrip(grads)
        params, opt_state, metrics = adamw.apply(params, grads, opt_state,
                                                 adam_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs)
    opt_sh = adamw.state_partition(params_sh)
    opt_sh["step"] = NamedSharding(mesh, P())
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs)
    metrics_sh = None  # replicated scalars

    jstep = jax.jit(
        step,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )
    return jstep, dict(params=params_sh, opt=opt_sh, batch=batch_sh, rt=rt)


def build_loss_fn(model: Model, mesh, run_cfg: RunConfig, shape: ShapeConfig):
    """Loss-only island (used by eval and the dry-run)."""
    rt = make_runtime(model, run_cfg, shape)
    param_specs = model.partition(run_cfg.sharding_rules)
    batch_specs = batch_partition(model, rt)

    def island(params, batch):
        return model.loss(rt, params, batch, remat=run_cfg.remat)

    return jax.shard_map(
        island, mesh=mesh, in_specs=(param_specs, batch_specs),
        out_specs=P(), check_vma=False), rt
