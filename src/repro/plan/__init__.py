"""Execution-plan layer: resolved run descriptions + arrangement tuning.

``ExecutionPlan`` (plan.py) is the single source of truth every entry point
builds its mesh + runtime from; ``cost`` ranks the legal (C, R) / scheme
arrangements analytically (paper eqs. 2-4); ``autotune`` refines the top of
the ranking with measured steps and persists the winner. See docs/TUNING.md.
"""

from repro.plan import autotune, cost
from repro.plan.plan import (ExecutionPlan, make_plan, make_role_plans,
                             make_serve_plan, plan_path)

__all__ = ["ExecutionPlan", "make_plan", "make_role_plans",
           "make_serve_plan", "plan_path", "cost", "autotune"]
