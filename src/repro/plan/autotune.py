"""Measured refinement of the analytical arrangement ranking.

The cost model ranks every legal (scheme, C, placement) arrangement; this
module wall-clocks the top-k candidates (plus the analytical worst, as a
sanity anchor) with short jitted train steps and persists the measured
winner to ``results/PLAN_<arch>_<shape>.json``. On real hardware the same
search runs on the production mesh; on CPU it runs on the forced-host smoke
mesh, which is what the `plan-smoke` CI job and
``benchmarks/throughput.py --compare-arrangements`` exercise.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.plan import cost
from repro.plan.plan import ExecutionPlan, make_plan, plan_path

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def measure_plan(model, plan: ExecutionPlan, *, steps: int = 3,
                 warmup: int = 1, adam_cfg=None, mesh=None) -> float:
    """Median wall-clock seconds of the jitted train step under `plan`."""
    import jax

    from repro.core import zigzag as zz
    from repro.optim import adamw

    adam_cfg = adam_cfg or adamw.AdamWConfig(warmup_steps=0)
    jstep, sh = plan.build_train_step(model, adam_cfg, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params, adam_cfg)
    batch = model.make_batch(jax.random.PRNGKey(1), plan.shape_config())
    perm = zz.make_positions(plan.seq_len, plan.sp_size,
                             plan.run_config().seq_scheme).reshape(-1)
    batch = {k: np.take(np.asarray(v), perm, axis=1)
             for k, v in batch.items()}
    params = jax.device_put(params, sh["params"])
    opt = jax.device_put(opt, sh["opt"])
    batch = jax.device_put(batch, sh["batch"])

    for _ in range(max(warmup, 1)):
        params, opt, metrics = jstep(params, opt, batch)
    jax.block_until_ready(metrics)
    times = []
    for _ in range(max(steps, 1)):
        t0 = time.perf_counter()
        params, opt, metrics = jstep(params, opt, batch)
        jax.block_until_ready(metrics)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def autotune(cfg: ModelConfig, shape: ShapeConfig, *, arch: str,
             n_devices: int, data: int = 1, mesh_kind: str = "local",
             top_k: int = 3, steps: int = 3, microbatches: Optional[int] = 1,
             out_dir=None, cluster=None,
             arrangements: Optional[Sequence[cost.Arrangement]] = None,
             overlap_frac: float = 1.0,
             comm_chunk_grid: Sequence[int] = (1,),
             ) -> Dict[str, object]:
    """Measure the analytical top-k (plus the analytical worst) and persist
    the winner.

    Returns {"plan": ExecutionPlan, "measured": [...], "analytical": [...],
    "path": written json path}. The measured list is sorted fastest-first;
    the winner is by construction never the slowest measured arrangement.

    ``overlap_frac`` parameterizes the analytical overlap model used for
    the ranking (pass the measured fraction from
    ``obs.commlog.overlap_report``); ``comm_chunk_grid`` widens the search
    to sub-chunked ring transfers — each candidate arrangement is measured
    once per legal grid entry (illegal entries, i.e. chunk counts that do
    not divide the team sequence length, are skipped).
    """
    from repro.models.factory import build_model

    model = build_model(cfg)
    sp = n_devices // data
    ranking = cost.rank_arrangements(
        cfg, shape, sp, batch=max(shape.global_batch // data, 1),
        cluster=cluster, arrangements=arrangements,
        overlap_frac=overlap_frac)
    cands = list(ranking[:top_k])
    if ranking[-1] not in cands:
        cands.append(ranking[-1])   # anchor: the analytical worst

    mesh_cache = {}
    measured: List[Dict[str, object]] = []
    for entry in cands:
        arr: cost.Arrangement = entry["arrangement"]
        for n_chunks in dict.fromkeys(comm_chunk_grid):
            s_team = arr.c * shape.seq_len // sp
            if arr.scheme == "ulysses" and n_chunks > 1:
                continue            # no ring scan to chunk
            if n_chunks > 1 and s_team % n_chunks:
                continue
            try:
                plan = make_plan(
                    cfg, shape, arch=arch, n_devices=n_devices, data=data,
                    scheme=arr.scheme, c=arr.c,
                    placement=arr.placement if arr.c > 1 else None,
                    microbatches=microbatches, mesh_kind=mesh_kind,
                    comm_chunks=n_chunks, overlap_frac=overlap_frac,
                    cluster=cluster)
            except ValueError:
                continue
            key = (plan.c, plan.r, plan.data)
            if key not in mesh_cache:
                mesh_cache[key] = plan.build_mesh()
            t = measure_plan(model, plan, steps=steps, mesh=mesh_cache[key])
            measured.append({"arrangement": arr, "plan": plan,
                             "comm_chunks": n_chunks,
                             "measured_s": t,
                             "analytical_s": entry["total_s"]})
    measured.sort(key=lambda e: e["measured_s"])
    winner: ExecutionPlan = measured[0]["plan"]

    out_dir = pathlib.Path(out_dir) if out_dir is not None else RESULTS
    path = plan_path(out_dir, arch, shape.name)
    record = {
        "plan": winner.to_dict(),
        "measured": [{"arrangement": e["arrangement"].key,
                      "comm_chunks": e.get("comm_chunks", 1),
                      "measured_s": e["measured_s"],
                      "analytical_s": e["analytical_s"]} for e in measured],
        "overlap_frac": overlap_frac,
        "analytical": [{"arrangement": e["arrangement"].key,
                        "total_s": e["total_s"],
                        "volumes": e["volumes"]} for e in ranking],
        "n_devices": n_devices, "data": data, "steps_timed": steps,
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=2))
    return {"plan": winner, "measured": measured, "analytical": ranking,
            "path": path}
