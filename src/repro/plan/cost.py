"""Analytical cost model for communication arrangements (paper eqs. 2-4).

For a fixed sequence-parallel degree P there is a whole family of legal
communication arrangements: the StarTrail (C, R) factorisations with
P = C^2 * R and either axis placement, the plain ring (C = 1), and the
DeepSpeed-Ulysses all-to-all scheme (legal only while P <= Hkv — the
head-count scalability limit `core/ulysses.py` enforces at trace time).

This module enumerates the legal arrangements for a ModelConfig/ShapeConfig
pair, prices each one with the paper's per-arrangement communication-volume
formulas (team all-gather, sub-ring ppermute bytes, reduce-scatter combine;
all-to-all for Ulysses) on top of the `roofline/hw.py` constants, and ranks
them. `repro.plan.plan` turns the winner into an `ExecutionPlan`;
`repro.plan.autotune` refines the top of the ranking with measured runs.

Volumes are implementation-exact per device per attention layer (they match
what `benchmarks/comm_volume.py` parses out of the compiled HLO); times come
from `core/scheduler.py`'s overlap model so the ranking agrees with the
paper-§3.4 topology scheduler at C > 1.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import scheduler as sch
from repro.core.topology import valid_c_values
from repro.roofline import hw

SCHEMES = ("startrail", "ring", "ulysses")


@dataclasses.dataclass(frozen=True)
class Arrangement:
    """One point of the (scheme, C, placement) tuning space at fixed P."""

    scheme: str                 # 'startrail' | 'ring' (C=1) | 'ulysses'
    c: int
    r: int
    placement: str = "team_inner"

    @property
    def key(self) -> str:
        if self.scheme == "startrail":
            return f"startrail_c{self.c}_{self.placement}"
        return self.scheme


def num_attention_layers(cfg: ModelConfig) -> int:
    n = sum(1 for i in range(cfg.num_layers) if cfg.mixer_on_layer(i) == "attn")
    if cfg.encdec:
        n += cfg.num_encoder_layers + cfg.num_layers  # self + cross attention
    return n


def ulysses_supported(cfg: ModelConfig, sp: int) -> bool:
    """Ulysses heads-divisibility limit: SP must divide Hq and Hkv."""
    return cfg.num_heads % sp == 0 and cfg.num_kv_heads % sp == 0


def check_scheme(cfg: ModelConfig, sp: int, scheme: str) -> None:
    """Raise (with the same wording as core/ulysses.py) for illegal schemes."""
    if scheme not in SCHEMES:
        raise ValueError(f"scheme must be one of {SCHEMES}, got {scheme!r}")
    if scheme == "ulysses" and not ulysses_supported(cfg, sp):
        raise ValueError(
            f"Ulysses requires head counts divisible by SP degree: "
            f"Hq={cfg.num_heads}, Hkv={cfg.num_kv_heads}, SP={sp} "
            f"(the paper's scalability limit)")


def enumerate_arrangements(cfg: ModelConfig, sp: int) -> List[Arrangement]:
    """All legal arrangements at sequence-parallel degree `sp`."""
    out: List[Arrangement] = []
    for c in valid_c_values(sp):
        r = sp // (c * c)
        if c == 1:
            out.append(Arrangement("ring", 1, r))
        else:
            for placement in ("team_inner", "ring_inner"):
                out.append(Arrangement("startrail", c, r, placement))
    if ulysses_supported(cfg, sp):
        out.append(Arrangement("ulysses", 1, sp))
    return out


# ---------------------------------------------------------------------------
# Per-device communication volumes (bytes, one attention layer, forward)
# ---------------------------------------------------------------------------

def comm_volumes(cfg: ModelConfig, shape: ShapeConfig, sp: int,
                 arr: Arrangement, *, batch: Optional[int] = None,
                 dtype_bytes: int = 2) -> Dict[str, float]:
    """Implementation-exact per-device bytes for one attention layer.

    StarTrail (paper eqs. 3-4, with this implementation's R ring permutes —
    the chunks tour the full ring so the backward reuses the placement):

      team all-gather:    (C-1) * B * N/P * (Hq + 2*Hkv) * dh * bytes
      placement ppermute: 2 * B * (C*N/P) * Hkv * dh * bytes      (Alg. 2)
      sub-ring ppermute:  R  * [the same chunk]                   (eq. 4)
      reduce-scatter:     (C-1) * B * N/P * Hq * dh * 4           (f32 combine)

    Ring is the C=1 degenerate point (no team collectives). Ulysses is the
    two all-to-all pairs: q/k/v seq->head then o head->seq, each moving
    (P-1)/P of the local tensor.
    """
    b = shape.global_batch if batch is None else batch
    n = shape.seq_len
    dh = cfg.head_dim_
    q_h = cfg.num_heads * dh
    kv_h = cfg.num_kv_heads * dh
    s_local = n / sp
    c, r = arr.c, arr.r

    if arr.scheme == "ulysses":
        a2a = (sp - 1) / sp * b * s_local * (2 * q_h + 2 * kv_h) * dtype_bytes
        return {"team_allgather": 0.0, "placement_p2p": 0.0,
                "ring_p2p": 0.0, "combine_rs": 0.0, "all_to_all": a2a,
                "total": a2a}

    chunk = 2 * b * (c * s_local) * kv_h * dtype_bytes   # one team's K/V
    vols = {
        "team_allgather": (c - 1) * b * s_local * (q_h + 2 * kv_h) * dtype_bytes,
        "placement_p2p": chunk if c > 1 else 0.0,
        "ring_p2p": r * chunk,
        "combine_rs": (c - 1) * b * s_local * q_h * 4.0,
        "all_to_all": 0.0,
    }
    vols["total"] = sum(vols.values())
    return vols


# ---------------------------------------------------------------------------
# Time model (delegates to the §3.4 scheduler for ring/startrail)
# ---------------------------------------------------------------------------

def _workload(cfg: ModelConfig, shape: ShapeConfig, batch: int) -> sch.AttnWorkload:
    return sch.AttnWorkload(
        batch=max(batch, 1), seq_len=shape.seq_len, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
        causal=(cfg.prefix_len_frac == 0.0))


def arrangement_time(cfg: ModelConfig, shape: ShapeConfig, sp: int,
                     arr: Arrangement, *, batch: Optional[int] = None,
                     cluster: Optional[sch.ClusterModel] = None,
                     overlap_frac: float = 1.0,
                     comm_chunks: int = 1) -> float:
    """Estimated seconds for one attention layer under `arr`.

    ``overlap_frac``/``comm_chunks`` parameterize the ring-scheme overlap
    model (`core/scheduler.attention_step_cost`): pass the measured
    fraction from ``obs.commlog.overlap_report`` so the ranking stops
    over-promising on bandwidth-bound shapes.
    """
    b = shape.global_batch if batch is None else batch
    w = _workload(cfg, shape, b)
    cl = cluster or sch.ClusterModel(sp_size=sp)
    if arr.scheme in ("ring", "startrail"):
        return sch.attention_step_cost(
            w, cl, arr.c, arr.placement, overlap_frac=overlap_frac,
            comm_chunks=comm_chunks)["total_s"]
    # Ulysses: fully-local attention between two all-to-all pairs; the
    # all-to-alls cannot overlap with the attention itself.
    vols = comm_volumes(cfg, shape, sp, arr, batch=b,
                        dtype_bytes=w.dtype_bytes)
    causal_frac = 0.5 if w.causal else 1.0
    flops = 4.0 * w.batch * w.seq_len * w.seq_len * w.num_heads \
        * w.head_dim * causal_frac / sp
    return flops / cl.peak_flops + vols["all_to_all"] / cl.link_bw \
        + 2 * cl.step_latency


def rank_arrangements(cfg: ModelConfig, shape: ShapeConfig, sp: int, *,
                      batch: Optional[int] = None,
                      cluster: Optional[sch.ClusterModel] = None,
                      arrangements: Optional[Sequence[Arrangement]] = None,
                      overlap_frac: float = 1.0,
                      comm_chunks: int = 1,
                      ) -> List[Dict[str, object]]:
    """All legal arrangements priced and sorted fastest-first.

    Each entry: {"arrangement": Arrangement, "total_s": float,
    "volumes": per-layer byte breakdown, "model_s": whole-model estimate}.
    ``overlap_frac`` (measured via ``obs.commlog.overlap_report``) and
    ``comm_chunks`` parameterize the ring overlap model.
    """
    cands = list(arrangements) if arrangements is not None \
        else enumerate_arrangements(cfg, sp)
    n_attn = max(num_attention_layers(cfg), 1)
    out = []
    for arr in cands:
        t = arrangement_time(cfg, shape, sp, arr, batch=batch,
                             cluster=cluster, overlap_frac=overlap_frac,
                             comm_chunks=comm_chunks)
        out.append({
            "arrangement": arr,
            "total_s": t,
            "model_s": t * n_attn,
            "volumes": comm_volumes(cfg, shape, sp, arr, batch=batch),
        })
    out.sort(key=lambda e: e["total_s"])
    return out


def choose_comm_chunks(cfg: ModelConfig, shape: ShapeConfig, sp: int,
                       arr: Arrangement, *, batch: Optional[int] = None,
                       cluster: Optional[sch.ClusterModel] = None,
                       overlap_frac: float = 1.0,
                       grid: Sequence[int] = (1, 2, 4)) -> int:
    """Resolve the ring-transfer sub-chunk count for one arrangement.

    Argmin of the overlap model over ``grid``, constrained to chunk counts
    that divide the per-device team sequence length (c * N / P — the axis
    `core/startrail._chunked_ppermute` splits). Non-ring schemes have no
    transfers to chunk -> 1.
    """
    if arr.scheme not in ("ring", "startrail"):
        return 1
    s_team = arr.c * shape.seq_len // sp
    legal = tuple(n for n in grid if n >= 1 and s_team % n == 0) or (1,)
    b = shape.global_batch if batch is None else batch
    w = _workload(cfg, shape, b)
    cl = cluster or sch.ClusterModel(sp_size=sp)
    return sch.choose_comm_chunks(w, cl, arr.c, arr.placement,
                                  overlap_frac=overlap_frac, grid=legal)


# ---------------------------------------------------------------------------
# Serving decode-step cost (paged-kernel vs page-gather bytes)
# ---------------------------------------------------------------------------

DECODE_KERNELS = ("ref", "pallas")


def decode_step_cost(cfg: ModelConfig, *, batch: int, cache_len: int,
                     sp: int, page_size: int, kernel: str = "ref",
                     dtype_bytes: int = 2,
                     cluster: Optional[sch.ClusterModel] = None
                     ) -> Dict[str, float]:
    """Per-device cost of one decode step's attention, all layers.

    Decode is bandwidth-bound: the FLOPs (one M=1 query against the cache)
    are identical for both kernels, but the **bytes through HBM** differ.
    Both paths walk the *bucketed* per-shard table width (the engine
    buckets ``W`` to powers of two, so reserved-but-unfilled entries are
    touched too — `pl.when` skips their FLOPs, not their DMA):

      * ``kernel='pallas'`` (paged kernel) streams each table-indexed K/V
        page exactly once, DMA'd straight from the pool — one pass over
        ``2 * Hkv * dh * W_bucket * page_size`` bytes per sequence per
        layer.
      * ``kernel='ref'`` (page gather) makes three passes over the same
        width: read the pool pages, write the dense per-shard cache copy,
        then stream the dense copy into the attention.

    Returns {'flops', 'bytes', 'flops_s', 'bytes_s', 'total_s'} summed over
    the attention layers. This is the model behind defaulting
    ``kernel_impl='pallas'`` on TPU; `benchmarks/serving_load.py` reports
    the measured per-kernel tokens/s next to it.
    """
    if kernel not in DECODE_KERNELS:
        raise ValueError(f"kernel must be one of {DECODE_KERNELS}, "
                         f"got {kernel!r}")
    cl = cluster or sch.ClusterModel(sp_size=sp)
    n_attn = max(num_attention_layers(cfg), 1)
    dh = cfg.head_dim_
    keys_local = -(-cache_len // sp)                 # ceil: per-shard keys
    pages_local = -(-keys_local // page_size)
    w_bucket = 1
    while w_bucket < pages_local:                    # engine pow2 bucketing
        w_bucket *= 2
    bucket_bytes = batch * w_bucket * page_size * 2 * cfg.num_kv_heads \
        * dh * dtype_bytes
    flops = 4.0 * batch * keys_local * cfg.num_heads * dh
    if kernel == "pallas":
        bytes_moved = bucket_bytes
    else:
        bytes_moved = 3.0 * bucket_bytes             # gather out + in, + read
    flops_s = n_attn * flops / cl.peak_flops
    bytes_s = n_attn * bytes_moved / hw.HBM_BW
    return {"flops": n_attn * flops, "bytes": n_attn * bytes_moved,
            "flops_s": flops_s, "bytes_s": bytes_s,
            "total_s": max(flops_s, bytes_s)}


def rank_decode_kernels(cfg: ModelConfig, *, batch: int, cache_len: int,
                        sp: int, page_size: int,
                        cluster: Optional[sch.ClusterModel] = None
                        ) -> List[Dict[str, object]]:
    """Both decode kernels priced and sorted fastest-first."""
    out = [{"kernel": k,
            **decode_step_cost(cfg, batch=batch, cache_len=cache_len,
                               sp=sp, page_size=page_size, kernel=k,
                               cluster=cluster)}
           for k in DECODE_KERNELS]
    out.sort(key=lambda e: e["total_s"])
    return out


def serve_slo_cost(cfg: ModelConfig, *, prompt_len: int,
                   queued_tokens: int = 0, sp: int = 1, page_size: int = 8,
                   decode_batch: int = 1, kernel: str = "ref",
                   cluster: Optional[sch.ClusterModel] = None
                   ) -> Dict[str, float]:
    """Price a request's TTFT and steady tokens/s for SLO-aware admission.

    The front end (``repro.frontend.slo.SLOAdmission``) calls this per
    admission: TTFT ~ this prompt's own cold prefill plus the time the
    replica spends clearing the ``queued_tokens`` already committed ahead
    of it, drained at the full-batch decode rate. Both terms come from the
    same cost model the planner ranks kernels and factorisations with, so
    the admission decision and the plan agree about the machine.

    Returns ``{'prefill_s', 'decode_step_s', 'queue_s', 'ttft_s',
    'tokens_per_s'}`` (analytical seconds — callers calibrate to measured
    hardware with one scale factor).
    """
    prefill_s = prefill_step_cost(
        cfg, prompt_len=max(prompt_len, 1), sp=sp, page_size=page_size,
        cluster=cluster)["total_s"]
    decode_step_s = decode_step_cost(
        cfg, batch=max(decode_batch, 1),
        cache_len=max(prompt_len, page_size * sp), sp=sp,
        page_size=page_size, kernel=kernel, cluster=cluster)["total_s"]
    rate = max(decode_batch, 1) / max(decode_step_s, 1e-12)
    queue_s = queued_tokens / rate
    return {"prefill_s": prefill_s, "decode_step_s": decode_step_s,
            "queue_s": queue_s, "ttft_s": prefill_s + queue_s,
            "tokens_per_s": rate}


# ---------------------------------------------------------------------------
# Serving prefill cost and the prefix-cache capacity / hit-rate trade
# ---------------------------------------------------------------------------

def prefill_step_cost(cfg: ModelConfig, *, prompt_len: int,
                      cached_len: int = 0, sp: int = 1,
                      page_size: int = 8, dtype_bytes: int = 2,
                      cluster: Optional[sch.ClusterModel] = None
                      ) -> Dict[str, float]:
    """Per-device cost of prefilling one request with ``cached_len`` of its
    prompt served from the prefix cache.

    Only the suffix tokens are forwarded: dense/MLP FLOPs scale linearly in
    forwarded tokens (``2 * P_dense`` per token), attention quadratically
    (suffix queries still score the cached keys — reading them from the
    pool — but never recompute their K/V or their own rows). A cache hit
    costs ~0 FLOPs per cached token: what remains is the page-pool *read*
    of the cached K/V during the suffix's attention plus the page-table
    writes (int32 per block), which is why the model prices cached tokens
    in bytes, not FLOPs.

    Returns {'flops', 'bytes', 'total_s', 'flops_saved', 'saved_frac'};
    ``saved_frac`` is the fraction of the cold prefill FLOPs the cache
    removed. ``benchmarks/serving_load.py`` reports this next to the
    measured tokens/s.
    """
    if not 0 <= cached_len <= prompt_len:
        raise ValueError(f"cached_len={cached_len} outside "
                         f"[0, {prompt_len}]")
    cl = cluster or sch.ClusterModel(sp_size=sp)
    n_attn = max(num_attention_layers(cfg), 1)
    dh = cfg.head_dim_
    d = cfg.d_model
    # dense params touched per token per layer (qkv/o + mlp), vocab head off
    dense_per_layer = d * dh * (cfg.num_heads * 2 + cfg.num_kv_heads * 2) \
        + 3 * d * cfg.d_ff

    def attn_flops(q_tokens: int, k_tokens_extra: int) -> float:
        # causal suffix scores ~ q*(q/2) within itself + q*cached keys
        return 4.0 * cfg.num_heads * dh * (
            q_tokens * q_tokens / 2.0 + q_tokens * k_tokens_extra)

    suffix = prompt_len - cached_len
    flops_cold = (2.0 * dense_per_layer * prompt_len * cfg.num_layers
                  + n_attn * attn_flops(prompt_len, 0)) / sp
    flops = (2.0 * dense_per_layer * suffix * cfg.num_layers
             + n_attn * attn_flops(suffix, cached_len)) / sp
    # cached K/V read once by the suffix attention; page-table writes are
    # one int32 per (shard, block)
    cached_blocks = cached_len // max(page_size, 1)
    bytes_moved = n_attn * (2.0 * cached_len * cfg.num_kv_heads * dh
                            * dtype_bytes) / sp + 4.0 * cached_blocks
    flops_s = flops / cl.peak_flops
    bytes_s = bytes_moved / hw.HBM_BW
    return {"flops": flops, "bytes": bytes_moved,
            "total_s": max(flops_s, bytes_s),
            "flops_saved": flops_cold - flops,
            "saved_frac": 1.0 - flops / flops_cold if flops_cold else 0.0}


def chunked_prefill_cost(cfg: ModelConfig, *, prompt_len: int,
                         cached_len: int = 0, chunk: int = 0, sp: int = 1,
                         page_size: int = 8, dtype_bytes: int = 2,
                         cluster: Optional[sch.ClusterModel] = None
                         ) -> Dict[str, object]:
    """Price a chunked prefill against the monolithic one.

    Mirrors the engine's chunking rule: the chunk is rounded up to a
    compile bucket (a power-of-two multiple of ``lcm(sp, page_size)``), and
    chunk ``k`` runs as a suffix prefill with ``cached_len`` equal to the
    tokens already landed — so its attention re-reads the earlier chunks'
    K/V from the pool. Chunking therefore *costs* total time (the re-reads,
    plus quadratic self-attention lost to the split) and *buys* latency:
    the longest single device launch shrinks from the whole prompt to one
    chunk, which is what bounds the decode stall a co-scheduled batch sees.

    Returns ``{'chunks': [per-chunk prefill_step_cost + start/end],
    'n_chunks', 'total_s', 'monolithic_s', 'overhead_frac', 'max_step_s',
    'monolithic_step_s'}``; ``chunk=0`` degenerates to one chunk with zero
    overhead. ``benchmarks/serving_load.py`` reports the measured p99
    decode gap next to this model.
    """
    if not 0 <= cached_len <= prompt_len:
        raise ValueError(f"cached_len={cached_len} outside "
                         f"[0, {prompt_len}]")
    base = math.lcm(sp, page_size)
    step = 0
    if chunk > 0:
        step = base
        while step < max(chunk, base):
            step *= 2
    bounds = []
    start = cached_len
    while start < prompt_len:
        end = prompt_len if not step else min(start + step, prompt_len)
        bounds.append((start, end))
        start = end
    if not bounds:                      # fully cached prompt
        bounds = [(cached_len, prompt_len)]
    chunks = []
    for s, e in bounds:
        c = prefill_step_cost(cfg, prompt_len=e, cached_len=s, sp=sp,
                              page_size=page_size, dtype_bytes=dtype_bytes,
                              cluster=cluster)
        c["start"], c["end"] = s, e
        chunks.append(c)
    mono = prefill_step_cost(cfg, prompt_len=prompt_len,
                             cached_len=cached_len, sp=sp,
                             page_size=page_size, dtype_bytes=dtype_bytes,
                             cluster=cluster)
    total_s = sum(c["total_s"] for c in chunks)
    return {
        "chunks": chunks,
        "n_chunks": len(chunks),
        "total_s": total_s,
        "monolithic_s": mono["total_s"],
        "overhead_frac": (total_s / mono["total_s"] - 1.0
                          if mono["total_s"] else 0.0),
        "max_step_s": max(c["total_s"] for c in chunks),
        "monolithic_step_s": mono["total_s"],
    }


def prefix_cache_value(cfg: ModelConfig, *, prompt_len: int,
                       shared_len: int, requests: int, sp: int,
                       page_size: int, pages_per_shard: int,
                       max_len: int = 0,
                       cluster: Optional[sch.ClusterModel] = None
                       ) -> Dict[str, float]:
    """Price a prefix-cache capacity against the hit-rate it can sustain.

    ``requests`` arrivals share a ``shared_len``-token prefix of their
    ``prompt_len`` prompts. The cache can only hit what fits: retaining the
    shared prefix costs ``ceil(shared_len / page_size)`` pages spread
    round-robin over ``sp`` shards, *on top of* the live sequences' own
    reservations — if the pool cannot hold prefix + one worst-case request,
    every lookup misses and the value is zero. Otherwise the first request
    pays the cold prefill and the remaining ``requests - 1`` save
    ``prefill_step_cost(..., cached_len=shared_cacheable)`` each.

    Returns {'hit_rate', 'saved_tokens', 'saved_flops', 'saved_s',
    'cache_pages', 'fits'} — the analytical counterpart of the
    ``prefix`` section the serving benchmark measures.
    """
    shared_cacheable = (shared_len // page_size) * page_size
    cache_pages = -(-shared_cacheable // page_size)
    # worst-case per-shard pages of one live request: ceil blocks, then
    # ceil over the round-robin shards (Scheduler._blocks_for semantics)
    worst_blocks = -(-(prompt_len + (max_len or prompt_len)) // page_size)
    worst = -(-worst_blocks // sp)
    fits = (-(-cache_pages // sp)) + worst <= pages_per_shard
    if not fits or requests < 2 or shared_cacheable == 0:
        return {"hit_rate": 0.0, "saved_tokens": 0, "saved_flops": 0.0,
                "saved_s": 0.0, "cache_pages": cache_pages, "fits": fits}
    per = prefill_step_cost(cfg, prompt_len=prompt_len,
                            cached_len=shared_cacheable, sp=sp,
                            page_size=page_size, cluster=cluster)
    cold = prefill_step_cost(cfg, prompt_len=prompt_len, sp=sp,
                             page_size=page_size, cluster=cluster)
    warm = requests - 1
    return {
        "hit_rate": warm * shared_cacheable / (requests * prompt_len),
        "saved_tokens": warm * shared_cacheable,
        "saved_flops": warm * per["flops_saved"],
        "saved_s": warm * (cold["total_s"] - per["total_s"]),
        "cache_pages": cache_pages,
        "fits": fits,
    }


# ---------------------------------------------------------------------------
# Host-tier spill/reload pricing (the KV-connector decision)
# ---------------------------------------------------------------------------

def kv_bytes_per_token(cfg: ModelConfig, *, dtype_bytes: int = 2) -> int:
    """Bytes of K+V one token pins across all attention layers."""
    n_attn = max(num_attention_layers(cfg), 1)
    return n_attn * 2 * cfg.num_kv_heads * cfg.head_dim_ * dtype_bytes


def kv_transfer_cost(cfg: ModelConfig, *, tokens: int, dtype_bytes: int = 2,
                     link_bw: Optional[float] = None) -> Dict[str, float]:
    """Price moving ``tokens`` of KV over the device<->host link.

    The pool is SP-sharded but the host link is per-*host*, so the bytes
    are not divided by ``sp``: every shard's pages cross the same DMA
    engine. ``roundtrip_s`` is spill (d2h) plus the eventual reload (h2d)
    — the full price a host-tier hit pays instead of recompute.
    """
    bw = link_bw if link_bw is not None else hw.HOST_LINK_BW
    total = float(tokens) * kv_bytes_per_token(cfg, dtype_bytes=dtype_bytes)
    one_way = total / bw
    return {"bytes": total, "d2h_s": one_way, "h2d_s": one_way,
            "roundtrip_s": 2.0 * one_way}


def spill_decision(cfg: ModelConfig, *, chain_tokens: int, sp: int = 1,
                   page_size: int = 8, dtype_bytes: int = 2,
                   link_bw: Optional[float] = None,
                   cluster: Optional[sch.ClusterModel] = None
                   ) -> Dict[str, object]:
    """Should an evicted ``chain_tokens``-token prefix spill to host?

    Compares what a future capacity miss would pay either way: recomputing
    the chain cold (``prefill_step_cost`` — dense FLOPs linear in tokens,
    attention quadratic) vs round-tripping its KV bytes over the host link
    (linear in tokens). Because only recompute has a quadratic term, the
    decision has a crossover chain length: short cheap chains are faster
    to re-prefill, long chains are faster to reload
    (``spill_threshold_tokens`` locates the boundary).

    Returns {'recompute_s', 'transfer_s', 'bytes', 'spill'}.
    """
    if chain_tokens <= 0:
        raise ValueError(f"chain_tokens must be positive, got {chain_tokens}")
    rec = prefill_step_cost(cfg, prompt_len=chain_tokens, sp=sp,
                            page_size=page_size, dtype_bytes=dtype_bytes,
                            cluster=cluster)
    xfer = kv_transfer_cost(cfg, tokens=chain_tokens,
                            dtype_bytes=dtype_bytes, link_bw=link_bw)
    return {"recompute_s": rec["total_s"],
            "transfer_s": xfer["roundtrip_s"],
            "bytes": xfer["bytes"],
            "spill": xfer["roundtrip_s"] < rec["total_s"]}


def spill_threshold_tokens(cfg: ModelConfig, *, sp: int = 1,
                           page_size: int = 8, max_tokens: int = 1 << 20,
                           dtype_bytes: int = 2,
                           link_bw: Optional[float] = None,
                           cluster: Optional[sch.ClusterModel] = None
                           ) -> Optional[int]:
    """Smallest page-multiple chain length for which spilling beats
    recompute, or None if no chain up to ``max_tokens`` does.

    recompute_s - transfer_s = a*t^2 + b*t with a > 0 (the attention
    term), so the decision is monotone in t: binary search the first
    page boundary where it flips.
    """
    def spills(tokens: int) -> bool:
        return bool(spill_decision(
            cfg, chain_tokens=tokens, sp=sp, page_size=page_size,
            dtype_bytes=dtype_bytes, link_bw=link_bw,
            cluster=cluster)["spill"])

    lo, hi = 1, max_tokens // page_size          # in blocks
    if hi < 1 or not spills(hi * page_size):
        return None
    while lo < hi:
        mid = (lo + hi) // 2
        if spills(mid * page_size):
            hi = mid
        else:
            lo = mid + 1
    return lo * page_size


# ---------------------------------------------------------------------------
# Microbatch selection (gradient accumulation)
# ---------------------------------------------------------------------------

def activation_bytes_per_microbatch(cfg: ModelConfig, shape: ShapeConfig, *,
                                    dp: int, sp: int, c: int,
                                    microbatches: int,
                                    remat: str = "attn_out") -> float:
    """Rough per-device activation footprint of one microbatch's fwd+bwd.

    Counts the residual-stream activations kept live for the backward
    (d_model wide, bf16) per decoder layer, scaled by the remat policy, plus
    the team-gathered attention working set (C * S_local wide). A planning
    heuristic, not an allocator: the dry-run's memory_analysis is the
    ground truth for a specific compile.
    """
    act_factor = {"none": 12.0, "attn_out": 6.0, "full": 2.0}[remat]
    b_local = max(shape.global_batch // max(dp, 1), 1) / max(microbatches, 1)
    tokens = b_local * shape.seq_len / sp
    resid = tokens * cfg.d_model * 2.0 * cfg.num_layers * act_factor
    attn_ws = tokens * c * cfg.head_dim_ * (cfg.num_heads
                                            + 2 * cfg.num_kv_heads) * 4.0
    return resid + attn_ws


def choose_microbatches(cfg: ModelConfig, shape: ShapeConfig, *, dp: int,
                        sp: int, c: int = 1, remat: str = "attn_out",
                        hbm_budget: float = 0.4 * hw.HBM_BYTES) -> int:
    """Smallest microbatch count dividing the per-device batch whose
    activation estimate fits the HBM budget (rest is params/opt/temp)."""
    if shape.kind != "train":
        return 1
    b_local = max(shape.global_batch // max(dp, 1), 1)
    for m in range(1, b_local + 1):
        if b_local % m != 0:
            continue
        est = activation_bytes_per_microbatch(
            cfg, shape, dp=dp, sp=sp, c=c, microbatches=m, remat=remat)
        if est <= hbm_budget:
            return m
    return b_local
