"""ExecutionPlan: the single resolved description of one run.

A frozen, JSON-serialisable record of everything the entry points used to
assemble by hand: the mesh grid and its (C, R) refinement, the attention
scheme (`startrail` | `ulysses` | `ring`), the sequence layout, block
implementation knobs, the remat policy, and the microbatch count for
gradient accumulation. `launch.train`, `launch.dryrun`, the trainer and the
benchmarks all build their mesh + runtime from a plan — nothing else
hand-assembles `make_production_mesh` / `RunConfig.c` plumbing.

Construction paths:
  * `make_plan(cfg, shape, ...)` — explicit knobs, validated; unspecified
    knobs resolved by the analytical cost model (`repro.plan.cost`).
  * `repro.plan.autotune.autotune(...)` — measured refinement of the
    analytical top-k; persists the winner to `results/PLAN_<arch>_<shape>.json`.
  * `ExecutionPlan.load(path)` — reuse a persisted plan (`--plan` flag).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.dist.meshes import PLACEMENTS
from repro.plan import cost

MESH_KINDS = ("local", "production")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Fully-resolved run description. P_sp = n_devices / (pod * data)."""

    arch: str
    shape: str                     # shape name ('train_4k', 'smoke', ...)
    seq_len: int
    global_batch: int
    n_devices: int
    kind: str = "train"            # 'train' | 'prefill' | 'decode'
    data: int = 1
    pod: int = 1
    scheme: str = "startrail"      # 'startrail' | 'ring' | 'ulysses'
    c: int = 1
    placement: str = "team_inner"
    seq_scheme: str = "zigzag"
    block_impl: str = "ref"        # ring-step block kernel ('ref' | 'pallas')
    block_skip: bool = False
    remat: str = "attn_out"
    microbatches: int = 1
    sharding_rules: str = "default"
    grad_compression: str = "none"
    mesh_kind: str = "local"       # 'local' (forced-host) | 'production'
    unroll_scans: bool = False
    # double-buffered ring scans (issue the next transfer before the block
    # kernel) + ring-transfer sub-chunking; see core/startrail.py
    pipeline_scan: bool = True
    comm_chunks: int = 1
    # ---- serving face (kind='decode' plans consumed by repro.engine) -----
    decode_batch: int = 0          # engine decode slots (0 = not a serve plan)
    page_size: int = 0             # KV page tokens (0 = not a serve plan)
    kernel_impl: str = "ref"       # paged-decode kernel ('ref' | 'pallas')
    replicas: int = 1              # gateway engine replicas (n_devices is
    #                                the per-replica device count)
    prefix_cache: bool = False     # block-hash prefix cache (repro.gateway)
    role: str = "unified"          # 'unified' | 'prefill' | 'decode' —
    #                                disaggregated serving (repro.gateway)
    host_tier_bytes: int = 0       # pinned-host KV tier capacity per engine
    #                                (0 = tier off; needs prefix_cache)

    # ---- derived sizes ---------------------------------------------------
    @property
    def dp_size(self) -> int:
        return self.pod * self.data

    @property
    def sp_size(self) -> int:
        return self.n_devices // self.dp_size

    @property
    def r(self) -> int:
        return self.sp_size // (self.c * self.c)

    def __post_init__(self):
        if self.mesh_kind not in MESH_KINDS:
            raise ValueError(f"mesh_kind must be one of {MESH_KINDS}")
        if self.scheme not in cost.SCHEMES:
            raise ValueError(f"scheme must be one of {cost.SCHEMES}, "
                             f"got {self.scheme!r}")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}")
        if self.pod < 1 or self.data < 1 or self.n_devices < 1:
            raise ValueError("pod/data/n_devices must be positive")
        if self.n_devices % self.dp_size != 0:
            raise ValueError(
                f"n_devices={self.n_devices} not divisible by "
                f"pod*data={self.dp_size}")
        sp = self.sp_size
        if self.c < 1 or sp % (self.c * self.c) != 0:
            raise ValueError(
                f"C={self.c} invalid for P={sp}: need P % C^2 == 0")
        if self.scheme in ("ring", "ulysses") and self.c != 1:
            raise ValueError(f"scheme {self.scheme!r} implies C=1, "
                             f"got C={self.c}")
        if self.seq_len % sp != 0:
            raise ValueError(
                f"seq_len={self.seq_len} not divisible by SP={sp}")
        if self.seq_scheme == "zigzag" and self.seq_len % (2 * sp) != 0:
            raise ValueError(
                f"zigzag layout needs seq_len % (2*P) == 0, got "
                f"seq_len={self.seq_len}, P={sp}")
        if self.microbatches < 1:
            raise ValueError("microbatches must be >= 1")
        if self.comm_chunks < 1:
            raise ValueError("comm_chunks must be >= 1")
        s_team = self.c * self.seq_len // sp
        if self.comm_chunks > 1 and s_team % self.comm_chunks:
            raise ValueError(
                f"comm_chunks={self.comm_chunks} must divide the team "
                f"sequence length C*N/P = {s_team} (the axis the chunked "
                f"ring ppermute splits)")
        from repro.kernels.dispatch import IMPLS

        for knob, val in (("block_impl", self.block_impl),
                          ("kernel_impl", self.kernel_impl)):
            if val not in IMPLS:
                raise ValueError(f"{knob} must be one of {IMPLS}, "
                                 f"got {val!r}")
        if self.decode_batch < 0 or self.page_size < 0:
            raise ValueError("decode_batch/page_size must be >= 0")
        if self.page_size and self.seq_len % self.page_size:
            raise ValueError(
                f"seq_len={self.seq_len} not divisible by "
                f"page_size={self.page_size}")
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if (self.replicas > 1 or self.prefix_cache) and not self.page_size:
            raise ValueError(
                "replicas/prefix_cache are serving-face knobs — only valid "
                "on kind='decode' plans with decode_batch/page_size set "
                "(build them with plan.make_serve_plan)")
        if self.role not in ("unified", "prefill", "decode"):
            raise ValueError(
                f"role must be 'unified' | 'prefill' | 'decode', "
                f"got {self.role!r}")
        if self.role != "unified" and not self.page_size:
            raise ValueError(
                "role is a serving-face knob — only valid on plans with "
                "decode_batch/page_size set (plan.make_serve_plan)")
        if self.host_tier_bytes < 0:
            raise ValueError("host_tier_bytes must be >= 0")
        if self.host_tier_bytes and not self.prefix_cache:
            raise ValueError(
                "host_tier_bytes > 0 needs prefix_cache=True: the host "
                "tier is fed by prefix-cache eviction (spilled chains are "
                "rediscovered through the trie hash walk)")
        if self.kind == "train":
            if self.global_batch % self.dp_size != 0:
                raise ValueError(
                    f"global_batch={self.global_batch} not divisible by "
                    f"dp={self.dp_size}")
            b_local = self.global_batch // self.dp_size
            if b_local % self.microbatches != 0:
                raise ValueError(
                    f"per-device batch {b_local} not divisible by "
                    f"microbatches={self.microbatches}")

    # ---- the objects the rest of the system consumes ---------------------
    def shape_config(self) -> ShapeConfig:
        return ShapeConfig(self.shape, seq_len=self.seq_len,
                           global_batch=self.global_batch, kind=self.kind)

    def run_config(self) -> RunConfig:
        return RunConfig(
            c=self.c, seq_scheme=self.seq_scheme, block_impl=self.block_impl,
            kernel_impl=self.kernel_impl,
            block_skip=self.block_skip, multi_pod=self.pod > 1,
            remat=self.remat, grad_compression=self.grad_compression,
            sharding_rules=self.sharding_rules, unroll_scans=self.unroll_scans,
            attention_scheme=self.scheme, microbatches=self.microbatches,
            pipeline_scan=self.pipeline_scan, comm_chunks=self.comm_chunks)

    def build_mesh(self):
        """The refined `( [pod,] data, sp_grp, sp_ring, sp_team )` mesh."""
        from repro.dist import meshes

        if self.mesh_kind == "local":
            if self.pod != 1:
                raise ValueError("local meshes are single-pod")
            return meshes.local_mesh_for_tests(c=self.c, r=self.r,
                                               data=self.data)
        from repro.launch.mesh import make_production_mesh

        prod = make_production_mesh(multi_pod=self.pod > 1)
        return meshes.refine_mesh(prod, self.c, placement=self.placement)

    def build_train_step(self, model, adam_cfg, mesh=None):
        """(jitted_step, shardings) — see train.step.build_train_step."""
        from repro.train import step as train_step

        mesh = mesh if mesh is not None else self.build_mesh()
        return train_step.build_train_step(
            model, mesh, self.run_config(), self.shape_config(), adam_cfg)

    # ---- persistence -----------------------------------------------------
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["r"] = self.r           # derived, recorded for readability
        d["sp_size"] = self.sp_size
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "ExecutionPlan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps({"plan": self.to_dict()}, indent=2))
        return path

    @classmethod
    def load(cls, path) -> "ExecutionPlan":
        d = json.loads(pathlib.Path(path).read_text())
        return cls.from_dict(d["plan"] if "plan" in d else d)


def plan_path(results_dir, arch: str, shape: str) -> pathlib.Path:
    return pathlib.Path(results_dir) / f"PLAN_{arch}_{shape}.json"


def make_plan(cfg: ModelConfig, shape: ShapeConfig, *, arch: Optional[str]
              = None, n_devices: int, data: int = 1, pod: int = 1,
              scheme: Optional[str] = None, c: Optional[int] = None,
              placement: Optional[str] = None,
              microbatches: Optional[int] = None,
              mesh_kind: str = "local", block_impl: Optional[str] = None,
              kernel_impl: Optional[str] = None,
              remat: str = "attn_out", sharding_rules: str = "default",
              grad_compression: str = "none", unroll_scans: bool = False,
              pipeline_scan: bool = True,
              comm_chunks: Optional[int] = None,
              overlap_frac: float = 1.0,
              cluster=None) -> ExecutionPlan:
    """Resolve one run into a validated ExecutionPlan.

    Knobs left as None are chosen by the analytical cost model
    (`cost.rank_arrangements`); explicitly-passed knobs are validated and
    illegal combinations raise (e.g. `scheme='ulysses'` when P > Hkv raises
    exactly as `core/ulysses.py` would at trace time). Unset
    `block_impl`/`kernel_impl` resolve per backend: the Pallas kernels on
    TPU, the jnp reference on CPU (`kernels.dispatch.resolve_impl`).
    Unset ``comm_chunks`` resolves via the overlap model
    (`cost.choose_comm_chunks`) at ``overlap_frac`` — pass the measured
    fraction from ``obs.commlog.overlap_report`` to stop the model
    assuming perfect comm/compute hiding.
    """
    from repro.kernels.dispatch import resolve_impl

    block_impl = resolve_impl(block_impl)
    kernel_impl = resolve_impl(kernel_impl)
    dp = pod * data
    if n_devices % dp != 0:
        raise ValueError(f"n_devices={n_devices} not divisible by "
                         f"pod*data={dp}")
    sp = n_devices // dp

    # sequence layout: causal load balance for training attention; SSM state
    # passing and serve-side cache layouts need contiguity
    if cfg.family in ("ssm", "hybrid") or shape.kind != "train":
        seq_scheme = "contiguous"
    else:
        seq_scheme = "zigzag"

    if scheme is not None:
        cost.check_scheme(cfg, sp, scheme)
    ranking = cost.rank_arrangements(cfg, shape, sp,
                                     batch=max(shape.global_batch // dp, 1),
                                     cluster=cluster)

    def matches(arr: cost.Arrangement) -> bool:
        if scheme is not None and arr.scheme != scheme:
            return False
        if c is not None and arr.c != c:
            return False
        if placement is not None and arr.c > 1 and arr.placement != placement:
            return False
        return True

    picked = next((e["arrangement"] for e in ranking if matches(e["arrangement"])),
                  None)
    if picked is None:
        legal = sorted({e["arrangement"].key for e in ranking})
        raise ValueError(
            f"no legal arrangement matches scheme={scheme!r} c={c} "
            f"placement={placement!r} at P={sp}; legal: {legal}")

    if microbatches is None:
        if mesh_kind == "production" and shape.kind == "train":
            microbatches = cost.choose_microbatches(
                cfg, shape, dp=dp, sp=sp, c=picked.c, remat=remat)
        else:
            microbatches = 1

    if comm_chunks is None:
        comm_chunks = cost.choose_comm_chunks(
            cfg, shape, sp, picked, batch=max(shape.global_batch // dp, 1),
            cluster=cluster, overlap_frac=overlap_frac)

    return ExecutionPlan(
        arch=arch or cfg.name, shape=shape.name, seq_len=shape.seq_len,
        global_batch=shape.global_batch, n_devices=n_devices,
        kind=shape.kind, data=data, pod=pod, scheme=picked.scheme,
        c=picked.c,
        placement=picked.placement if picked.c > 1 else "team_inner",
        seq_scheme=seq_scheme, block_impl=block_impl,
        kernel_impl=kernel_impl,
        block_skip=cfg.window is not None and seq_scheme == "contiguous",
        remat=remat, microbatches=microbatches,
        sharding_rules=sharding_rules, grad_compression=grad_compression,
        mesh_kind=mesh_kind, unroll_scans=unroll_scans,
        pipeline_scan=pipeline_scan, comm_chunks=comm_chunks)


def make_serve_plan(cfg: ModelConfig, *, arch: Optional[str] = None,
                    n_devices: int, data: int = 1,
                    scheme: Optional[str] = None, c: Optional[int] = None,
                    placement: Optional[str] = None,
                    decode_batch: int = 4, page_size: int = 8,
                    max_len: int = 512, mesh_kind: str = "local",
                    kernel_impl: Optional[str] = None,
                    block_impl: Optional[str] = None,
                    sharding_rules: str = "default",
                    replicas: int = 1, prefix_cache: bool = False,
                    role: str = "unified", host_tier_bytes: int = 0,
                    cluster=None) -> ExecutionPlan:
    """Resolve one *serving* run (the engine's mesh + kernels) into a plan.

    ``kind='decode'``: ``seq_len`` is the engine capacity (``max_len``
    rounded up so both the SP degree and the page size divide it),
    ``global_batch``/``decode_batch`` the decode slot count, and
    ``kernel_impl`` the paged-decode kernel — backend-resolved when unset,
    like ``block_impl``. The arrangement (scheme, C, placement) comes from
    the same analytical ranking as training plans; for M=1 decode the ring
    degenerates to the lse-combine reduction, so the mesh factorisation
    mainly decides the *placement* of the cache shards.

    ``replicas``/``prefix_cache`` fill the gateway face (``repro.gateway``):
    ``n_devices`` is then the per-replica device count, and
    ``cost.prefix_cache_value`` prices the cache capacity against the
    hit-rate it can sustain (cached prefill tokens cost ~0 FLOPs — only
    page-table writes).
    """
    import math

    dp = data
    if n_devices % dp != 0:
        raise ValueError(f"n_devices={n_devices} not divisible by "
                         f"data={dp}")
    sp = n_devices // dp
    if page_size < 1:
        raise ValueError("page_size must be >= 1")
    if decode_batch < 1:
        raise ValueError("decode_batch must be >= 1")
    quantum = math.lcm(sp, page_size)
    seq_len = ((max_len + quantum - 1) // quantum) * quantum
    shape = ShapeConfig("serve", seq_len=seq_len, global_batch=decode_batch,
                        kind="decode")
    base = make_plan(cfg, shape, arch=arch, n_devices=n_devices, data=data,
                     scheme=scheme, c=c, placement=placement,
                     mesh_kind=mesh_kind, block_impl=block_impl,
                     kernel_impl=kernel_impl, sharding_rules=sharding_rules,
                     cluster=cluster)
    return dataclasses.replace(base, decode_batch=decode_batch,
                               page_size=page_size, replicas=replicas,
                               prefix_cache=prefix_cache, role=role,
                               host_tier_bytes=host_tier_bytes)


def make_role_plans(cfg: ModelConfig, *, roles: Sequence[str],
                    n_devices: int, **kw) -> List[ExecutionPlan]:
    """Per-replica plans for a disaggregated gateway.

    ``roles`` is one entry per replica (e.g. ``['prefill', 'decode']``);
    ``n_devices`` is the per-replica device count, as in the ``replicas``
    face of `make_serve_plan`. Every other knob is shared across roles so
    the engines stay numerically interchangeable — same kernels, same page
    size, same rounded capacity — which is what makes the prefill→decode
    KV handoff bit-exact. Returns one plan per role with ``replicas=1``
    (the gateway composes them; a mixed-role gateway cannot be described
    by a single plan's ``replicas`` count).
    """
    if not roles:
        raise ValueError("roles must name at least one replica")
    kw.pop("replicas", None)
    kw.pop("role", None)
    return [make_serve_plan(cfg, n_devices=n_devices, replicas=1,
                            role=role, **kw)
            for role in roles]
