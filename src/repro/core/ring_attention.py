"""Ring Attention baseline (Liu, Zaharia, Abbeel 2023).

In the StarTrail formulation, Ring Attention is exactly the C = 1
degenerate point: team size 1 (no gather / scatter), a single ring of all
P devices, P ring steps circulating N/P-token K/V chunks. We therefore
*implement* it as StarTrail with a (1, P, 1) axis factorisation, which both
deduplicates code and guarantees the baseline/technique comparison is
apples-to-apples (same block kernel, same masks, same scan machinery).

The paper's analysis (eqs. 2-4) is reproduced in
``benchmarks/comm_volume.py`` against this implementation's measured
collective bytes.
"""

from __future__ import annotations

from repro.core.startrail import StarTrailConfig, startrail_attention


def ring_attention(q, k, v, cfg: StarTrailConfig):
    """Per-shard ring attention: requires cfg.axes sized (1, P, 1)."""
    return startrail_attention(q, k, v, cfg)


def ring_config(seq_len: int, **kw) -> StarTrailConfig:
    return StarTrailConfig(seq_len=seq_len, **kw)
