"""StarTrail (WallFacer) concentric-ring sequence-parallel attention.

The paper's contribution, as a composable JAX module. The sequence-parallel
dimension P is factored onto three mesh axes

    (sp_grp = C, sp_ring = R, sp_team = C),      P = C^2 * R

and exact full-sequence attention of a sequence sharded over those axes is
computed as:

  1. all_gather Q/K/V over ``sp_team``          (paper: team gather, overlaps
                                                 with the QKV projections)
  2. one ppermute over the joint SP axes with the Alg.-2 placement
     permutation                                 (paper: initial K/V dispatch)
  3. a ``jax.lax.scan`` of R ring steps: flash-attention block accumulate
     (online softmax, merged into the running (o, lse) accumulator — fused
     into the Pallas kernel epilogue on ``block_impl='pallas'``) + ppermute
     of K/V along ``sp_ring``                    (paper: concentric rings;
                                                 with ``pipeline=True`` the
                                                 step-s+1 transfer is issued
                                                 before the step-s block
                                                 kernel — double-buffered —
                                                 optionally split into
                                                 ``comm_chunks`` sub-chunk
                                                 transfers)
  4. log-sum-exp combine across ``sp_team`` + psum_scatter
                                                 (paper: ReduceScatter_combine)

C = 1 degenerates to Ring Attention (the paper's baseline); R = 1 to a fully
collective scheme. The backward is a custom VJP implementing the paper's
two-loop scheme: K/V and their grads stay resident; the (Q, dO, lse, delta,
dQ) pack circulates the ring (the "query inner loop"), followed by the
transposed placement permute and team reduce-scatters.

Masks are derived from *global token positions*, computed on-device from
axis indices (no position tensors are communicated). Causal, sliding-window
(SWA) and full masks are supported; the zigzag layout (§3.5) balances causal
work across shards.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import topology as topo_lib
from repro.core.combine import NEG_INF
from repro.kernels import dispatch as kernels


@dataclasses.dataclass(frozen=True)
class StarTrailConfig:
    """Static configuration of the concentric-ring attention.

    Attributes:
      seq_len: global sequence length N.
      axes: mesh axis names (sp_grp, sp_ring, sp_team).
      seq_scheme: 'zigzag' (causal load balance) or 'contiguous'.
      causal: causal mask.
      window: sliding-window size (tokens), None = full.
      scale: softmax scale; None = 1/sqrt(D).
      block_impl: 'ref' (pure-jnp / XLA; CPU + dry-run default) or 'pallas'
        (TPU kernel; validated in interpret mode on CPU).
      block_skip: skip fully-masked ring steps with lax.cond (wins for SWA
        with contiguous layout; applies to forward *and* backward scans).
      pipeline: double-buffered ring scans — issue the step-s+1 ppermute
        *before* the step-s block kernel in program order, carrying the
        in-flight buffer through the scan, so the scheduler overlaps the
        wire time with the block compute. Same ops as the non-pipelined
        scan, reordered issue: bit-identical results.
      comm_chunks: split each ring transfer into this many sequence
        sub-chunks (independent ppermutes), letting compute on chunk 0
        overlap the wire time of chunks 1..n. 1 = whole-tensor transfers.
        Values are bit-exact for any chunking (pure data movement).
    """

    seq_len: int
    axes: Tuple[str, str, str] = ("sp_grp", "sp_ring", "sp_team")
    seq_scheme: str = "zigzag"
    causal: bool = True
    window: Optional[int] = None
    scale: Optional[float] = None
    prefix_len: Optional[int] = None   # prefix-LM (VLM): keys < prefix_len
                                       # are visible to all queries
    block_impl: str = "ref"
    block_skip: bool = False
    unroll: bool = False   # unroll ring scans (dry-run cost accounting:
                           # XLA cost_analysis counts while-loop bodies once)
    pipeline: bool = True
    comm_chunks: int = 1

    @property
    def grp_axis(self) -> str:
        return self.axes[0]

    @property
    def ring_axis(self) -> str:
        return self.axes[1]

    @property
    def team_axis(self) -> str:
        return self.axes[2]


# ---------------------------------------------------------------------------
# Position bookkeeping (pure jnp; works with traced shard indices)
# ---------------------------------------------------------------------------

def shard_positions(sp_rank: jax.Array, seq_len: int, sp_size: int, scheme: str) -> jax.Array:
    """Global positions of SP shard `sp_rank` (traced ok) -> (S_local,) int32."""
    s_local = seq_len // sp_size
    if scheme == "contiguous":
        return sp_rank * s_local + jnp.arange(s_local, dtype=jnp.int32)
    if scheme == "zigzag":
        ch = seq_len // (2 * sp_size)
        a = sp_rank * ch + jnp.arange(ch, dtype=jnp.int32)
        b = (2 * sp_size - 1 - sp_rank) * ch + jnp.arange(ch, dtype=jnp.int32)
        return jnp.concatenate([a, b])
    raise ValueError(f"unknown seq scheme {scheme!r}")


def team_positions(team_idx: jax.Array, c: int, seq_len: int, sp_size: int, scheme: str) -> jax.Array:
    """Positions of the C concatenated member shards of team `team_idx`."""
    ranks = team_idx * c + jnp.arange(c, dtype=jnp.int32)
    rows = jax.vmap(lambda r: shard_positions(r, seq_len, sp_size, scheme))(ranks)
    return rows.reshape(-1)


# ---------------------------------------------------------------------------
# Block compute (routed through the kernels.dispatch layer)
# ---------------------------------------------------------------------------

def _block_fwd(cfg: StarTrailConfig, q, k, v, pos_q, pos_k):
    return kernels.block_fwd(
        q, k, v, pos_q, pos_k, causal=cfg.causal, window=cfg.window,
        scale=cfg.scale, prefix_len=cfg.prefix_len, impl=cfg.block_impl,
    )


def _block_fwd_merge(cfg: StarTrailConfig, q, k, v, o_acc, lse_acc,
                     pos_q, pos_k):
    return kernels.block_fwd_merge(
        q, k, v, o_acc, lse_acc, pos_q, pos_k, causal=cfg.causal,
        window=cfg.window, scale=cfg.scale, prefix_len=cfg.prefix_len,
        impl=cfg.block_impl,
    )


def _block_bwd(cfg: StarTrailConfig, q, k, v, do, lse, delta, pos_q, pos_k):
    return kernels.block_bwd(
        q, k, v, do, lse, delta, pos_q, pos_k,
        causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        prefix_len=cfg.prefix_len, impl=cfg.block_impl,
    )


def _fully_masked(cfg: StarTrailConfig, pos_q, pos_k):
    """True iff the whole (Q block x K block) pair is masked out."""
    dead = jnp.array(False)
    if cfg.causal:
        dead = dead | (jnp.min(pos_k) > jnp.max(pos_q))
    if cfg.window is not None:
        p = (jnp.min(pos_q) - jnp.max(pos_k)) >= cfg.window
        if not cfg.causal:
            p = p & ((jnp.min(pos_k) - jnp.max(pos_q)) >= cfg.window)
        dead = dead | p
    if cfg.prefix_len is not None:
        # any key inside the prefix keeps the tile alive
        dead = dead & (jnp.min(pos_k) >= cfg.prefix_len)
    return dead


def _chunked_ppermute(x, axes, perm, n_chunks: int, axis: int):
    """ppermute ``x``, optionally as ``n_chunks`` independent sequence
    sub-chunk transfers along ``axis``.

    Chunking is pure data movement — values are bit-exact for any n — but
    lets the scheduler start the step-s+1 block kernel after chunk 0 lands
    instead of waiting for the whole tensor (see docs/TUNING.md).
    """
    if n_chunks <= 1 or jnp.ndim(x) == 0:
        return jax.lax.ppermute(x, axes, perm)
    if x.shape[axis] % n_chunks:
        raise ValueError(
            f"comm_chunks={n_chunks} must divide the permuted sequence "
            f"axis (got length {x.shape[axis]})")
    parts = jnp.split(x, n_chunks, axis=axis)
    return jnp.concatenate(
        [jax.lax.ppermute(p, axes, perm) for p in parts], axis=axis)


# ---------------------------------------------------------------------------
# The per-shard attention (call inside shard_map) with custom VJP
# ---------------------------------------------------------------------------

def startrail_attention(q, k, v, cfg: StarTrailConfig):
    """Exact full-sequence attention for sequence-sharded q, k, v.

    Must be called inside a ``shard_map`` whose mesh contains ``cfg.axes``.
    Shapes (per shard): q (B, S, Hq, D); k, v (B, S, Hkv, D);
    returns o (B, S, Hq, D). S = N / P, with the shard's tokens laid out by
    ``cfg.seq_scheme``.
    """
    fn = _make_attention(cfg)
    return fn(q, k, v)


@functools.lru_cache(maxsize=None)
def _make_attention(cfg: StarTrailConfig):
    g_ax, r_ax, t_ax = cfg.axes

    def _sizes():
        c = jax.lax.axis_size(t_ax)
        r = jax.lax.axis_size(r_ax)
        g = jax.lax.axis_size(g_ax)
        if g != c:
            raise ValueError(
                f"sp_grp axis size {g} must equal sp_team axis size {c} "
                f"(both are the paper's C)"
            )
        return c, r, c * c * r

    def _self_coords():
        gi = jax.lax.axis_index(g_ax)
        ji = jax.lax.axis_index(r_ax)
        ti = jax.lax.axis_index(t_ax)
        return gi, ji, ti

    def _topo(c, r):
        return topo_lib.StarTrailTopology(sp_size=c * c * r, c=c)

    # -- forward ------------------------------------------------------------
    def _forward(q, k, v):
        c, r, p = _sizes()
        tp = _topo(c, r)
        gi, ji, ti = _self_coords()
        B, S, Hq, D = q.shape

        # 1. team gather (paper: AllGather_QKVmatmul)
        q_team = jax.lax.all_gather(q, t_ax, axis=1, tiled=True)
        k_team = jax.lax.all_gather(k, t_ax, axis=1, tiled=True)
        v_team = jax.lax.all_gather(v, t_ax, axis=1, tiled=True)

        # 2. initial K/V placement (paper Alg. 2)
        perm = tp.init_placement_permutation()
        k0 = jax.lax.ppermute(k_team, cfg.axes, perm)
        v0 = jax.lax.ppermute(v_team, cfg.axes, perm)

        own_team = gi * r + ji
        pos_q = team_positions(own_team, c, cfg.seq_len, p, cfg.seq_scheme)

        ring_perm = tp.ring_permutation()

        # 3. concentric-ring scan (double-buffered when cfg.pipeline: the
        # step-s+1 K/V transfer is issued *before* the step-s block kernel,
        # carrying the in-flight buffer through the carry — same ops as the
        # issue-after order, so results are bit-identical)
        def step(carry, s):
            k_cur, v_cur, o_acc, lse_acc = carry
            kv_team = ((ji + s) % r) * c + ti
            pos_k = team_positions(kv_team, c, cfg.seq_len, p, cfg.seq_scheme)

            def rotate():
                # rotate K/V for the next step (also on the last step: the
                # chunks end back in placement order, which the backward
                # reuses).
                with jax.named_scope("ring_permute_issue"):
                    k_nxt = _chunked_ppermute(k_cur, cfg.axes, ring_perm,
                                              cfg.comm_chunks, 1)
                    v_nxt = _chunked_ppermute(v_cur, cfg.axes, ring_perm,
                                              cfg.comm_chunks, 1)
                return k_nxt, v_nxt

            if cfg.pipeline:
                k_nxt, v_nxt = rotate()
            # barrier: stops XLA hoisting the f32 upcast through the
            # ppermute (keeps K/V bf16 on the wire)
            k_use, v_use = jax.lax.optimization_barrier((k_cur, v_cur))

            def compute(o_acc, lse_acc):
                with jax.named_scope("ring_block_compute"):
                    return _block_fwd_merge(cfg, q_team, k_use, v_use,
                                            o_acc, lse_acc, pos_q, pos_k)

            if cfg.block_skip:
                o_acc, lse_acc = jax.lax.cond(
                    _fully_masked(cfg, pos_q, pos_k),
                    lambda oa, la: (oa, la),
                    compute,
                    o_acc,
                    lse_acc,
                )
            else:
                o_acc, lse_acc = compute(o_acc, lse_acc)

            if not cfg.pipeline:
                k_nxt, v_nxt = rotate()
            return (k_nxt, v_nxt, o_acc, lse_acc), None

        o0 = jnp.zeros((B, c * S, Hq, D), jnp.float32)
        l0 = jnp.full((B, Hq, c * S), NEG_INF, jnp.float32)
        (k_fin, v_fin, o_part, lse_part), _ = jax.lax.scan(
            step, (k0, v0, o0, l0), jnp.arange(r),
            unroll=r if cfg.unroll else 1,
        )
        del k_fin, v_fin  # == (k0, v0); XLA aliases them

        # 4. lse-combine + reduce-scatter (paper: ReduceScatter_combine)
        m = jax.lax.pmax(lse_part, t_ax)
        dead = m <= NEG_INF / 2
        m_safe = jnp.where(dead, 0.0, m)
        se = jax.lax.psum(jnp.exp(lse_part - m_safe), t_ax)
        se_safe = jnp.where(se == 0.0, 1.0, se)
        lse_glob = jnp.where(dead, NEG_INF, m_safe + jnp.log(se_safe))

        w = jnp.exp(lse_part - jnp.where(dead, 0.0, lse_glob))
        w = jnp.where(dead, 0.0, w)
        o_scaled = o_part * jnp.swapaxes(w, 1, 2)[..., None]
        o_local = jax.lax.psum_scatter(o_scaled, t_ax, scatter_dimension=1, tiled=True)
        return o_local.astype(q.dtype), (q_team, k0, v0, lse_glob)

    # -- backward (paper: two-loop; Q pack circulates, K/V grads resident) --
    def _backward(res, o_local, do_local):
        q_team, k0, v0, lse_glob = res
        c, r, p = _sizes()
        tp = _topo(c, r)
        gi, ji, ti = _self_coords()
        B, CS, Hq, D = q_team.shape
        Hkv = k0.shape[2]

        do_f = do_local.astype(jnp.float32)
        o_f = o_local.astype(jnp.float32)
        delta_local = jnp.einsum("bshd,bshd->bhs", do_f, o_f)

        do_team = jax.lax.all_gather(do_local, t_ax, axis=1, tiled=True)
        delta_team = jax.lax.all_gather(delta_local, t_ax, axis=2, tiled=True)

        # K/V (and their positions) stay resident on this device.
        kv_team_idx = ji * c + ti
        pos_k = team_positions(kv_team_idx, c, cfg.seq_len, p, cfg.seq_scheme)

        ring_perm = tp.ring_permutation()
        own_team = gi * r + ji

        pack = dict(
            q=q_team,
            do=do_team,
            delta=delta_team,
            lse=lse_glob,
            dq=jnp.zeros((B, CS, Hq, D), jnp.float32),
            team=own_team.astype(jnp.int32),
        )
        dk_acc = jnp.zeros((B, CS, Hkv, D), jnp.float32)
        dv_acc = jnp.zeros((B, CS, Hkv, D), jnp.float32)

        # seq axis each circulating leaf chunks along (team is a scalar)
        pack_axis = dict(q=1, do=1, delta=2, lse=2, dq=1, team=None)

        def _pack_permute(name, a):
            ax = pack_axis[name]
            return _chunked_ppermute(a, cfg.axes, ring_perm,
                                     cfg.comm_chunks if ax is not None else 1,
                                     ax if ax is not None else 0)

        # double-buffered like the forward: the step-s+1 Q-pack transfer of
        # the *input* leaves (q, do, delta, lse, team) is issued before the
        # step-s block gradients; dq — produced by the compute — permutes
        # after. Same six leaf permutes as the issue-after order.
        def step(carry, _):
            pack, dk_acc, dv_acc = carry
            pos_q = team_positions(pack["team"], c, cfg.seq_len, p, cfg.seq_scheme)

            if cfg.pipeline:
                with jax.named_scope("ring_permute_issue"):
                    pack_nxt = {n: _pack_permute(n, a)
                                for n, a in pack.items() if n != "dq"}
            q_use, do_use = jax.lax.optimization_barrier(
                (pack["q"], pack["do"]))  # keep the circulating pack bf16

            def compute(pack_dq, dk_acc, dv_acc):
                with jax.named_scope("ring_block_compute"):
                    dq_c, dk_c, dv_c = _block_bwd(
                        cfg, q_use, k0, v0, do_use, pack["lse"],
                        pack["delta"], pos_q, pos_k,
                    )
                return pack_dq + dq_c, dk_acc + dk_c, dv_acc + dv_c

            if cfg.block_skip:
                dq_new, dk_acc, dv_acc = jax.lax.cond(
                    _fully_masked(cfg, pos_q, pos_k),
                    lambda dq, dk, dv: (dq, dk, dv),
                    compute,
                    pack["dq"],
                    dk_acc,
                    dv_acc,
                )
            else:
                dq_new, dk_acc, dv_acc = compute(pack["dq"], dk_acc, dv_acc)

            if cfg.pipeline:
                pack = dict(pack_nxt, dq=_pack_permute("dq", dq_new))
            else:
                pack = dict(pack, dq=dq_new)
                pack = {n: _pack_permute(n, a) for n, a in pack.items()}
            return (pack, dk_acc, dv_acc), None

        (pack, dk_acc, dv_acc), _ = jax.lax.scan(
            step, (pack, dk_acc, dv_acc), None, length=r,
            unroll=r if cfg.unroll else 1,
        )
        # after R permutes the pack is back home (full ring tour)

        dq_local = jax.lax.psum_scatter(
            pack["dq"], t_ax, scatter_dimension=1, tiled=True
        )

        inv = tp.inverse_placement_permutation()
        dk_team = jax.lax.ppermute(dk_acc, cfg.axes, inv)
        dv_team = jax.lax.ppermute(dv_acc, cfg.axes, inv)
        dk_local = jax.lax.psum_scatter(dk_team, t_ax, scatter_dimension=1, tiled=True)
        dv_local = jax.lax.psum_scatter(dv_team, t_ax, scatter_dimension=1, tiled=True)
        return dq_local, dk_local, dv_local

    @jax.custom_vjp
    def attn(q, k, v):
        o, _ = _forward(q, k, v)
        return o

    def attn_fwd(q, k, v):
        o, res = _forward(q, k, v)
        return o, (res, o)

    def attn_bwd(saved, do):
        res, o = saved
        q_team, k0, v0, _ = res
        dq, dk, dv = _backward(res, o, do)
        return (
            dq.astype(q_team.dtype),
            dk.astype(k0.dtype),
            dv.astype(v0.dtype),
        )

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


# ---------------------------------------------------------------------------
# shard_map wrapper for GSPMD-style models
# ---------------------------------------------------------------------------

def sharded_startrail_attention(
    q, k, v, *, mesh, cfg: StarTrailConfig, batch_axes=("data",),
):
    """shard_map island: q,k,v are global (B, N, H, D) arrays (or tracers in
    a surrounding pjit); attention runs under the StarTrail scheme.

    Batch is sharded over `batch_axes`; sequence over cfg.axes.
    """
    seq_spec = tuple(cfg.axes)
    spec = P(tuple(batch_axes), seq_spec, None, None)

    def local(q, k, v):
        return startrail_attention(q, k, v, cfg)

    return jax.shard_map(
        local, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode-time attention: one (or few) new token(s) vs an SP-sharded KV cache.
# The ring degenerates to a partial-attention + global lse-combine reduction.
# ---------------------------------------------------------------------------

def combine_decode_partials(o, lse, axes):
    """Merge per-shard partial (o, lse) pairs into full attention via the
    global lse-combine psum over ``axes``. Shards whose lse is -inf (no
    visible key) contribute exact zeros; if *every* shard is dead the
    result is zero (the caller treats such rows as inactive).
    """
    o, _ = combine_partials_with_lse(o, lse, axes)
    return o


def combine_partials_with_lse(o, lse, axes):
    """``combine_decode_partials`` that also returns the merged lse, for
    callers that go on to merge the cross-shard result with *another*
    disjoint partial (the prefix-cached prefill merges page-pool partials
    with the locally-computed suffix partial via ``combine.combine_pair``).
    """
    m = jax.lax.pmax(lse, axes)
    dead = m <= NEG_INF / 2
    m_safe = jnp.where(dead, 0.0, m)
    se = jax.lax.psum(jnp.exp(lse - m_safe), axes)
    se_safe = jnp.where(se == 0.0, 1.0, se)
    w = jnp.where(dead, 0.0, jnp.exp(lse - m_safe) / se_safe)
    o = jax.lax.psum(o * jnp.swapaxes(w, 1, 2)[..., None], axes)
    lse_c = jnp.where(dead, NEG_INF, m_safe + jnp.log(se_safe))
    return o, lse_c


def decode_attention(q_new, k_cache, v_cache, pos_q, pos_k, cfg: StarTrailConfig):
    """Per-shard decode attention (call inside shard_map).

    q_new: (B, M, Hq, D) replicated across SP axes (M = new tokens, usually 1)
    k_cache/v_cache: (B, S_local, Hkv, D) this shard's slice of the cache
    pos_q: (M,) or (B, M) positions of the new tokens; pos_k: (S_local,) or
      (B, S_local) cache positions
    Returns (B, M, Hq, D) fully-combined attention, replicated across SP.

    Validity contract (repo-wide): cache-slot validity is encoded through
    *positions*, never a separate mask — callers push the positions of
    unfilled/unowned slots past the query position (``cache_len + 1``) so
    the causal mask removes them (see serve.kv_cache / engine.paged_cache).
    """
    o, lse = kernels.decode(
        q_new, k_cache, v_cache, pos_q, pos_k,
        causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        impl=cfg.block_impl,
    )
    return combine_decode_partials(o, lse, tuple(cfg.axes)).astype(q_new.dtype)
