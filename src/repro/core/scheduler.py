"""Communication Topology Scheduler (paper §3.4).

Grid-searches the StarTrail tuning space

    Config = argmax_{C, placement} Profile(C in [1, sqrt(P)],
                                           placement in {P2P_intra, Collect_intra})

The paper profiles a few iterations on the real cluster; without hardware,
``Profile`` defaults to the analytic cost model below (the paper's eqs. 2-4
plus an overlap model on v5e constants). On a real deployment, pass
``profile_fn`` that wall-clocks the compiled step — the search is identical.

Cost model for one attention block over sequence N, hidden H, P devices,
attention-parallel size C (bf16, bytes):

    collective (team gather + reduce-scatter):  4*B*N*H*(C-1)/P      (eq. 3)
    ring P2P total:                             2*B*N*kvH/C          (eq. 4)
    ring steps:                                 P / C^2
    attention compute per device:               2 * (2*N^2*Hq*dh/P)  flops

Overlap: per ring step, XLA overlaps the permute with the block compute.
The model is parameterized by a *measured* overlap fraction f (default 1.0
= perfect hiding; ``obs.commlog.overlap_report`` measures the real one
from the compiled HLO's collective placement) and by ``comm_chunks`` n
(sub-chunked transfers: the exposed wire time divides by n, the per-step
message latency multiplies by n). At f=1, n=1 the exposed time per step is
max(compute_step, wire_step) + latency — the old perfect-overlap form. The
placement option decides which axis gets the fast links: 'team_inner'
(Collect_intra) gives the team collectives the short hops; 'ring_inner'
(P2P_intra) favours the permutes. We model it as a bandwidth discount on
the favoured class (paper's inter/intra-node distinction mapped to ICI
hop distance).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.topology import valid_c_values
from repro.roofline import hw


@dataclasses.dataclass(frozen=True)
class AttnWorkload:
    batch: int
    seq_len: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    causal: bool = True
    dtype_bytes: int = 2


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    sp_size: int
    peak_flops: float = hw.PEAK_FLOPS_BF16
    link_bw: float = hw.ICI_BW_PER_LINK
    # hop-distance discount for the non-favoured collective class
    far_penalty: float = 2.0
    step_latency: float = 1e-6


def attention_step_cost(w: AttnWorkload, cl: ClusterModel, c: int,
                        placement: str, *, overlap_frac: float = 1.0,
                        comm_chunks: int = 1) -> Dict[str, float]:
    """Analytic per-block cost (seconds) for attention-parallel size c.

    ``overlap_frac`` f is the measured fraction of each ring transfer's
    wire time that hides under the block compute (1.0 = the perfect
    hiding the model used to assume; ``obs.commlog.overlap_report``
    measures it from the compiled HLO's collective placement).
    ``comm_chunks`` n splits each transfer into n sub-chunk messages: the
    *exposed* (un-hidden) wire time shrinks ~n-fold — the next step's
    kernel starts once chunk 0 lands — at the price of n per-message
    latencies. Chunking therefore wins on bandwidth-bound shapes (large
    transfers, low f) and loses on latency-bound ones.
    """
    if not 0.0 <= overlap_frac <= 1.0:
        raise ValueError(f"overlap_frac must be in [0, 1], "
                         f"got {overlap_frac}")
    if comm_chunks < 1:
        raise ValueError(f"comm_chunks must be >= 1, got {comm_chunks}")
    p = cl.sp_size
    r = p // (c * c)
    causal_frac = 0.5 if w.causal else 1.0

    # compute: each device computes Q_team (c*N/p) x (N/c) of keys
    flops = (4.0 * w.batch * (c * w.seq_len / p) * (w.seq_len / c)
             * w.num_heads * w.head_dim * causal_frac)
    t_compute = flops / cl.peak_flops

    kv_h = w.num_kv_heads * w.head_dim
    q_h = w.num_heads * w.head_dim
    # collective: all-gather q,k,v + reduce-scatter o over the team (eq. 3)
    coll_bytes = (w.batch * w.seq_len / p * (c - 1)
                  * (2 * kv_h + 2 * q_h) * w.dtype_bytes)
    # ring: r-1 steps of the team's K/V chunk (eq. 4 without the setup hop)
    ring_step_bytes = 2 * w.batch * (c * w.seq_len / p) * kv_h * w.dtype_bytes
    ring_bytes = ring_step_bytes * max(r - 1, 0)

    bw_coll = cl.link_bw
    bw_ring = cl.link_bw
    if placement == "team_inner":     # collectives on the short hops
        bw_ring = cl.link_bw / cl.far_penalty
    else:                              # rings on the short hops
        bw_coll = cl.link_bw / cl.far_penalty

    t_coll = coll_bytes / bw_coll
    t_wire_step = ring_step_bytes / bw_ring
    t_lat_step = comm_chunks * cl.step_latency
    t_compute_step = t_compute / max(r, 1)
    # per-step overlap of the permute with the block compute: a fraction
    # overlap_frac of the wire time (up to the compute available) hides;
    # the exposed remainder is pipelined across the comm_chunks sub-chunk
    # transfers (compute on chunk 0 overlaps the wire of chunks 1..n)
    hidden = overlap_frac * min(t_wire_step, t_compute_step)
    t_step_exposed = (t_compute_step + (t_wire_step - hidden) / comm_chunks
                      + t_lat_step)
    t_ring_exposed = max(r - 1, 0) * t_step_exposed
    t_ring_exposed += t_compute_step  # last step has no permute to hide
    t_ring_step = t_wire_step + t_lat_step
    # team collectives overlap with the qkv matmuls only partially (paper:
    # "up to two-thirds"); expose one third
    t_total = t_ring_exposed + t_coll / 3.0

    return {
        "c": c, "placement": placement, "total_s": t_total,
        "compute_s": t_compute, "collective_bytes": coll_bytes,
        "ring_bytes": ring_bytes, "ring_steps": r,
        "compute_step_s": t_compute_step, "ring_step_s": t_ring_step,
        "overlap_frac": overlap_frac, "comm_chunks": comm_chunks,
    }


def choose_comm_chunks(w: AttnWorkload, cl: ClusterModel, c: int,
                       placement: str, *, overlap_frac: float = 1.0,
                       grid: Tuple[int, ...] = (1, 2, 4)) -> int:
    """Smallest-cost comm_chunks under the overlap model (ties -> fewer
    chunks: every extra chunk is an extra message to schedule)."""
    best = min(grid, key=lambda n: (attention_step_cost(
        w, cl, c, placement, overlap_frac=overlap_frac,
        comm_chunks=n)["total_s"], n))
    return int(best)


def schedule(w: AttnWorkload, cl: ClusterModel,
             profile_fn: Optional[Callable[[int, str], float]] = None
             ) -> Dict[str, object]:
    """Grid search; returns the best config + the full grid (paper eq. 8)."""
    grid = []
    for c in valid_c_values(cl.sp_size):
        for placement in ("team_inner", "ring_inner"):
            if profile_fn is not None:
                cost = {"c": c, "placement": placement,
                        "total_s": profile_fn(c, placement)}
            else:
                cost = attention_step_cost(w, cl, c, placement)
            grid.append(cost)
    best = min(grid, key=lambda g: g["total_s"])
    return {"best": best, "grid": grid}
