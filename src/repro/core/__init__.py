"""StarTrail core: concentric-ring sequence parallelism (the paper's contribution)."""

from repro import compat as _compat  # installs jax shims; keep first

from repro.core.combine import combine_pair
from repro.core.ring_attention import ring_attention
from repro.core.startrail import (
    StarTrailConfig,
    decode_attention,
    sharded_startrail_attention,
    shard_positions,
    startrail_attention,
    team_positions,
)
from repro.core.topology import StarTrailTopology, valid_c_values
from repro.core.ulysses import ulysses_attention

__all__ = [
    "StarTrailConfig",
    "StarTrailTopology",
    "combine_pair",
    "decode_attention",
    "ring_attention",
    "sharded_startrail_attention",
    "shard_positions",
    "startrail_attention",
    "team_positions",
    "ulysses_attention",
    "valid_c_values",
]
