"""DeepSpeed-Ulysses baseline: all-to-all head-sharded sequence parallelism.

Included because the paper compares against it (§2.2.1) and to demonstrate
its head-count scalability limit: the SP degree cannot exceed the number of
KV heads (GQA), which is why e.g. paligemma (kv=1) cannot use it at all —
StarTrail has no such limit. Raises a clear error in that case.

Implementation: two ``jax.lax.all_to_all`` collectives over the joint SP
axes swap the sharded dimension seq <-> heads around a fully-local
attention.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kernels
from repro.core.startrail import StarTrailConfig, shard_positions


def ulysses_attention(q, k, v, cfg: StarTrailConfig):
    """Per-shard Ulysses attention (inside shard_map over cfg.axes).

    q: (B, S_local, Hq, D); k, v: (B, S_local, Hkv, D).
    """
    axes = tuple(cfg.axes)
    sp = 1
    for a in axes:
        sp *= jax.lax.axis_size(a)
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv % sp != 0 or Hq % sp != 0:
        raise ValueError(
            f"Ulysses requires head counts divisible by SP degree: "
            f"Hq={Hq}, Hkv={Hkv}, SP={sp} (the paper's scalability limit)"
        )

    # seq-sharded -> head-sharded: gather seq (axis 1), scatter heads (axis 2)
    qh = jax.lax.all_to_all(q, axes, split_axis=2, concat_axis=1, tiled=True)
    kh = jax.lax.all_to_all(k, axes, split_axis=2, concat_axis=1, tiled=True)
    vh = jax.lax.all_to_all(v, axes, split_axis=2, concat_axis=1, tiled=True)

    # positions: full sequence, in shard-major order of the chosen scheme
    ranks = jnp.arange(sp, dtype=jnp.int32)
    pos = jax.vmap(lambda r: shard_positions(r, cfg.seq_len, sp, cfg.seq_scheme))(ranks).reshape(-1)

    o = kernels.prefill(
        qh, kh, vh, pos, pos, causal=cfg.causal, window=cfg.window,
        scale=cfg.scale, prefix_len=cfg.prefix_len, impl=cfg.block_impl,
    )
    # head-sharded -> seq-sharded
    return jax.lax.all_to_all(o, axes, split_axis=1, concat_axis=2, tiled=True)
