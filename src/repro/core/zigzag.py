"""Zigzag sequence sharding (StarTrail/WallFacer §3.5, after [Zhu et al.]).

For causal masks, contiguous sequence sharding is unbalanced: the shard
holding the head of the sequence does ~0 work while the tail shard does the
most. The zigzag loader splits the sequence into 2*P chunks and gives shard
p chunks (p, 2P-1-p), so every shard owns one "early" and one "late" chunk
and the causal workload is balanced to within one chunk.

Positions are carried explicitly through the attention (the mask is
``pos_k <= pos_q``), so any assignment is *correct*; zigzag only changes the
balance. These helpers are pure index manipulation usable both host-side
(numpy, data pipeline) and trace-side (jnp).
"""

from __future__ import annotations

import numpy as np


def zigzag_positions(seq_len: int, num_shards: int) -> np.ndarray:
    """Global token positions per shard, shape (num_shards, seq_len // num_shards).

    Shard p owns chunks p and 2P-1-p of the 2P-chunk split, concatenated.
    """
    if seq_len % (2 * num_shards) != 0:
        raise ValueError(
            f"seq_len={seq_len} must be divisible by 2*num_shards={2 * num_shards}"
        )
    chunk = seq_len // (2 * num_shards)
    pos = np.arange(seq_len, dtype=np.int32).reshape(2 * num_shards, chunk)
    out = np.empty((num_shards, 2 * chunk), dtype=np.int32)
    for p in range(num_shards):
        out[p] = np.concatenate([pos[p], pos[2 * num_shards - 1 - p]])
    return out


def contiguous_positions(seq_len: int, num_shards: int) -> np.ndarray:
    """Plain contiguous sharding (used for full/bidirectional masks)."""
    if seq_len % num_shards != 0:
        raise ValueError(f"seq_len={seq_len} % num_shards={num_shards} != 0")
    return (
        np.arange(seq_len, dtype=np.int32).reshape(num_shards, seq_len // num_shards)
    )


def make_positions(seq_len: int, num_shards: int, scheme: str) -> np.ndarray:
    if scheme == "zigzag":
        return zigzag_positions(seq_len, num_shards)
    if scheme == "contiguous":
        return contiguous_positions(seq_len, num_shards)
    raise ValueError(f"unknown sharding scheme {scheme!r}")


def permutation_for(positions: np.ndarray) -> np.ndarray:
    """Flat permutation perm with x_sharded = x[perm] (host-side reorder).

    `positions.reshape(-1)` IS that permutation: entry i of the flattened
    sharded layout holds global token positions[i // S, i % S].
    """
    return positions.reshape(-1)


def inverse_permutation_for(positions: np.ndarray) -> np.ndarray:
    perm = permutation_for(positions)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=perm.dtype)
    return inv


def shard_tokens(x: np.ndarray, positions: np.ndarray, axis: int = -1) -> np.ndarray:
    """Reorder a (…, seq_len, …) array so an even split over `axis` realises
    the given per-shard positions. Host-side (numpy)."""
    perm = permutation_for(positions)
    return np.take(x, perm, axis=axis)


def unshard_tokens(x: np.ndarray, positions: np.ndarray, axis: int = -1) -> np.ndarray:
    inv = inverse_permutation_for(positions)
    return np.take(x, inv, axis=axis)


def causal_workload(positions: np.ndarray, seq_len: int) -> np.ndarray:
    """Number of (q, k) pairs each shard computes under a causal mask,
    assuming it sees all keys (ring completes a full tour). Used by tests
    and the load-balance benchmark."""
    # each query at global position g attends to g+1 keys
    return (positions.astype(np.int64) + 1).sum(axis=1)


def balance_ratio(positions: np.ndarray, seq_len: int) -> float:
    """max/mean causal workload across shards; 1.0 = perfectly balanced."""
    w = causal_workload(positions, seq_len)
    return float(w.max() / w.mean())
