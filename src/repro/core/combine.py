"""Numerically-stable combination of partial softmax-attention results.

A partial result is a pair (o, lse) where

    o   = softmax(s_block) @ v_block          (normalised within the block)
    lse = logsumexp(s_block, axis=keys)

Two partials over disjoint key sets merge exactly:

    m      = max(lse1, lse2)
    w_i    = exp(lse_i - m)
    o      = (w1 * o1 + w2 * o2) / (w1 + w2)
    lse    = m + log(w1 + w2)

Fully-masked blocks carry lse = -inf and weight 0. All math in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # used instead of -inf to keep XLA/grad paths NaN-free


def combine_pair(o1, lse1, o2, lse2):
    """Merge two partial attention results.

    Shapes: o (..., S, H, D); lse (..., H, S). Returns (o, lse) in f32.
    """
    o1 = o1.astype(jnp.float32)
    o2 = o2.astype(jnp.float32)
    lse1 = lse1.astype(jnp.float32)
    lse2 = lse2.astype(jnp.float32)
    m = jnp.maximum(lse1, lse2)
    # guard: if both are NEG_INF the row saw no keys at all; emit zeros.
    both_dead = m <= NEG_INF / 2
    m_safe = jnp.where(both_dead, 0.0, m)
    w1 = jnp.exp(lse1 - m_safe)
    w2 = jnp.exp(lse2 - m_safe)
    denom = w1 + w2
    denom_safe = jnp.where(denom == 0.0, 1.0, denom)
    # broadcast weights (..., H, S) -> (..., S, H, 1)
    w1b = _lse_to_o_layout(w1)
    w2b = _lse_to_o_layout(w2)
    db = _lse_to_o_layout(denom_safe)
    o = (w1b * o1 + w2b * o2) / db
    lse = jnp.where(both_dead, NEG_INF, m_safe + jnp.log(denom_safe))
    return o, lse


def _lse_to_o_layout(x):
    """(..., H, S) -> (..., S, H, 1) to broadcast against o."""
    return jnp.swapaxes(x, -1, -2)[..., None]


def finalize(o, lse):
    """No-op placeholder kept for API symmetry; o is already normalised."""
    return o, lse
