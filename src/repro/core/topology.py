"""Communication Configuration Generator (StarTrail / WallFacer Algs. 2-3).

The paper groups the P sequence-parallel devices into *teams* of size C.
Teams are numbered 0..P/C-1; rings ("concentric rings") are formed across
teams that belong to the same *team group* (P/C^2 teams per group), by
members sharing the same intra-team rank.

We realise the topology structurally on a 3-axis mesh factorisation of the
sequence-parallel dimension:

    (sp_grp = C, sp_ring = R, sp_team = C)        with P = C^2 * R

Device coordinates (g, j, t):
    g : team-group index          (which 1/C slice of K/V this ring covers)
    j : position within the ring  (paper: team-in-group index)
    t : intra-team rank           (paper: r_a)

The global *team* index of device (g, j, t) is tau = g*R + j and its global
sequence-parallel rank is  p = g*R*C + j*C + t  (major-to-minor (g, j, t)),
which matches ``PartitionSpec(("sp_grp", "sp_ring", "sp_team"))`` sharding
of the sequence dimension.

This module is pure Python (no jax device state) so it is unit-testable and
usable at trace time. The paper's Algorithms 2 and 3 are ported verbatim
(`paper_get_init_send`, `paper_get_p2p_config`) and the structural versions
are proven equivalent to them in tests/test_topology.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class StarTrailTopology:
    """Static description of a concentric-ring topology.

    Attributes:
      sp_size: P, total number of sequence-parallel devices.
      c: the attention-parallel size (team size / replication factor).
    """

    sp_size: int
    c: int

    def __post_init__(self):
        if self.c < 1:
            raise ValueError(f"C must be >= 1, got {self.c}")
        if self.sp_size % (self.c * self.c) != 0:
            raise ValueError(
                f"P={self.sp_size} must be divisible by C^2={self.c * self.c}"
            )
        if self.c > int(math.isqrt(self.sp_size)):
            raise ValueError(
                f"C={self.c} out of range [1, sqrt(P)={math.isqrt(self.sp_size)}]"
            )

    # ---- derived sizes -------------------------------------------------
    @property
    def ring_size(self) -> int:
        """R = P / C^2: number of devices (teams) in each sub-ring."""
        return self.sp_size // (self.c * self.c)

    @property
    def num_teams(self) -> int:
        return self.sp_size // self.c

    @property
    def num_team_groups(self) -> int:
        return self.c

    @property
    def teams_per_group(self) -> int:  # == ring_size
        return self.ring_size

    # ---- coordinate conversions ---------------------------------------
    def coords(self, rank: int) -> Tuple[int, int, int]:
        """Global SP rank -> (g, j, t)."""
        c, r = self.c, self.ring_size
        g, rem = divmod(rank, r * c)
        j, t = divmod(rem, c)
        return g, j, t

    def rank(self, g: int, j: int, t: int) -> int:
        return (g * self.ring_size + j) * self.c + t

    def team_of(self, g: int, j: int) -> int:
        return g * self.ring_size + j

    # ---- K/V assignment -------------------------------------------------
    def kv_team_at_step(self, g: int, j: int, t: int, step: int) -> int:
        """Which team's K/V chunk device (g, j, t) holds at ring step `step`.

        Step 0 is the state right after the initial placement permutation.
        The ring shifts so that device j receives from device (j+1) % R.
        """
        del g  # coverage is identical across groups by design
        jj = (j + step) % self.ring_size
        return jj * self.c + t

    # ---- permutations (linear ranks, for lax.ppermute) -------------------
    def init_placement_permutation(self) -> List[Tuple[int, int]]:
        """The paper's Alg. 2: route each team's gathered K/V to its ring slot.

        Member t' of team tau' sends the team chunk to team-group g = t',
        ring position j = tau' // C, intra rank t = tau' % C. A bijection on
        [0, P).
        """
        perm = []
        for g in range(self.c):
            for j in range(self.ring_size):
                for t in range(self.c):
                    src = self.rank(g, j, t)
                    tau = self.team_of(g, j)
                    dst = self.rank(t, tau // self.c, tau % self.c)
                    perm.append((src, dst))
        return perm

    def inverse_placement_permutation(self) -> List[Tuple[int, int]]:
        """Transpose/inverse of `init_placement_permutation` (for backward)."""
        return [(d, s) for (s, d) in self.init_placement_permutation()]

    def ring_permutation(self, shift: int = 1) -> List[Tuple[int, int]]:
        """Cyclic shift along the ring axis: device j sends to j - shift.

        With shift=+1 each device *receives* the chunk of its j+1 neighbour,
        so after s steps device j holds the chunk initially at (j+s) % R
        (consistent with `kv_team_at_step`).
        """
        perm = []
        for g in range(self.c):
            for j in range(self.ring_size):
                for t in range(self.c):
                    src = self.rank(g, j, t)
                    dst = self.rank(g, (j - shift) % self.ring_size, t)
                    perm.append((src, dst))
        return perm

    # ---- invariants (used by property tests and the scheduler) ----------
    def coverage(self, g: int, j: int, t: int) -> List[int]:
        """All K/V team chunks device (g,j,t) sees across the ring steps."""
        return [self.kv_team_at_step(g, j, t, s) for s in range(self.ring_size)]

    def check_invariants(self) -> None:
        """Paper §3.3: team members jointly cover all K/V exactly once; no
        two teams within the same ring hold identical K/V."""
        for g in range(self.c):
            for j in range(self.ring_size):
                seen: List[int] = []
                for t in range(self.c):
                    cov = self.coverage(g, j, t)
                    if len(set(cov)) != len(cov):
                        raise AssertionError("duplicate K/V within a ring")
                    seen.extend(cov)
                if sorted(seen) != list(range(self.num_teams)):
                    raise AssertionError(
                        f"team (g={g}, j={j}) does not cover all K/V exactly once: {sorted(seen)}"
                    )
        # placement permutation must be a bijection
        perm = self.init_placement_permutation()
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert sorted(srcs) == list(range(self.sp_size))
        assert sorted(dsts) == list(range(self.sp_size))


# ---------------------------------------------------------------------------
# Verbatim ports of the paper's Algorithms 2 and 3 (inter-team rank r_t,
# intra-team rank r_a, inter-team dimension d_t = #teams, intra-team
# dimension d_a = C). Kept for fidelity + tested equivalent to the
# structural formulation above.
# ---------------------------------------------------------------------------

def paper_get_init_send(r_t: int, r_a: int, d_t: int, d_a: int) -> int:
    """Algorithm 2: get_init_send()."""
    team_group_size = d_t // d_a
    target_team_group_rank = r_a
    target_team = target_team_group_rank * team_group_size + r_t // d_a
    target_device_intra_team_rank = r_t % d_a
    return target_team * d_a + target_device_intra_team_rank


def paper_get_p2p_config(r_t: int, r_a: int, d_t: int, d_a: int) -> Tuple[int, int]:
    """Algorithm 3: get_P2P_config() -> (next_global_rank, last_global_rank)."""
    team_group_size = d_t // d_a
    self_team_group_rank = r_t // team_group_size
    next_team_in_group = (r_t + 1) % team_group_size + team_group_size * self_team_group_rank
    last_team_in_group = (r_t - 1) % team_group_size + team_group_size * self_team_group_rank
    next_rank = r_a + next_team_in_group * d_a
    last_rank = r_a + last_team_in_group * d_a
    return next_rank, last_rank


def paper_rank(topo: StarTrailTopology, r_t: int, r_a: int) -> int:
    """Paper's flat numbering: global = team * C + intra."""
    return r_t * topo.c + r_a


def valid_c_values(sp_size: int) -> List[int]:
    """All C in [1, sqrt(P)] with P % C^2 == 0 (the scheduler's search space)."""
    out = []
    c = 1
    while c * c <= sp_size:
        if sp_size % (c * c) == 0:
            out.append(c)
        c += 1
    return out
