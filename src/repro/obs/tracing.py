"""Nestable spans with a thread-safe in-memory buffer, exported as
Chrome-trace-format JSON (chrome://tracing / Perfetto "traceEvents").

Two span flavors:

  * ``span(name, ...)`` — a synchronous complete event (ph="X") covering
    a with-block: an engine step, a prefill chunk, a train phase. Nesting
    comes for free from Chrome's stack-building on (pid, tid, ts, dur).
  * ``async_begin``/``async_end`` — async events (ph="b"/"e") keyed by an
    id, for spans that outlive any single stack frame: a request's whole
    lifecycle from admission to finish, crossing gateway router →
    scheduler → engine steps.

The disabled tracer (default, and the module-level ``NULL_TRACER``) makes
every call a no-op returning a shared null context manager — the serving
hot loop pays one attribute check per span site, nothing else, so leaving
instrumentation in place costs ~nothing when ``--trace-out`` is absent.

``annotate=True`` additionally wraps each sync span in
``jax.profiler.TraceAnnotation`` so host-side spans line up with device
timelines when a jax profile is captured alongside.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NullContext:
    """Reusable no-op context manager (allocated once, never per-span)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CONTEXT = _NullContext()


class Tracer:
    def __init__(self, enabled: bool = True, annotate: bool = False):
        self.enabled = enabled
        self.annotate = annotate
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._ids = itertools.count(1)
        # epoch for ts: trace-relative µs keeps numbers small and stable
        self._t0 = time.perf_counter()

    # -- clock ------------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # -- sync spans -------------------------------------------------------
    def span(self, name: str, cat: str = "repro", **args):
        """Context manager recording a complete event over the block."""
        if not self.enabled:
            return _NULL_CONTEXT
        return self._span(name, cat, args)

    @contextlib.contextmanager
    def _span(self, name: str, cat: str, args: Dict[str, Any]):
        if self.annotate:
            ann = _trace_annotation(name)
        else:
            ann = _NULL_CONTEXT
        ts = self._now_us()
        with ann:
            try:
                yield self
            finally:
                dur = self._now_us() - ts
                self._emit({"name": name, "cat": cat, "ph": "X",
                            "ts": ts, "dur": dur, "pid": os.getpid(),
                            "tid": threading.get_ident(),
                            **({"args": args} if args else {})})

    # -- async (cross-frame) spans ---------------------------------------
    def async_begin(self, name: str, cat: str = "request",
                    span_id: Optional[str] = None, **args) -> Optional[str]:
        """Open an async span; returns the id to pass to ``async_end``."""
        if not self.enabled:
            return None
        sid = span_id if span_id is not None else f"s{next(self._ids)}"
        self._emit({"name": name, "cat": cat, "ph": "b", "id": str(sid),
                    "ts": self._now_us(), "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    **({"args": args} if args else {})})
        return sid

    def async_end(self, name: str, span_id: Optional[str],
                  cat: str = "request", **args) -> None:
        if not self.enabled or span_id is None:
            return
        self._emit({"name": name, "cat": cat, "ph": "e", "id": str(span_id),
                    "ts": self._now_us(), "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    **({"args": args} if args else {})})

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """Zero-duration marker (ph='i')."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self._now_us(), "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    **({"args": args} if args else {})})

    # -- buffer -----------------------------------------------------------
    def _emit(self, event: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(event)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def extend(self, events: List[Dict[str, Any]]) -> None:
        """Merge events recorded by another tracer (typically shipped back
        from a worker process at drain — each event already carries its
        origin ``pid``, so Chrome/Perfetto lays processes out side by
        side). Events are appended as-is: the two tracers' clocks are
        both process-relative, close enough for eyeballing one serve run.
        Works on a disabled tracer too — the merged trace is still
        dumpable even when local span recording is off."""
        with self._lock:
            self._events.extend(events)

    # -- export -----------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def dump(self, path) -> None:
        import pathlib

        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.chrome_trace()))


def _trace_annotation(name: str):
    """A jax.profiler.TraceAnnotation when jax is importable, else a no-op
    (the obs layer must not force jax into pure-host tools)."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:
        return _NULL_CONTEXT


#: Shared disabled tracer — the default for every producer, so span sites
#: cost one truthiness check when tracing is off.
NULL_TRACER = Tracer(enabled=False)
