"""Process-local metrics registry: counters, gauges and fixed-bucket
histograms with labels, rendered as Prometheus text or JSON.

This is the store every producer in the system writes through — the
engine's ``EngineMetrics``, the gateway's per-replica aggregation, the
kernel dispatch layer's pallas->ref fallback provenance, the trainer's
comm-volume accounting — so one scrape (``render_prometheus``) or dump
(``--metrics-dump``) sees the whole system at once.

Design constraints (why this is not just ``prometheus_client``):

  * **No dependencies, near-zero overhead.** A counter ``inc`` is one dict
    lookup + add; a histogram ``observe`` is a bisect over ~16 static
    bucket bounds. The serving hot loop ticks these per token.
  * **Deterministic fixed buckets.** TTFT and inter-token latency use
    pinned bucket bounds (``TTFT_BUCKETS`` / ``INTERTOKEN_BUCKETS``) so
    quantile estimates are reproducible across runs and comparable across
    benchmark JSONs — no adaptive sketches.
  * **Resettable.** Engines reset their metrics between benchmark phases
    (``keep_compiles`` semantics); Prometheus counters are monotonic for a
    scraper, but a process-local registry may zero a series explicitly.
  * **Labels are per-sample dicts.** A series is (metric name, sorted label
    items); ``sum_values``/``collect`` aggregate over label subsets, which
    is how ``Engine.pallas_fallbacks()`` sums the dispatch layer's
    ``scope``-labeled fallback counters without snapshot-delta arithmetic.

A process-global registry (``global_registry()``) holds cross-cutting
series (kernel fallbacks); components own private ``Registry`` instances
(or share one with distinguishing labels, as gateway replicas do). The
``scope(...)`` context manager tags global-registry writes with the active
component so per-instance attribution needs no snapshots.
"""

from __future__ import annotations

import bisect
import contextlib
import contextvars
import json
import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# attribution scope (who is currently tracing/running device code)
# ---------------------------------------------------------------------------

_SCOPE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_scope", default="global")


def current_scope() -> str:
    """The active attribution scope ('global' outside any ``scope(...)``)."""
    return _SCOPE.get()


@contextlib.contextmanager
def scope(name: str):
    """Tag global-registry writes (e.g. dispatch fallbacks) with ``name``."""
    tok = _SCOPE.set(name)
    try:
        yield
    finally:
        _SCOPE.reset(tok)


# ---------------------------------------------------------------------------
# label plumbing
# ---------------------------------------------------------------------------

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _matches(key: LabelKey, subset: Dict[str, object]) -> bool:
    have = dict(key)
    return all(have.get(k) == str(v) for k, v in subset.items())


_ESCAPES = {"\\": "\\\\", "\n": "\\n", '"': '\\"'}


def _escape(v: str) -> str:
    return "".join(_ESCAPES.get(ch, ch) for ch in v)


def _fmt_labels(key: LabelKey, extra: Sequence[Tuple[str, str]] = ()) -> str:
    items = list(key) + list(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items) + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


# ---------------------------------------------------------------------------
# metric kinds
# ---------------------------------------------------------------------------

class Metric:
    """Base: one named family of labeled series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        if not re.fullmatch(r"[a-zA-Z_:][a-zA-Z0-9_:]*", name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: Dict[LabelKey, float] = {}

    # -- reads ------------------------------------------------------------
    def value(self, **labels) -> float:
        """The exact series' value (0.0 for a never-touched series)."""
        return self._series.get(_label_key(labels), 0.0)

    def sum(self, **labels) -> float:
        """Sum over every series whose labels are a superset of ``labels``."""
        return sum(v for k, v in self._series.items() if _matches(k, labels))

    def series(self, **labels) -> Dict[LabelKey, float]:
        """{label key -> value} for series matching the label subset."""
        return {k: v for k, v in self._series.items() if _matches(k, labels)}

    # -- writes -----------------------------------------------------------
    def _add(self, amount: float, labels: Dict[str, object]) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def _put(self, value: float, labels: Dict[str, object]) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def reset(self, **labels) -> None:
        """Drop series matching the label subset (all, when unlabeled)."""
        with self._lock:
            for k in [k for k in self._series if _matches(k, labels)]:
                del self._series[k]

    # -- rendering --------------------------------------------------------
    def render(self) -> List[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {_escape(self.help)}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        for key in sorted(self._series):
            lines.append(f"{self.name}{_fmt_labels(key)} "
                         f"{_fmt_value(self._series[key])}")
        return lines

    def to_json(self) -> Dict:
        return {
            "kind": self.kind,
            "help": self.help,
            "series": [{"labels": dict(k), "value": v}
                       for k, v in sorted(self._series.items())],
        }


class Counter(Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self._add(amount, labels)

    def set(self, value: float, **labels) -> None:
        """Process-local reset support (benchmark phases); a scraped
        counter should only ever ``inc``."""
        self._put(value, labels)


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._put(value, labels)

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._add(amount, labels)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self._add(-amount, labels)

    def max(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = max(self._series.get(key, 0.0), float(value))


# Deterministic fixed buckets (seconds). TTFT spans ms..minute; the
# inter-token gap is the decode-step scale. Pinned so quantiles are
# reproducible run-to-run and comparable across benchmark JSONs.
TTFT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
INTERTOKEN_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                      0.1, 0.25, 0.5, 1.0, 2.5)


class Histogram(Metric):
    """Fixed-bucket histogram; per-series (bucket counts, sum, count).

    ``self._series`` (from the base class) holds the ``_sum`` line;
    ``self._counts[key]`` the per-bucket cumulative-ready counts and
    ``self._n[key]`` the observation count.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = TTFT_BUCKETS):
        super().__init__(name, help)
        bounds = tuple(float(b) for b in buckets)
        if list(bounds) != sorted(set(bounds)) or not bounds:
            raise ValueError(f"histogram {name}: buckets must be strictly "
                             f"increasing and non-empty, got {buckets}")
        if bounds[-1] == math.inf:
            bounds = bounds[:-1]
        self.buckets = bounds
        self._counts: Dict[LabelKey, List[int]] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        i = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * (len(self.buckets) + 1)
                self._n[key] = 0
            counts[i] += 1
            self._n[key] += 1
            self._series[key] = self._series.get(key, 0.0) + float(value)

    def count(self, **labels) -> int:
        return sum(n for k, n in self._n.items() if _matches(k, labels))

    def bucket_counts(self, **labels) -> List[int]:
        """Per-bucket (non-cumulative) counts summed over matching series;
        the final entry is the +Inf overflow bucket."""
        out = [0] * (len(self.buckets) + 1)
        for k, counts in self._counts.items():
            if _matches(k, labels):
                for i, c in enumerate(counts):
                    out[i] += c
        return out

    def quantile(self, q: float, **labels) -> float:
        """Quantile estimate from the fixed buckets (linear interpolation
        inside the located bucket; exact to bucket resolution).

        The +Inf bucket clamps to the largest finite bound — the estimate
        is a lower bound there, like any bucketed histogram's.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        counts = self.bucket_counts(**labels)
        total = sum(counts)
        if total == 0:
            return 0.0
        rank = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) \
                    else self.buckets[-1]
                frac = (rank - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.buckets[-1]

    def reset(self, **labels) -> None:
        with self._lock:
            for k in [k for k in self._counts if _matches(k, labels)]:
                del self._counts[k]
                del self._n[k]
        super().reset(**labels)

    def render(self) -> List[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {_escape(self.help)}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        for key in sorted(self._counts):
            cum = 0
            for bound, c in zip(self.buckets, self._counts[key]):
                cum += c
                lines.append(
                    f"{self.name}_bucket"
                    f"{_fmt_labels(key, [('le', _fmt_value(bound))])} {cum}")
            cum += self._counts[key][-1]
            lines.append(f"{self.name}_bucket"
                         f"{_fmt_labels(key, [('le', '+Inf')])} {cum}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_value(self._series.get(key, 0.0))}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} "
                         f"{self._n[key]}")
        return lines

    def to_json(self) -> Dict:
        d = super().to_json()
        d["buckets"] = list(self.buckets)
        d["series"] = [{"labels": dict(k),
                        "counts": list(self._counts[k]),
                        "sum": self._series.get(k, 0.0),
                        "count": self._n[k]}
                       for k in sorted(self._counts)]
        return d


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

class Registry:
    """A named collection of metrics; get-or-create with kind checking."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = TTFT_BUCKETS) -> Histogram:
        h = self._get_or_create(Histogram, name, help, buckets=buckets)
        if tuple(float(b) for b in buckets) != h.buckets:
            raise ValueError(f"histogram {name!r} already registered with "
                             f"buckets {h.buckets}")
        return h

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def metrics(self) -> Iterable[Metric]:
        return list(self._metrics.values())

    def value(self, name: str, **labels) -> float:
        m = self._metrics.get(name)
        return m.value(**labels) if m else 0.0

    def sum_values(self, name: str, **labels) -> float:
        m = self._metrics.get(name)
        return m.sum(**labels) if m else 0.0

    # -- export -----------------------------------------------------------
    def render_prometheus(self) -> str:
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> Dict:
        return {name: m.to_json()
                for name, m in sorted(self._metrics.items())}

    def dump(self, path, fmt: str = "prometheus") -> None:
        import pathlib

        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        if fmt == "prometheus":
            p.write_text(self.render_prometheus())
        elif fmt == "json":
            p.write_text(json.dumps(self.to_json(), indent=2, sort_keys=True))
        else:
            raise ValueError(f"fmt must be 'prometheus' or 'json', got {fmt!r}")


_GLOBAL = Registry()


def global_registry() -> Registry:
    """The process-global registry (cross-cutting series: kernel-dispatch
    fallback provenance). Component metrics belong in private registries."""
    return _GLOBAL


# ---------------------------------------------------------------------------
# Prometheus text parsing (round-trip tests + benchmark gates that must
# read the *exported* metric, not in-process state)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)\s*$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape(v: str) -> str:
    return v.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\")


def parse_prometheus(text: str) -> Dict[Tuple[str, LabelKey], float]:
    """Prometheus text -> {(sample name, label key) -> value}.

    Histogram series appear under their ``_bucket``/``_sum``/``_count``
    sample names, exactly as scraped.
    """
    out: Dict[Tuple[str, LabelKey], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            for lm in _LABEL_RE.finditer(m.group("labels")):
                labels[lm.group(1)] = _unescape(lm.group(2))
        raw = m.group("value")
        value = {"+Inf": math.inf, "-Inf": -math.inf,
                 "NaN": math.nan}.get(raw)
        out[(m.group("name"), _label_key(labels))] = \
            float(raw) if value is None else value
    return out


def parse_prometheus_families(text: str) -> Dict[str, Dict]:
    """``# TYPE``-aware Prometheus text parse.

    Returns {family name -> {"kind", "help", "samples"}} where ``samples``
    maps (sample name, label key) -> value, sample names keeping their
    ``_bucket``/``_sum``/``_count`` suffixes for histograms. Families
    without a ``# TYPE`` line parse as ``untyped`` under their sample
    name. This is the structured half of cross-process metrics merging:
    ``Registry.merge_prometheus_text`` consumes it to rebuild real
    Counter/Gauge/Histogram series from a worker's scrape.
    """
    kinds: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    out: Dict[str, Dict] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            kinds[name] = kind.strip()
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, h = rest.partition(" ")
            helps[name] = _unescape(h)
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        sample = m.group("name")
        family = sample
        for suffix in ("_bucket", "_sum", "_count"):
            base = sample[:-len(suffix)] if sample.endswith(suffix) else None
            if base and kinds.get(base) == "histogram":
                family = base
                break
        fam = out.setdefault(family, {
            "kind": kinds.get(family, "untyped"),
            "help": helps.get(family, ""),
            "samples": {},
        })
        labels: Dict[str, str] = {}
        if m.group("labels"):
            for lm in _LABEL_RE.finditer(m.group("labels")):
                labels[lm.group(1)] = _unescape(lm.group(2))
        raw = m.group("value")
        special = {"+Inf": math.inf, "-Inf": -math.inf,
                   "NaN": math.nan}.get(raw)
        fam["samples"][(sample, _label_key(labels))] = \
            float(raw) if special is None else special
    return out


def merge_prometheus_text(registry: Registry, text: str,
                          **extra_labels) -> Registry:
    """Fold a scraped Prometheus exposition into ``registry``, adding
    ``extra_labels`` to every series (the orchestrator merges each
    worker's ``/metrics`` text under ``worker=<i>``).

    Counters and gauges merge by *addition* so same-named series from
    several workers aggregate; histograms are rebuilt bucket-for-bucket —
    cumulative ``_bucket`` lines are differenced back to per-bucket
    counts, and ``_sum``/``_count`` restored — so quantile estimates over
    the merged registry see every process's observations. Merge each
    scrape into a *fresh* registry (merging the same text twice
    double-counts, exactly like summing a scrape with itself).
    """
    for family, fam in parse_prometheus_families(text).items():
        kind, help_, samples = fam["kind"], fam["help"], fam["samples"]
        if kind == "histogram":
            # bucket bounds from any one series' finite `le` labels
            bounds = sorted({float(dict(key)["le"])
                             for (s, key) in samples
                             if s == f"{family}_bucket"
                             and dict(key)["le"] != "+Inf"
                             and not math.isinf(float(dict(key)["le"]))})
            if not bounds:
                continue
            h = registry.histogram(family, help_, buckets=bounds)
            series: Dict[LabelKey, Dict[float, float]] = {}
            sums: Dict[LabelKey, float] = {}
            counts_n: Dict[LabelKey, float] = {}
            for (sample, key), v in samples.items():
                if sample == f"{family}_bucket":
                    lab = dict(key)
                    le_raw = lab.pop("le")
                    le = math.inf if le_raw == "+Inf" else float(le_raw)
                    series.setdefault(_label_key(lab), {})[le] = v
                elif sample == f"{family}_sum":
                    sums[key] = v
                elif sample == f"{family}_count":
                    counts_n[key] = v
            for key, bucket_map in series.items():
                lab = dict(key)
                lab.update({k: str(v) for k, v in extra_labels.items()})
                dst = _label_key(lab)
                cum = [bucket_map.get(b, 0.0) for b in bounds]
                cum.append(bucket_map.get(math.inf, cum[-1] if cum else 0.0))
                per = [cum[0]] + [cum[i] - cum[i - 1]
                                  for i in range(1, len(cum))]
                with h._lock:
                    have = h._counts.setdefault(
                        dst, [0] * (len(h.buckets) + 1))
                    for i, c in enumerate(per):
                        have[i] += int(c)
                    h._n[dst] = h._n.get(dst, 0) + int(counts_n.get(key, 0))
                    h._series[dst] = h._series.get(dst, 0.0) \
                        + sums.get(key, 0.0)
            continue
        m = registry.counter(family, help_) if kind == "counter" \
            else registry.gauge(family, help_)
        for (sample, key), v in samples.items():
            lab = dict(key)
            lab.update({k: str(v2) for k, v2 in extra_labels.items()})
            m._add(v, lab)
    return registry
