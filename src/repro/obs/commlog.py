"""Per-step communication accounting: measured-vs-analytical bytes per
collective for the plan's resolved arrangement.

Three layers:

  * ``analytical_wire_volumes(cfg, plan)`` — the `plan/cost.comm_volumes`
    closed forms (paper eqs. 2–4) regrouped by HLO collective kind:
    team all-gather, placement + sub-ring ppermute (collective-permute),
    lse-combine reduce-scatter, Ulysses all-to-all.
  * ``measure_attention_island(cfg, plan)`` — compile the actual attention
    island on ``plan.build_mesh()`` with unrolled ring scans and parse the
    compiled HLO's collective result buffers
    (``roofline/hlo.collective_bytes``), converting result bytes to wire
    bytes per device per collective's algorithm.
  * ``comm_report(cfg, plan)`` — per-kind measured/analytical/ratio table
    with a single ``within_tolerance`` verdict; this is the artifact the
    CI ``obs-smoke`` job gates within 5% on the C=2 smoke mesh.

Result-bytes → wire-bytes conversion (per device, per op):

  ===================  =========  =======================================
  op                   factor     why
  ===================  =========  =======================================
  all-gather           (c-1)/c    result is the full gathered tensor; a
                                  device sends/receives (c-1) of c shards
  reduce-scatter       (c-1)      result is the scattered *shard*; each
                                  device moves (c-1) shard-sized messages
  collective-permute   1          result == the message
  all-to-all           (p-1)/p    a device keeps its own 1/p slice
  ===================  =========  =======================================

The lse-combine ``pmax``/``psum`` all-reduces (numerics glue, not a paper
term) are *unmodelled*: reported under ``unmodelled_allreduce_bytes`` but
excluded from the tolerance gate.

Wire dtype: the CPU backend legalises bf16 to f32 (dtype_bytes=4, as
``benchmarks/comm_volume.py`` and EXPERIMENTS.md document); on TPU the
wire dtype is bf16 (dtype_bytes=2). ``_wire_dtype_bytes()`` picks by
backend so measured and analytical always use the same width.

``CommLog`` is the trainer-facing face: it prices one train step's
attention communication once at construction (analytical wire bytes ×
attention-layer count, ×3 for fwd + bwd's two passes over the same
collectives) and ticks ``comm_bytes_total{collective=...}`` registry
counters per step — bookkeeping only, no device sync.
"""

from __future__ import annotations

import json
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.plan import cost

#: HLO op kind <- cost.comm_volumes component mapping.
KIND_FROM_COMPONENTS = {
    "all-gather": ("team_allgather",),
    "collective-permute": ("placement_p2p", "ring_p2p"),
    "reduce-scatter": ("combine_rs",),
    "all-to-all": ("all_to_all",),
}


def _wire_dtype_bytes() -> int:
    import jax

    return 4 if jax.default_backend() == "cpu" else 2


def _arrangement(plan) -> "cost.Arrangement":
    return cost.Arrangement(plan.scheme, plan.c, plan.r,
                            placement=plan.placement)


def analytical_wire_volumes(cfg: ModelConfig, plan, *,
                            batch: int = 1,
                            seq_len: Optional[int] = None,
                            dtype_bytes: Optional[int] = None,
                            ) -> Dict[str, float]:
    """Per-device wire bytes per attention layer, keyed by HLO op kind."""
    n = seq_len or plan.seq_len
    shape = ShapeConfig(plan.shape, seq_len=n, global_batch=batch,
                        kind="train")
    vols = cost.comm_volumes(
        cfg, shape, plan.sp_size, _arrangement(plan), batch=batch,
        dtype_bytes=_wire_dtype_bytes() if dtype_bytes is None
        else dtype_bytes)
    return {kind: sum(vols[c] for c in comps)
            for kind, comps in KIND_FROM_COMPONENTS.items()}


# result-bytes -> wire-bytes factors; group size filled per plan
def _wire_factors(plan) -> Dict[str, float]:
    c = plan.c
    p = plan.sp_size
    return {
        "all-gather": (c - 1) / c if c > 1 else 0.0,
        "reduce-scatter": float(c - 1),
        "collective-permute": 1.0,
        "all-to-all": (p - 1) / p if p > 1 else 0.0,
    }


def _compile_island_text(cfg: ModelConfig, plan, *, batch: int = 1,
                         seq_len: Optional[int] = None) -> str:
    """Optimized HLO text of one attention layer's island on the plan's
    mesh, with the plan's pipeline/comm_chunks knobs honoured and
    ``unroll=True`` so every sub-ring ppermute appears as its own
    instruction (XLA keeps a while-loop body once otherwise). Requires the
    process to have ``plan.n_devices`` (forced-host on CPU) devices."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import startrail as st
    from repro.core import ulysses as ul

    n = seq_len or plan.seq_len
    st_cfg = st.StarTrailConfig(
        seq_len=n, seq_scheme=plan.seq_scheme, causal=True, unroll=True,
        pipeline=getattr(plan, "pipeline_scan", True),
        comm_chunks=getattr(plan, "comm_chunks", 1))
    mesh = plan.build_mesh()
    spec = P(None, st_cfg.axes, None, None)

    if plan.scheme == "ulysses":
        def local(q, k, v):
            return ul.ulysses_attention(q, k, v, st_cfg)
    else:
        def local(q, k, v):
            return st.startrail_attention(q, k, v, st_cfg)

    f = jax.jit(jax.shard_map(local, mesh=mesh, in_specs=(spec,) * 3,
                              out_specs=spec, check_vma=False))
    dh = cfg.head_dim_
    args = [jax.ShapeDtypeStruct((batch, n, h, dh), jnp.bfloat16)
            for h in (cfg.num_heads, cfg.num_kv_heads, cfg.num_kv_heads)]
    return f.lower(*args).compile().as_text()


def measure_attention_island(cfg: ModelConfig, plan, *,
                             batch: int = 1,
                             seq_len: Optional[int] = None,
                             ) -> Dict[str, object]:
    """Compile one attention layer's island on the plan's mesh and parse
    its HLO collectives into per-device wire bytes by kind."""
    from repro.roofline import hlo as hlo_lib

    n = seq_len or plan.seq_len
    parsed = hlo_lib.collective_bytes(
        _compile_island_text(cfg, plan, batch=batch, seq_len=n))
    by_kind = parsed["bytes_by_kind"]

    factors = _wire_factors(plan)
    wire = {kind: by_kind.get(kind, 0) * factors[kind]
            for kind in KIND_FROM_COMPONENTS}
    return {
        "wire_bytes_by_kind": wire,
        "result_bytes_by_kind": dict(by_kind),
        "count_by_kind": dict(parsed["count_by_kind"]),
        "unmodelled_allreduce_bytes": by_kind.get("all-reduce", 0),
    }


#: In-graph ring-scan spans (``jax.named_scope`` in ``core/startrail``).
#: They survive lowering into HLO instruction metadata (``op_name``) and
#: are what a device profiler groups the per-ring-step timeline by.
RING_SCOPES = ("ring_permute_issue", "ring_block_compute")


def ring_scope_counts(hlo_text: str) -> Dict[str, int]:
    """Instructions carrying each ring-scan scope in their HLO metadata.

    A zero ``ring_permute_issue`` count on a ring plan means the pipelined
    issue path was compiled out (e.g. ``pipeline_scan=False``); the
    overlap fraction should then be read as the scheduler's doing, not the
    double-buffered scan's.
    """
    import re

    counts = {s: 0 for s in RING_SCOPES}
    for m in re.finditer(r'op_name="([^"]*)"', hlo_text):
        for s in RING_SCOPES:
            if s in m.group(1):
                counts[s] += 1
    return counts


def overlap_report(cfg: ModelConfig, plan, *, batch: int = 1,
                   seq_len: Optional[int] = None,
                   registry=None) -> Dict[str, object]:
    """Measured comm/compute overlap fraction for the plan's attention
    island (``roofline/hlo.collective_overlap`` over the optimized HLO).

    The fraction is the share of dot instructions scheduled inside a
    collective-permute's issue→first-use window — the overlap the
    pipelined ring scan creates, and the number to feed back into the
    analytical model (``make_plan(..., overlap_frac=...)``,
    ``autotune(..., overlap_frac=...)``) in place of its perfect-hiding
    default. When ``registry`` is given, sets the
    ``attention_overlap_fraction`` gauge labelled by arrangement.
    """
    from repro.roofline import hlo as hlo_lib

    n = seq_len or plan.seq_len
    text = _compile_island_text(cfg, plan, batch=batch, seq_len=n)
    ov = hlo_lib.collective_overlap(text)
    report = {
        "ring_scope_instructions": ring_scope_counts(text),
        "arrangement": {"scheme": plan.scheme, "c": plan.c, "r": plan.r,
                        "sp": plan.sp_size, "placement": plan.placement,
                        "seq_scheme": plan.seq_scheme,
                        "pipeline_scan": getattr(plan, "pipeline_scan", True),
                        "comm_chunks": getattr(plan, "comm_chunks", 1)},
        "shape": {"batch": batch, "seq_len": n},
        **ov,
    }
    if registry is not None:
        registry.gauge(
            "attention_overlap_fraction",
            "Share of HLO dot instructions scheduled inside a "
            "collective-permute issue->first-use window (measured "
            "comm/compute overlap for the attention island)",
        ).set(ov["overlap_fraction"],
              scheme=plan.scheme, c=str(plan.c),
              pipeline=str(report["arrangement"]["pipeline_scan"]),
              comm_chunks=str(report["arrangement"]["comm_chunks"]))
    return report


def island_wire_volumes(cfg: ModelConfig, plan, *,
                        batch: int = 1,
                        seq_len: Optional[int] = None) -> Dict[str, float]:
    """What the *forward-only* compiled island should show.

    Identical to ``analytical_wire_volumes`` except collective-permute: in
    the forward pass each K/V chunk makes exactly one full sub-ring tour —
    R hops — whether the first hop is the placement exchange (C>1, where
    the final ring step's fetch is dead and XLA DCEs it) or a plain ring
    step (C=1, all R live). The per-step convention's extra placement hop
    (placement_p2p + R·chunk) pairs with the backward's reuse of the
    placement, so it belongs in ``CommLog`` pricing but not in a
    forward-island HLO comparison.
    """
    n = seq_len or plan.seq_len
    shape = ShapeConfig(plan.shape, seq_len=n, global_batch=batch,
                        kind="train")
    vols = cost.comm_volumes(cfg, shape, plan.sp_size, _arrangement(plan),
                             batch=batch, dtype_bytes=_wire_dtype_bytes())
    out = {kind: sum(vols[c] for c in comps)
           for kind, comps in KIND_FROM_COMPONENTS.items()}
    out["collective-permute"] = vols["ring_p2p"]  # R hops, no placement
    return out


def comm_report(cfg: ModelConfig, plan, *, batch: int = 1,
                seq_len: Optional[int] = None,
                tolerance: float = 0.05) -> Dict[str, object]:
    """Measured-vs-analytical per-collective report for one attention
    layer on the plan's arrangement. ``within_tolerance`` covers every
    kind with non-zero analytical volume."""
    n = seq_len or plan.seq_len
    analytical = island_wire_volumes(cfg, plan, batch=batch, seq_len=n)
    measured = measure_attention_island(cfg, plan, batch=batch, seq_len=n)

    kinds = {}
    ok = True
    for kind, a in analytical.items():
        m = measured["wire_bytes_by_kind"][kind]
        ratio = (m / a) if a else (None if m == 0 else float("inf"))
        within = ratio is None or abs(ratio - 1.0) <= tolerance
        ok = ok and within
        kinds[kind] = {"measured_bytes": m, "analytical_bytes": a,
                       "ratio": ratio, "within_tolerance": within}
    return {
        "arrangement": {"scheme": plan.scheme, "c": plan.c, "r": plan.r,
                        "sp": plan.sp_size, "placement": plan.placement,
                        "seq_scheme": plan.seq_scheme},
        "shape": {"batch": batch, "seq_len": n,
                  "num_heads": cfg.num_heads,
                  "num_kv_heads": cfg.num_kv_heads,
                  "head_dim": cfg.head_dim_,
                  "dtype_bytes": _wire_dtype_bytes()},
        "per_collective": kinds,
        "unmodelled_allreduce_bytes":
            measured["unmodelled_allreduce_bytes"],
        "collective_counts": measured["count_by_kind"],
        "tolerance": tolerance,
        "within_tolerance": ok,
    }


def within_tolerance(report: Dict[str, object]) -> bool:
    return bool(report["within_tolerance"])


def dump_report(report: Dict[str, object], path) -> None:
    import pathlib

    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(report, indent=2, sort_keys=True))


class CommLog:
    """Registry-backed per-train-step communication accounting.

    Prices the plan's per-layer analytical wire volumes once, then
    ``record_step`` ticks ``comm_bytes_total{collective=...}`` counters —
    host-side dict adds only, safe inside the trainer's async pipeline.
    The fwd+bwd multiplier is 3: the backward re-runs the gather/ring
    collectives for both dK/dV accumulation and dQ (the ring tour is
    re-traversed, rematerialising K/V), matching `plan/cost`'s train-step
    convention.
    """

    TRAIN_STEP_MULTIPLIER = 3

    def __init__(self, registry, cfg: ModelConfig, plan, *,
                 batch: Optional[int] = None, train: bool = True):
        b = batch if batch is not None else plan.global_batch
        per_layer = analytical_wire_volumes(cfg, plan, batch=max(b, 1))
        layers = cost.num_attention_layers(cfg)
        mult = self.TRAIN_STEP_MULTIPLIER if train else 1
        self._per_step = {kind: v * layers * mult
                          for kind, v in per_layer.items()}
        self._counter = registry.counter(
            "comm_bytes_total",
            "Analytical per-device bytes per collective kind, accumulated "
            "per step (plan/cost eqs. 2-4 at the resolved arrangement)")
        self._steps = registry.counter(
            "comm_steps_total", "Steps priced by the comm log")

    @property
    def per_step(self) -> Dict[str, float]:
        return dict(self._per_step)

    def record_step(self, n: int = 1) -> None:
        for kind, v in self._per_step.items():
            if v:
                self._counter.inc(v * n, collective=kind)
        self._steps.inc(n)
