"""repro.obs — unified observability: metrics registry, span tracing, and
measured-vs-analytical communication-volume accounting.

See docs/OBSERVABILITY.md for the contract and metric name table.
"""

from repro.obs.registry import (  # noqa: F401
    INTERTOKEN_BUCKETS, TTFT_BUCKETS, Counter, Gauge, Histogram, Registry,
    current_scope, global_registry, merge_prometheus_text, parse_prometheus,
    parse_prometheus_families, scope,
)
from repro.obs.tracing import NULL_TRACER, Tracer  # noqa: F401

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "Tracer", "NULL_TRACER",
    "TTFT_BUCKETS", "INTERTOKEN_BUCKETS", "current_scope", "scope",
    "global_registry", "parse_prometheus", "parse_prometheus_families",
    "merge_prometheus_text",
]
