"""Serving driver: prefill a batch of prompts, then decode greedily.

CPU-runnable reduced mode:

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --devices 8 --c 1 --prompt-len 16 --gen 8
"""

import argparse
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--c", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import registry
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.dist import meshes
    from repro.models.factory import build_model
    from repro.serve import kv_cache, step as serve_step

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get(args.arch)
    model = build_model(cfg)
    run_cfg = RunConfig(c=args.c, seq_scheme="contiguous")
    r = args.devices // (args.data * args.c * args.c)
    mesh = meshes.local_mesh_for_tests(c=args.c, r=r, data=args.data)
    sp = args.c * args.c * r

    capacity = args.prompt_len + args.gen
    capacity = ((capacity + sp - 1) // sp) * sp  # pad to SP multiple

    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)

    # prefill at prompt length (its own SP-divisible length), then copy the
    # prefix of each shard-sharded cache into the capacity-sized cache
    shape_p = ShapeConfig("serve", seq_len=args.prompt_len,
                          global_batch=args.batch, kind="prefill")
    jprefill, _ = serve_step.build_prefill_step(model, mesh, run_cfg, shape_p)
    batch = {"tokens": tokens}
    if cfg.frontend_stub is not None:
        batch["frontend_emb"] = jnp.zeros(
            (args.batch, args.prompt_len, cfg.d_model),
            jnp.dtype(cfg.param_dtype))
    tok, cache_p = jprefill(params, batch)

    # expand attention caches to capacity (host-side, example-scale)
    cache = kv_cache.init_cache(cfg, args.batch, capacity)
    def merge(dst, src):
        out = {}
        for k in dst:
            if isinstance(dst[k], dict):
                out[k] = merge(dst[k], src[k])
            elif dst[k].ndim >= 3 and dst[k].shape[2] == capacity:
                pad = np.zeros(dst[k].shape, dst[k].dtype)
                pad[:, :, :src[k].shape[2]] = np.asarray(src[k])
                out[k] = jnp.asarray(pad)
            else:
                out[k] = src[k]
        return out
    cache = {"stack": merge(cache["stack"], cache_p["stack"])}

    generated = [np.asarray(tok)]
    for i in range(args.gen - 1):
        shape_d = ShapeConfig("serve", seq_len=capacity,
                              global_batch=args.batch, kind="decode")
        jdecode, _ = serve_step.build_decode_step(model, mesh, run_cfg, shape_d)
        # NOTE example-scale: cache_len is static per compile; production
        # serving buckets cache lengths. Here we decode at fixed capacity-1.
        tok, cache = jdecode(params, cache, tok)
        generated.append(np.asarray(tok))
    out = np.concatenate(generated, axis=1)
    print(f"[serve] prompt {tokens.shape} -> generated {out.shape}:")
    print(out)
    return out


if __name__ == "__main__":
    main()
